"""Version shims over the JAX public API surface we depend on.

The repo targets the jax version baked into the container; a few symbols
moved between releases:

* ``jax.tree.flatten_with_path`` — only on newer jax; older releases spell
  it ``jax.tree_util.tree_flatten_with_path``.
* ``jax.shard_map`` — promoted out of ``jax.experimental.shard_map``.

Import from here instead of feature-testing at every call site.
"""
from __future__ import annotations

import jax
import jax.tree_util as _tu

tree_flatten_with_path = getattr(getattr(jax, "tree", None),
                                "flatten_with_path",
                                _tu.tree_flatten_with_path)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401

"""Continuous-batching serve engine.

Fixed-slot batched decoding over any of the architectures: requests join a
slot after a (batched) prefill into that slot's cache region, decode steps
run for the whole batch every tick, and finished slots are recycled —
the standard production serving loop (compare vLLM/JetStream), sized here
for CPU smoke scale but shape-stable for TPU.

Per-slot positions: decode uses a per-slot `pos` vector, so slots at
different depths coexist in one batched step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [len] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 128):
        assert cfg.family not in ("audio",), "enc-dec engine: use Whisper API"
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.cache = self.model.init_cache(slots, max_seq)
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self._next_rid = 0
        self._decode = jax.jit(self._decode_fn)
        self._prefill_one = jax.jit(self._prefill_fn,
                                    static_argnames=("plen",))

    # ---- jitted kernels ----
    def _decode_fn(self, params, cache, tok, pos):
        """All slots step together with PER-SLOT positions: vmap the
        single-sequence decode over the cache's batch axis (axis 1 of the
        stacked [layers, batch, ...] leaves)."""
        def one(p, c, t, q):
            c = jax.tree.map(lambda x: x[:, None], c)    # re-add batch dim
            logits, c2 = self.model.decode(p, c, t[None], q)
            return logits[0], jax.tree.map(lambda x: x[:, 0], c2)
        return jax.vmap(one, in_axes=(None, 1, 0, 0), out_axes=(0, 1))(
            params, cache, tok, pos)

    def _prefill_fn(self, params, tokens, *, plen):
        return self.model.prefill(params, tokens, self.max_seq)

    # ---- public API ----
    def submit(self, prompt: np.ndarray, max_new: int, rid: int | None = None):
        # rid defaults to a monotonic counter: `len(self.queue)` would
        # recycle ids once the queue drains, aliasing distinct requests.
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        r = Request(rid, prompt, max_new)
        self.queue.append(r)
        return r

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                r = self.queue.pop(0)
                logits, cache1 = self._prefill_one(
                    self.params, jnp.asarray(r.prompt[None]),
                    plen=len(r.prompt))
                # splice the single-sequence cache into slot s
                def put(full, one):
                    return full.at[:, s:s + 1].set(one)
                self.cache = jax.tree.map(put, self.cache, cache1)
                self.pos[s] = len(r.prompt)
                tok = int(jnp.argmax(logits[0]))
                r.out.append(tok)
                self.active[s] = r

    def step(self):
        """One engine tick: admit new requests, one decode step for all
        active slots, retire finished ones.  Returns #active."""
        self._admit()
        if not any(self.active):
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None:
                toks[s, 0] = r.out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        n_active = 0
        for s, r in enumerate(self.active):
            if r is None:
                continue
            self.pos[s] += 1
            r.out.append(int(nxt[s]))
            if len(r.out) >= r.max_new or self.pos[s] >= self.max_seq - 1:
                r.done = True
                self.active[s] = None
            else:
                n_active += 1
        return n_active + len(self.queue)

    def run(self, max_ticks: int = 1000):
        t = 0
        while (any(self.active) or self.queue) and t < max_ticks:
            self.step()
            t += 1

"""Multi-tenant plan serving: shape-bucketed batching of concurrent
CompiledProgram invocations (DESIGN.md §10).

PR 5 made a single caller fast — one cached XLA dispatch per run().  This
layer makes MANY callers fast: a request queue admits concurrent
invocations of registered programs, buckets them by the whole-program
compile-cache signature (static dims by value, shapes, dtypes — PR 5's
keying IS the bucketing function), pads ragged same-program requests up to
the bucket shape, and coalesces each bucket into ONE vmapped whole-program
XLA call (CompiledProgram.batched_call, the batchable-entry hook in
lower.py).  Padding is semantics-free: padded bag rows and padded
bag-aligned array rows carry per-lane `bag_limits`/`array_limits` masks —
the same §3.4 pad+mask machinery the distributed executor trusts — so a
padded request returns bit-identical results to a solo run().

Scheduling is deterministic and clock-injected: a bucket flushes when it
reaches `max_batch` requests or when its oldest request has waited
`flush_ms` (the straggler timeout).  `pump()` advances the server one
scheduling step against the injected clock — tests drive it with a fake
clock and scripted arrivals, production drives it from a background thread
(`start()`) or any event loop.  Host→device transfer of the next ready
bucket is overlapped with in-flight compute: the stacked arrays of bucket
k+1 are `jax.device_put` while bucket k's donated computation runs, before
its outputs are materialized.

Observability mirrors explain(): `stats()` returns the counters (per-bucket
queue depth, batch occupancy, padded-row fraction, p50/p99 latency,
requests/sec, batch-signature compile-cache hits/misses) and
`explain_serving()` renders the golden-testable text form.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import deque

import numpy as np

import jax

from ..core import faults as F


class QueueFull(RuntimeError):
    """Admission refused: the server-wide queue cap is reached.  Raised
    from submit() BEFORE a ticket exists — a shed request is never
    admitted, so the ledger invariant (admitted = completed + cancelled +
    failed + queued) is untouched; the shed is counted in stats()."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed while it was still queued: it is
    shed before pad/stack/flush ever spends work on it."""


def _bucket_len(n: int, floor: int) -> int:
    """Bucket edge for a row count: next power of two, at least `floor`.
    Ragged same-program requests round up to a shared edge so they share
    one traced batch computation instead of one signature each."""
    L = max(int(floor), 1)
    while L < n:
        L *= 2
    return L


def _pad0(a: np.ndarray, L: int) -> np.ndarray:
    if a.shape[0] == L:
        return a
    pad = np.zeros((L - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad])


def _pct(xs, q):
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]


class PlanTicket:
    """One admitted invocation: resolves to the program's output dict
    (numpy, sliced back to the request's own shapes), or to cancelled /
    failed.  `result()` blocks (real-clock servers run a pump thread);
    deterministic tests drain() the server instead and read `output`."""

    __slots__ = ("rid", "program", "cin", "bucket", "t_submit", "deadline",
                 "state", "output", "error", "_event", "_completions")

    def __init__(self, rid, program, cin, bucket, t_submit, deadline=None):
        self.rid = rid
        self.program = program
        self.cin = cin                 # canonicalized inputs (numpy)
        self.bucket = bucket
        self.t_submit = t_submit
        self.deadline = deadline       # absolute clock time, or None
        self.state = "queued"
        self.output = None
        self.error = None
        self._event = threading.Event()
        self._completions = 0          # must stay ≤ 1 (no duplicate resolve)

    def done(self) -> bool:
        return self.state != "queued"

    def result(self, timeout=None) -> dict:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} still queued")
        if self.state == "cancelled":
            raise RuntimeError(f"request {self.rid} was cancelled")
        if self.state == "failed":
            raise self.error
        return self.output

    def _resolve(self, state, output=None, error=None):
        self._completions += 1
        assert self._completions == 1, \
            f"request {self.rid} resolved twice ({self.state} -> {state})"
        self.state = state
        self.output = output
        self.error = error
        self._event.set()


class _Bucket:
    """One shape class of one program: the queue plus its counters."""

    __slots__ = ("key", "cp", "program", "label", "static", "bag_pads",
                 "arr_pads", "limit_bags", "limit_arrays", "tickets",
                 "flushes", "reqs", "traced", "hits", "real_lanes", "lanes",
                 "pad_rows", "bag_rows", "failed_flushes", "est_peak",
                 "lane_cap")

    def __init__(self, key, cp, program, label, static, bag_pads, arr_pads):
        self.key = key
        self.cp = cp
        self.program = program
        self.label = label
        self.static = static               # dim name → value
        self.bag_pads = bag_pads           # bag name → padded row count
        self.arr_pads = arr_pads           # array name → padded dim-0
        self.limit_bags = tuple(sorted(bag_pads))
        self.limit_arrays = tuple(sorted(arr_pads))
        self.tickets: deque = deque()
        self.flushes = 0
        self.reqs = 0
        self.traced = 0
        self.hits = 0
        self.real_lanes = 0                # requests actually served
        self.lanes = 0                     # vmap lanes dispatched (≥ real)
        self.pad_rows = 0                  # padded bag rows
        self.bag_rows = 0                  # total bag rows dispatched
        self.failed_flushes = 0            # batched calls that raised
        self.est_peak = None               # estimated device bytes per lane
        self.lane_cap = None               # memory_budget // est_peak

    def occ(self) -> float:
        return 100.0 * self.real_lanes / self.lanes if self.lanes else 0.0

    def padf(self) -> float:
        return 100.0 * self.pad_rows / self.bag_rows if self.bag_rows \
            else 0.0


class PlanServer:
    """Shared serving engine for compiled loop programs.

      server = PlanServer({"pagerank": cp_pr, "group_by": cp_gb})
      server.start()                      # background pump thread
      t = server.submit("group_by", dict(S=(k, v), C=np.zeros(10)))
      out = t.result(timeout=5.0)         # numpy output dict

    Deterministic mode (tests): pass `clock=fake_clock`, never start a
    thread, and call `pump()` / `drain()` explicitly — every scheduling
    decision reads the injected clock, so scripted arrival schedules
    replay exactly.

    `max_batch` caps requests per flush; `flush_ms` bounds how long a
    straggler waits for company; `bucket_floor` is the smallest bag bucket
    edge (row counts round up to powers of two from there);
    `batch_round=True` also rounds the LANE count up to a power of two
    (replicating the first request into dummy lanes, outputs dropped) so
    the compile cache holds O(log max_batch) entries per bucket instead of
    one per distinct batch size.  `memory_budget` (device bytes) makes
    admission memory-aware: each bucket's flush is capped at
    budget // estimated-peak-per-lane lanes (excess requests wait,
    `mem_deferred`), and requests whose single lane cannot fit shed with a
    RESOURCE_EXHAUSTED error (`mem_shed`) instead of OOM-killing a
    flush."""

    def __init__(self, programs: dict, *, max_batch: int = 8,
                 flush_ms: float = 2.0, bucket_floor: int = 8,
                 batch_round: bool = True, clock=None, prefetch: bool = True,
                 sequential_fallback: bool = True, deadline_ms: float = None,
                 queue_cap: int = None, nan_guard: bool = True,
                 bisect: bool = True, memory_budget: int = None,
                 speculative: bool = True):
        self._programs = dict(programs)
        self.max_batch = int(max_batch)
        self.flush_s = float(flush_ms) / 1e3
        self.bucket_floor = int(bucket_floor)
        self.batch_round = bool(batch_round)
        self.prefetch = bool(prefetch)
        self.sequential_fallback = bool(sequential_fallback)
        # robustness knobs (DESIGN.md §11): default request deadline (per
        # request override in submit()), server-wide admission cap, per-lane
        # non-finite output guard, and failed-batch bisection
        self.deadline_s = None if deadline_ms is None \
            else float(deadline_ms) / 1e3
        self.queue_cap = None if queue_cap is None else int(queue_cap)
        self.nan_guard = bool(nan_guard)
        self.bisect = bool(bisect)
        # memory-aware admission (DESIGN.md §12): with a device budget set,
        # each bucket gets a lane cap = budget // estimated-peak-per-lane
        # (memest over the bucket's padded signature).  A flush never takes
        # more lanes than fit — the remainder WAITS in queue (mem_deferred)
        # instead of the whole batch OOM-killing mid-flight; a request whose
        # single lane already exceeds the budget is shed with a
        # RESOURCE_EXHAUSTED error (mem_shed) that classify() reads as
        # capacity, steering the caller toward out-of-core run().
        self.memory_budget = None if memory_budget is None \
            else int(memory_budget)
        self.mem_deferred = 0              # lanes queued past their flush
        self.mem_shed = 0                  # requests too big for the budget
        self._clock = clock if clock is not None else time.monotonic
        self._lock = threading.RLock()
        self._buckets: dict = {}           # key → _Bucket (insertion order)
        self._staged: dict = {}            # key → (rids, Bp, device pytree)
        self._next_rid = 0
        self._t0 = None                    # first submit time
        self._t_last = None                # last completion time
        self._lat = deque(maxlen=8192)     # completion latencies (seconds)
        self.admitted = 0
        self.completed = 0
        self.cancelled = 0
        self.failed = 0
        self.seq_fallbacks = 0
        self.load_shed = 0                 # admissions refused (queue cap)
        self.deadline_expired = 0          # queued requests shed at deadline
        self.failed_flushes = 0            # batched calls that raised
        self.bisections = 0                # failed batches split in half
        self.poisoned = 0                  # lanes failed by the nan guard
        # speculative re-execution of straggling flushes (DESIGN.md §13)
        self.speculative = bool(speculative)
        self.speculated = 0                # backup flushes launched
        # failure policy (DESIGN.md §11): server-level ledger on the
        # injected clock; with a fake clock, retry backoff never really
        # sleeps — tests replay schedules deterministically
        self.faults = F.FaultLedger("serve")
        self.faults.clock = self._clock
        if clock is not None:
            self.faults.sleep = lambda s: None
        self.policy = F.RetryPolicy()
        self._thread = None
        self._stop = None

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, program: str, inputs: dict, *,
               deadline_ms: float = None) -> PlanTicket:
        """Admit one invocation: canonicalize host-side, bucket by the
        padded compile-cache signature, enqueue.  Never blocks and never
        touches the device.  Raises QueueFull (no ticket, load-shed
        counted) when the server-wide admission cap is reached;
        `deadline_ms` (or the server default) arms a deadline after which
        the still-queued request is shed before any pad/flush work."""
        cp = self._programs[program]
        cin = cp.canonical_inputs(inputs)
        with self._lock:
            if self.queue_cap is not None:
                queued = sum(len(b.tickets) for b in self._buckets.values())
                if queued >= self.queue_cap:
                    self.load_shed += 1
                    raise QueueFull(
                        f"queue cap {self.queue_cap} reached "
                        f"({self.load_shed} shed so far)")
            b = self._bucket_for(program, cp, cin)
            now = self._clock()
            if self._t0 is None:
                self._t0 = now
            dl_s = float(deadline_ms) / 1e3 if deadline_ms is not None \
                else self.deadline_s
            t = PlanTicket(self._next_rid, program, cin, b, now,
                           deadline=None if dl_s is None else now + dl_s)
            self._next_rid += 1
            b.tickets.append(t)
            self.admitted += 1
            return t

    def cancel(self, ticket: PlanTicket) -> bool:
        """Withdraw a still-queued request.  False once it flushed."""
        with self._lock:
            if ticket.done():
                return False
            try:
                ticket.bucket.tickets.remove(ticket)
            except ValueError:
                return False
            self._staged.pop(ticket.bucket.key, None)
            ticket._resolve("cancelled")
            self.cancelled += 1
            return True

    def _bucket_for(self, program, cp, cin) -> _Bucket:
        params = cp.program.params
        aligned = cp.bag_row_aligned
        bag_pads, bag_lens = {}, {}
        for name, t in params.items():
            if t.kind == "bag":
                n = int(cin[name][0].shape[0])
                bag_lens[name] = n
                bag_pads[name] = _bucket_len(n, self.bucket_floor)
        arr_pads = {}
        for arr, bag in aligned.items():
            v = cin.get(arr)
            if bag in bag_lens and isinstance(v, np.ndarray) and v.ndim \
                    and v.shape[0] == bag_lens[bag]:
                arr_pads[arr] = bag_pads[bag]
        static, psig = {}, []
        for name, t in params.items():
            v = cin[name]
            if t.kind == "dim":
                static[name] = int(v)
                psig.append((name, "dim", int(v)))
            elif t.kind == "bag":
                L = bag_pads[name]
                psig.append((name, "bag", tuple(
                    ((L,) + tuple(c.shape[1:]), str(c.dtype)) for c in v)))
            else:
                shp = tuple(np.shape(v))
                if name in arr_pads:
                    shp = (arr_pads[name],) + shp[1:]
                psig.append((name, t.kind, shp, str(np.asarray(v).dtype)))
        key = (program, tuple(psig), frozenset(arr_pads))
        b = self._buckets.get(key)
        if b is None:
            b = _Bucket(key, cp, program, self._label(program, key, static,
                                                      bag_pads, arr_pads),
                        static, bag_pads, arr_pads)
            self._mem_size(b, tuple(psig))
            self._buckets[key] = b
        return b

    def _mem_size(self, b: _Bucket, psig) -> None:
        """Estimate peak device bytes for ONE lane of this bucket (the
        padded signature IS the shape set every lane runs at) and derive
        the lane cap.  Estimation failure just leaves the bucket uncapped
        — admission control is an optimization, never a correctness
        gate."""
        if self.memory_budget is None:
            return
        try:
            from ..core import memest
            senv = memest.shape_env_from_signature(b.cp.program, psig)
            est = memest.estimate(b.cp.plan, b.cp.program, senv)
            b.est_peak = int(est.peak_bytes)
            if b.est_peak > 0:
                b.lane_cap = self.memory_budget // b.est_peak
        except Exception:                  # noqa: BLE001 — advisory only
            return

    def _take_n(self, b: _Bucket) -> int:
        """Lanes one flush of this bucket may take: max_batch, tightened
        by the memory-derived lane cap."""
        n = self.max_batch
        if b.lane_cap is not None:
            n = min(n, max(b.lane_cap, 1))
        return n

    @staticmethod
    def _label(program, key, static, bag_pads, arr_pads) -> str:
        parts = [f"{n}:{L}" for n, L in bag_pads.items()]
        parts += [f"{n}:{L}" for n, L in sorted(arr_pads.items())]
        parts += [f"{n}={v}" for n, v in static.items()]
        h = hashlib.md5(repr(key).encode()).hexdigest()[:4]
        return f"{program}{{{' '.join(parts)}}}#{h}"

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def _next_ready(self, now, force=False):
        """Deterministic flush order: full buckets first (insertion
        order), then timed-out stragglers, then — under drain — anything
        non-empty."""
        for key, b in self._buckets.items():
            if len(b.tickets) >= self.max_batch:
                return key
        for key, b in self._buckets.items():
            if b.tickets and now - b.tickets[0].t_submit >= self.flush_s:
                return key
        if force:
            for key, b in self._buckets.items():
                if b.tickets:
                    return key
        return None

    def pump(self) -> int:
        """One scheduling step: flush every ready bucket (full or
        timed-out against the injected clock).  Returns the number of
        requests completed.  Thread-safe; deterministic under a fake
        clock."""
        return self._pump(force=False)

    def drain(self) -> int:
        """Flush everything regardless of readiness until no request is
        queued.  Returns the number of requests completed."""
        return self._pump(force=True)

    def _pump(self, force: bool) -> int:
        done = 0
        with self._lock:
            while True:
                now = self._clock()
                self._shed_expired(now)
                key = self._next_ready(now, force=force)
                if key is None:
                    return done
                done += self._flush(self._buckets[key], force)

    def _shed_expired(self, now) -> None:
        """Deadline shedding, BEFORE pad/stack/flush: queued requests
        whose deadline passed fail with DeadlineExceeded and never cost a
        lane.  A staged prefetch whose ticket set changed is dropped."""
        for b in self._buckets.values():
            if not any(tk.deadline is not None and now >= tk.deadline
                       for tk in b.tickets):
                continue
            keep = deque()
            while b.tickets:
                tk = b.tickets.popleft()
                if tk.deadline is not None and now >= tk.deadline:
                    tk._resolve("failed", error=DeadlineExceeded(
                        f"request {tk.rid} shed after "
                        f"{(now - tk.t_submit) * 1e3:.1f}ms in queue"))
                    self.failed += 1
                    self.deadline_expired += 1
                else:
                    keep.append(tk)
            b.tickets = keep
            self._staged.pop(b.key, None)

    # ------------------------------------------------------------------
    # flush: stack → device_put → one batched XLA call → unstack
    # ------------------------------------------------------------------

    def _round_lanes(self, B: int) -> int:
        if not self.batch_round:
            return B
        Bp = 1
        while Bp < B:
            Bp *= 2
        return min(Bp, self.max_batch)

    def _stack(self, b: _Bucket, take):
        """Host-side coalescing of one flush: pad each request's bags (and
        bag-aligned arrays) to the bucket shape, stack along a new lane
        axis, round the lane count up (dummy lanes replicate request 0 and
        are dropped after the call).  Returns (arrays, lengths) numpy
        pytrees ready for one device_put."""
        Bp = self._round_lanes(len(take))
        if b.lane_cap is not None:
            # never let lane ROUNDING inflate a batch past the budget the
            # admission cap just enforced (dummy lanes cost real memory)
            Bp = max(len(take), min(Bp, b.lane_cap))
        lanes = list(take) + [take[0]] * (Bp - len(take))
        arrays, lengths = {}, {}
        for name, t in b.cp.program.params.items():
            if t.kind == "dim":
                continue
            if t.kind == "bag":
                L = b.bag_pads[name]
                ncols = len(take[0].cin[name])
                arrays[name] = tuple(
                    np.stack([_pad0(tk.cin[name][ci], L) for tk in lanes])
                    for ci in range(ncols))
                lengths[name] = np.asarray(
                    [tk.cin[name][0].shape[0] for tk in lanes], np.int32)
            elif name in b.arr_pads:
                L = b.arr_pads[name]
                arrays[name] = np.stack(
                    [_pad0(tk.cin[name], L) for tk in lanes])
                lengths[name] = np.asarray(
                    [tk.cin[name].shape[0] for tk in lanes], np.int32)
            else:
                arrays[name] = np.stack(
                    [np.asarray(tk.cin[name]) for tk in lanes])
        # poisonable injection point: the stacked batch is mutable numpy
        # here, one lane per request — a rid-matched poison spec NaNs
        # exactly its request's lane (the nan guard must then isolate it)
        F.site("serve.stack", program=b.program,
               rids=[tk.rid for tk in lanes], arrays=arrays)
        return Bp, arrays, lengths

    def _device_put(self, tree):
        F.site("serve.device_put")
        return jax.device_put(tree)

    def _stage(self, b: _Bucket):
        """Prefetch: stack the bucket's next flush and start its
        host→device transfer now, while the in-flight computation still
        runs.  Consumed by _flush when the ticket set matches.  Purely an
        overlap optimization — a fault here just skips the prefetch; the
        flush restacks and meets the fault on its own dispatch path."""
        take = list(b.tickets)[:self._take_n(b)]
        if not take:
            return
        try:
            Bp, arrays, lengths = self._stack(b, take)
            dev = self._device_put((arrays, lengths))
        except Exception:                  # noqa: BLE001 — optimization only
            return
        self._staged[b.key] = (tuple(t.rid for t in take), Bp, dev)

    def _call_batch(self, b: _Bucket, take, Bp, arrays, lengths):
        """One batched XLA call under the failure policy: transients retry
        at this level (batch intact); anything else raises to _dispatch,
        which bisects the batch.  The wall time feeds the straggler
        watchdog; a flagged straggling flush triggers speculative
        re-execution (DESIGN.md §13) — at most ONE backup copy per flush,
        first finisher wins, the loser is cancelled.  Both copies run the
        same cached batched executable on the same staged batch, so
        adopting the faster one never changes any lane's answer."""
        rids = tuple(tk.rid for tk in take)
        label = f"batch[{Bp}]"

        def call(buf=arrays):
            return b.cp.batched_call((b.key, Bp), b.static, buf, lengths,
                                     b.limit_bags, b.limit_arrays)

        def attempt():
            F.site("serve.batched_call", program=b.program, rids=rids)
            return call()

        # batched_call DONATES the mutated destinations — a backup copy
        # cannot reuse the original flush's buffers, so its operand set
        # is reserved before the first dispatch consumes them (a real
        # cluster's backup task reads its own replica of the batch)
        spare = None
        if self.speculative:
            spare = {n: tuple(c.copy() for c in v) if isinstance(v, tuple)
                     else v.copy()
                     for n, v in arrays.items()
                     if n in b.cp._donate_names}
        t0 = self._clock()
        out = F.run_with_retries(attempt, policy=self.policy,
                                 ledger=self.faults, label=label)
        dt = self._clock() - t0
        straggled = self.faults.note_time(label, dt)
        if straggled and self.speculative:
            self.speculated += 1
            t1 = self._clock()
            backup = call({**arrays, **spare})
            #                       no injection site: the backup flush
            #                       dispatches to a healthy replica
            dt2 = self._clock() - t1
            if dt2 < dt:
                self.faults.spec_saved_s += dt - dt2
                self.faults.record(
                    "speculative", label,
                    f"backup flush won: {dt2 * 1e3:.1f}ms vs straggler "
                    f"{dt * 1e3:.1f}ms (saved {(dt - dt2) * 1e3:.1f}ms); "
                    f"straggler copy cancelled")
                out = backup
            else:
                self.faults.record(
                    "speculative", label,
                    f"original flush finished first ({dt * 1e3:.1f}ms); "
                    f"backup cancelled after {dt2 * 1e3:.1f}ms")
        return out

    def _flush(self, b: _Bucket, force: bool) -> int:
        if b.lane_cap == 0:
            return self._shed_oversize(b)
        n = min(self._take_n(b), len(b.tickets))
        if b.lane_cap is not None and len(b.tickets) > n:
            # memory-aware admission: the rest of the bucket WAITS for the
            # next flush instead of riding a batch projected past the
            # device budget and OOM-killing everyone mid-flight
            self.mem_deferred += len(b.tickets) - n
            self.faults.record(
                "defer", b.label,
                f"{len(b.tickets) - n} lanes held: lane_cap={b.lane_cap} "
                f"(peak≈{b.est_peak}B/lane, budget={self.memory_budget}B)")
        take = [b.tickets.popleft() for _ in range(n)]
        if not take:
            return 0
        return self._dispatch(b, take, force, staged_ok=True)

    def _shed_oversize(self, b: _Bucket) -> int:
        """A single lane of this bucket already exceeds the device budget:
        no batch composition can serve it, so every queued request sheds
        with a capacity-classified error (the caller's remedy is the
        out-of-core run() path, not a retry here)."""
        self._staged.pop(b.key, None)
        shed = 0
        while b.tickets:
            tk = b.tickets.popleft()
            tk._resolve("failed", error=RuntimeError(
                f"RESOURCE_EXHAUSTED: request {tk.rid} needs "
                f"≈{b.est_peak} bytes/lane, over the "
                f"{self.memory_budget}-byte serving budget; run it "
                f"out-of-core (memory_budget= on compile_program)"))
            self.failed += 1
            self.mem_shed += 1
            shed += 1
        if shed:
            self.faults.record("shed", b.label,
                               f"{shed} oversize requests: "
                               f"peak≈{b.est_peak}B/lane > "
                               f"budget={self.memory_budget}B")
        return shed

    def _dispatch(self, b: _Bucket, take, force, staged_ok) -> int:
        """Serve `take` as ONE batched call.  Success accounting happens
        ONLY here on the success path (failed flushes must not inflate
        served lanes/occupancy/latency — they get their own counters); a
        failed call descends to _resolve_failed_batch (bisection)."""
        trace0 = b.cp.trace_count
        try:
            staged = self._staged.pop(b.key, None) if staged_ok else None
            if staged is not None \
                    and staged[0] == tuple(t.rid for t in take):
                Bp, (arrays, lengths) = staged[1], staged[2]
            else:
                Bp, arrays, lengths = self._stack(b, take)
                arrays, lengths = self._device_put((arrays, lengths))
            out = self._call_batch(b, take, Bp, arrays, lengths)
        except Exception as ex:            # noqa: BLE001 — ladder descent
            b.failed_flushes += 1
            self.failed_flushes += 1
            return self._resolve_failed_batch(b, take, force, ex)
        if b.cp.trace_count > trace0:
            b.traced += 1
        else:
            b.hits += 1
        # overlap: start the NEXT ready bucket's host→device transfer
        # while this (asynchronously dispatched) computation runs
        if self.prefetch:
            nk = self._next_ready(self._clock(), force=force)
            if nk is not None and nk not in self._staged:
                self._stage(self._buckets[nk])
        host = {n: np.asarray(v) for n, v in out.items()}
        b.flushes += 1
        b.lanes += Bp
        for tk in take:
            for bag, L in b.bag_pads.items():
                n = tk.cin[bag][0].shape[0]
                b.pad_rows += L - n
                b.bag_rows += L
        now = self._clock()
        self._t_last = now
        for i, tk in enumerate(take):
            res, finite = {}, True
            for n, v in host.items():
                lane = v[i]
                want = tuple(np.shape(tk.cin[n]))
                if lane.shape != want:
                    lane = lane[tuple(slice(0, s) for s in want)]
                res[n] = lane
                if self.nan_guard \
                        and np.issubdtype(lane.dtype, np.floating) \
                        and not np.all(np.isfinite(lane)):
                    finite = False
            if not finite:
                # per-lane poison isolation: only THIS request fails; its
                # batchmates' lanes are untouched and complete right here
                tk._resolve("failed", error=F.PoisonedOutput(
                    f"request {tk.rid}: non-finite values in output"))
                self.failed += 1
                self.poisoned += 1
                continue
            tk._resolve("done", output=res)
            b.reqs += 1
            b.real_lanes += 1
            self.completed += 1
            self._lat.append(now - tk.t_submit)
        return len(take)

    def _resolve_failed_batch(self, b: _Bucket, take, force, err) -> int:
        """A batched call failed after retries.  With one request there is
        nothing left to split: serve it through the sequential fallback
        (or fail it).  Otherwise BISECT: each half re-dispatches as its
        own batched call, so one poisoned request ends up failing alone in
        O(log B) extra calls while every other request still completes
        batched — never the all-sequential stampede."""
        if len(take) == 1 or not self.bisect:
            now = self._clock()
            self._t_last = now
            for tk in take:
                self._complete_fallback(tk, err, now)
            return len(take)
        self.bisections += 1
        mid = len(take) // 2
        done = self._dispatch(b, take[:mid], force, staged_ok=False)
        done += self._dispatch(b, take[mid:], force, staged_ok=False)
        return done

    def _complete_fallback(self, tk, err, now):
        """Batched trace failed: serve this request alone through the
        ordinary run() path (the guaranteed fallback), or fail it."""
        if not self.sequential_fallback:
            tk._resolve("failed", error=err)
            self.failed += 1
            return
        try:
            out = self._programs[tk.program].run(dict(tk.cin))
            tk._resolve("done",
                        output={n: np.asarray(v) for n, v in out.items()})
            self.completed += 1
            self.seq_fallbacks += 1
            self._lat.append(now - tk.t_submit)
        except Exception as ex:            # noqa: BLE001
            tk._resolve("failed", error=ex)
            self.failed += 1

    # ------------------------------------------------------------------
    # blocking / threaded / async front ends
    # ------------------------------------------------------------------

    def start(self, poll_s: float = 2e-4):
        """Run pump() from a daemon thread (real-clock servers)."""
        if self._thread is not None:
            return
        self._stop = threading.Event()

        def loop():
            while not self._stop.is_set():
                if self.pump() == 0:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="plan-server-pump")
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def run(self, program: str, inputs: dict, timeout: float = 60.0) -> dict:
        """Submit and wait.  With a pump thread this just blocks on the
        ticket; without one it pumps inline (real clock only)."""
        t = self.submit(program, inputs)
        if self._thread is not None:
            return t.result(timeout)
        deadline = time.monotonic() + timeout
        while not t.done():
            if self.pump() == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(f"request {t.rid} still queued")
                time.sleep(1e-4)
        return t.result(0)

    async def arun(self, program: str, inputs: dict,
                   timeout: float = 60.0) -> dict:
        """Asyncio front end: submit, then await the ticket without
        blocking the event loop.  Requires a running pump thread."""
        import asyncio
        t = self.submit(program, inputs)
        return await asyncio.to_thread(t.result, timeout)

    # ------------------------------------------------------------------
    # observability (stats() is the data, explain_serving() the text)
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            queued = sum(len(b.tickets) for b in self._buckets.values())
            lanes = sum(b.lanes for b in self._buckets.values())
            real = sum(b.real_lanes for b in self._buckets.values())
            lat_ms = [x * 1e3 for x in self._lat]
            span = (self._t_last - self._t0) \
                if self._t0 is not None and self._t_last is not None else 0.0
            return {
                "admitted": self.admitted, "completed": self.completed,
                "cancelled": self.cancelled, "failed": self.failed,
                "queued": queued,
                "seq_fallbacks": self.seq_fallbacks,
                "load_shed": self.load_shed,
                "deadline_expired": self.deadline_expired,
                "failed_flushes": self.failed_flushes,
                "bisections": self.bisections,
                "poisoned": self.poisoned,
                "mem_deferred": self.mem_deferred,
                "mem_shed": self.mem_shed,
                "speculated": self.speculated,
                "spec_saved_ms": self.faults.spec_saved_s * 1e3,
                "retries": self.faults.counters["retry"],
                "flushes": sum(b.flushes for b in self._buckets.values()),
                "batch_traced": sum(b.traced
                                    for b in self._buckets.values()),
                "batch_hits": sum(b.hits for b in self._buckets.values()),
                "p50_ms": _pct(lat_ms, 0.50), "p99_ms": _pct(lat_ms, 0.99),
                "rps": self.completed / span if span > 0 else 0.0,
                "occupancy": 100.0 * real / lanes if lanes else 0.0,
                "buckets": {
                    b.label: {"depth": len(b.tickets), "reqs": b.reqs,
                              "flushes": b.flushes, "occ": b.occ(),
                              "pad": b.padf(), "traced": b.traced,
                              "hits": b.hits, "est_peak": b.est_peak,
                              "lane_cap": b.lane_cap}
                    for b in self._buckets.values()},
            }

    def explain_serving(self) -> str:
        """Golden-testable dump of the serving state, the way explain()
        pins the plan: one row per shape bucket, then the admission
        totals, the latency/throughput probes, and the batch-signature
        compile-cache line."""
        s = self.stats()
        out = [f"== serving plans: {len(self._programs)} programs, "
               f"max_batch={self.max_batch}, "
               f"flush={self.flush_s * 1e3:.1f}ms, "
               f"bucket_floor={self.bucket_floor} =="]
        for label, r in s["buckets"].items():
            out.append(f"bucket {label}: depth={r['depth']} "
                       f"reqs={r['reqs']} flushes={r['flushes']} "
                       f"occ={r['occ']:.0f}% pad={r['pad']:.0f}% "
                       f"traced={r['traced']} hits={r['hits']}")
        out.append(f"totals: admitted={s['admitted']} "
                   f"completed={s['completed']} "
                   f"cancelled={s['cancelled']} failed={s['failed']} "
                   f"queued={s['queued']}")
        out.append(f"latency: p50={s['p50_ms']:.1f}ms "
                   f"p99={s['p99_ms']:.1f}ms  "
                   f"throughput={s['rps']:.1f} req/s")
        out.append(f"whole-program cache: {s['batch_traced']} batch "
                   f"signatures traced, {s['batch_hits']} hits, "
                   f"{s['seq_fallbacks']} sequential fallbacks")
        out.append(f"robustness: load_shed={s['load_shed']} "
                   f"deadline_expired={s['deadline_expired']} "
                   f"failed_flushes={s['failed_flushes']} "
                   f"bisections={s['bisections']} "
                   f"poisoned={s['poisoned']} retries={s['retries']} "
                   f"speculated={s['speculated']}")
        if self.memory_budget is not None:
            from ..core.memest import fmt_bytes
            caps = "  ".join(
                f"{r['lane_cap'] if r['lane_cap'] is not None else '-'}"
                f"@{fmt_bytes(r['est_peak']) if r['est_peak'] else '?'}"
                for r in s["buckets"].values())
            out.append(f"memory: budget={fmt_bytes(self.memory_budget)} "
                       f"mem_deferred={s['mem_deferred']} "
                       f"mem_shed={s['mem_shed']}  "
                       f"lane_caps=[{caps}]")
        return "\n".join(out)

    def explain_faults(self) -> str:
        """The serving layer's failure ledger (retries, stragglers) —
        the per-program ladders live on each CompiledProgram."""
        return self.faults.explain()

from .plans import DeadlineExceeded, PlanServer, PlanTicket, QueueFull
from .step import make_decode_step, make_prefill_step

__all__ = ["DeadlineExceeded", "PlanServer", "PlanTicket", "QueueFull",
           "make_prefill_step", "make_decode_step"]

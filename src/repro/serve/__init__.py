from .plans import PlanServer, PlanTicket
from .step import make_decode_step, make_prefill_step

__all__ = ["PlanServer", "PlanTicket", "make_prefill_step",
           "make_decode_step"]

"""Serving steps: prefill (builds the cache) and decode (one new token with
a KV/state cache of `max_seq`)."""
from __future__ import annotations

from ..models import get_model


def make_prefill_step(cfg, max_seq, mesh=None, dp_axes=("data",)):
    model = get_model(cfg)

    if cfg.family == "audio":
        def prefill_step(params, batch):
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 max_seq, mesh, dp_axes)
    else:
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], max_seq, mesh,
                                 dp_axes, pos_ids=batch.get("pos_ids"))
    return prefill_step


def make_decode_step(cfg, mesh=None, dp_axes=("data",)):
    model = get_model(cfg)

    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos, mesh, dp_axes)
    return decode_step

"""Layer-kind dispatch: param defs + forward/prefill/decode per block kind.

Kinds: "dense" (GQA attn + SwiGLU), "moe" (GQA attn + MoE [+dense residual]),
"ssm" (Mamba-1), "rec" (RG-LRU + MLP), "lattn" (local-window attn + MLP).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec_mod
from . import ssm as ssm_mod
from .common import ParamDef, rms_norm, swiglu


def _norm_def(cfg):
    return ParamDef((cfg.d_model,), ("embed",), jnp.float32, init="zeros")


def _mlp_defs(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype
    return {"w_gate": ParamDef((d, ff), ("embed", "ff"), dt),
            "w_in": ParamDef((d, ff), ("embed", "ff"), dt),
            "w_out": ParamDef((ff, d), ("ff", "embed"), dt)}


def block_defs(cfg, kind: str) -> dict:
    if kind == "dense":
        return {"ln1": _norm_def(cfg), "attn": attn.attn_defs(cfg),
                "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}
    if kind == "moe":
        d = {"ln1": _norm_def(cfg), "attn": attn.attn_defs(cfg),
             "ln2": _norm_def(cfg), "moe": moe_mod.moe_defs(cfg)}
        if cfg.dense_residual:
            d["mlp"] = _mlp_defs(cfg)
        return d
    if kind == "ssm":
        return {"ln": _norm_def(cfg), "ssm": ssm_mod.ssm_defs(cfg)}
    if kind == "rec":
        return {"ln1": _norm_def(cfg), "rec": rec_mod.rglru_defs(cfg),
                "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}
    if kind == "lattn":
        return {"ln1": _norm_def(cfg), "attn": attn.attn_defs(cfg),
                "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}
    raise ValueError(kind)


def block_cache_defs(cfg, kind: str, batch: int, max_seq: int):
    if kind in ("dense", "moe"):
        return attn.attn_cache_defs(cfg, batch, max_seq)
    if kind == "lattn":
        return attn.attn_cache_defs(cfg, batch, max_seq, window=cfg.window)
    if kind == "ssm":
        return ssm_mod.ssm_cache_defs(cfg, batch)
    if kind == "rec":
        return rec_mod.rglru_cache_defs(cfg, batch)
    raise ValueError(kind)


def _ffn(cfg, kind, p, h, mesh, dp_axes):
    if kind == "moe":
        y = moe_mod.moe_forward(cfg, p["moe"], h, mesh, dp_axes)
        if cfg.dense_residual:
            y = y + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_in"], p["mlp"]["w_out"])
        return y
    return swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_in"], p["mlp"]["w_out"])


def block_forward(cfg, kind, p, x, *, mesh=None, dp_axes=("data",), pos_ids=None):
    """Training-mode block. x: [B,S,d] -> [B,S,d]."""
    if kind == "ssm":
        return x + ssm_mod.mamba_forward(cfg, p["ssm"], rms_norm(x, p["ln"]))
    if kind == "rec":
        h = x + rec_mod.rglru_forward(cfg, p["rec"], rms_norm(x, p["ln1"]))
        return h + _ffn(cfg, "dense", p, rms_norm(h, p["ln2"]), mesh, dp_axes)
    window = cfg.window if kind == "lattn" else 0
    h = x + attn.attn_forward(cfg, p["attn"], rms_norm(x, p["ln1"]),
                              window=window, pos_ids=pos_ids,
                              mesh=mesh, dp=dp_axes)
    return h + _ffn(cfg, kind, p, rms_norm(h, p["ln2"]), mesh, dp_axes)


def block_prefill(cfg, kind, p, x, cache, *, mesh=None, dp_axes=("data",),
                  pos_ids=None):
    if kind == "ssm":
        y, c = ssm_mod.mamba_forward(cfg, p["ssm"], rms_norm(x, p["ln"]),
                                     return_state=True)
        return x + y, c
    if kind == "rec":
        y, c = rec_mod.rglru_forward(cfg, p["rec"], rms_norm(x, p["ln1"]),
                                     return_state=True)
        h = x + y
        return h + _ffn(cfg, "dense", p, rms_norm(h, p["ln2"]), mesh, dp_axes), c
    window = cfg.window if kind == "lattn" else 0
    y, c = attn.attn_prefill(cfg, p["attn"], rms_norm(x, p["ln1"]), cache,
                             window=window, pos_ids=pos_ids,
                             mesh=mesh, dp=dp_axes)
    h = x + y
    return h + _ffn(cfg, kind, p, rms_norm(h, p["ln2"]), mesh, dp_axes), c


def block_decode(cfg, kind, p, x, cache, pos, *, mesh=None, dp_axes=("data",),
                 pos_ids=None):
    if kind == "ssm":
        y, c = ssm_mod.mamba_decode(cfg, p["ssm"], rms_norm(x, p["ln"]), cache)
        return x + y, c
    if kind == "rec":
        y, c = rec_mod.rglru_decode(cfg, p["rec"], rms_norm(x, p["ln1"]), cache)
        h = x + y
        return h + _ffn(cfg, "dense", p, rms_norm(h, p["ln2"]), mesh, dp_axes), c
    window = cfg.window if kind == "lattn" else 0
    y, c = attn.attn_decode(cfg, p["attn"], rms_norm(x, p["ln1"]), cache, pos,
                            window=window, pos_ids=pos_ids,
                            mesh=mesh, dp=dp_axes)
    h = x + y
    return h + _ffn(cfg, kind, p, rms_norm(h, p["ln2"]), mesh, dp_axes), c

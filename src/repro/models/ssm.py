"""Mamba-1 selective SSM block (falcon-mamba-7b).

The selective scan is a *sequential loop-carried recurrence* — exactly the
class of loops the paper's framework rejects as non-parallelizable over the
group-by path (DESIGN.md §5).  We implement it TPU-natively as a chunked
diagonal linear recurrence: an outer `lax.scan` over sequence chunks (O(1)
state carry) with an inner `associative_scan` (log-depth) per chunk, so the
[B, S, d_inner, N] discretized tensors only ever materialize one chunk at a
time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, dense


def ssm_defs(cfg) -> dict[str, ParamDef]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, k = cfg.ssm_dt_rank, cfg.ssm_conv
    dt = cfg.param_dtype
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "dinner"), dt),
        "conv_w": ParamDef((k, di), ("conv", "dinner"), dt),
        "conv_b": ParamDef((di,), ("dinner",), dt, init="zeros"),
        "x_proj": ParamDef((di, dtr + 2 * n), ("dinner", "none"), dt),
        "dt_proj": ParamDef((dtr, di), ("dtrank", "dinner"), dt),
        "dt_bias": ParamDef((di,), ("dinner",), jnp.float32, init="ssm_dt"),
        "a_log": ParamDef((di, n), ("dinner", "state"), jnp.float32, init="ssm_a"),
        "d_skip": ParamDef((di,), ("dinner",), jnp.float32, init="ones"),
        "out_proj": ParamDef((di, d), ("dinner", "embed"), dt),
    }


def ssm_cache_defs(cfg, batch: int):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"conv": jax.ShapeDtypeStruct((batch, k - 1, di), cfg.cache_dtype),
            "h": jax.ShapeDtypeStruct((batch, di, n), jnp.float32)}


def _causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv over seq. x: [B,S,di]; w: [k,di]."""
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    return out + b.astype(x.dtype), xp[:, -(k - 1):] if k > 1 else pad


def _assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _ssm_params(cfg, p, x):
    """Per-step SSM tensors from conv'd activations x: [B, C, di]."""
    n, dtr = cfg.ssm_state, cfg.ssm_dt_rank
    proj = dense(x, p["x_proj"]).astype(jnp.float32)
    dt_r, bt, ct = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dense(dt_r, p["dt_proj"].astype(jnp.float32)) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                  # [di, N]
    da = jnp.exp(dt[..., None] * a)                           # [B,C,di,N]
    db_x = (dt * x.astype(jnp.float32))[..., None] * bt[..., None, :]
    return da, db_x, ct


def mamba_forward(cfg, p, x, *, h0=None, conv0=None, return_state=False):
    """x: [B,S,d] -> [B,S,d].  Chunked selective scan."""
    b, s, _ = x.shape
    di = cfg.d_inner
    xz = dense(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(xin, p["conv_w"], p["conv_b"], conv0)
    xc = jax.nn.silu(xc)

    chunk = min(cfg.scan_chunk, s)
    if s % chunk != 0:
        chunk = s  # fallback: single chunk for odd lengths
    nc = s // chunk
    xcs = xc.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)   # [nc,B,C,di]
    h_init = jnp.zeros((b, di, cfg.ssm_state), jnp.float32) if h0 is None else h0

    @jax.checkpoint
    def chunk_fn(h, xc_c):
        # rematted: backward recomputes the [B,C,di,N] discretized tensors
        # per chunk instead of saving them for the whole sequence
        da, db, ct = _ssm_params(cfg, p, xc_c)
        a_cum, b_cum = jax.lax.associative_scan(_assoc, (da, db), axis=1)
        h_all = a_cum * h[:, None] + b_cum                     # [B,C,di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h_all, ct)
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(chunk_fn, h_init, xcs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["out_proj"])
    if return_state:
        return out, {"conv": conv_tail.astype(cfg.cache_dtype), "h": h_last}
    return out


def mamba_decode(cfg, p, x, cache):
    """One-step decode. x: [B,1,d]; cache: {conv:[B,k-1,di], h:[B,di,N]}."""
    xz = dense(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)                         # [B,1,di]
    k = cfg.ssm_conv
    window = jnp.concatenate([cache["conv"].astype(xin.dtype), xin], axis=1)  # [B,k,di]
    xc = sum(window[:, i] * p["conv_w"][i].astype(xin.dtype) for i in range(k))
    xc = jax.nn.silu(xc + p["conv_b"].astype(xin.dtype))[:, None]  # [B,1,di]
    da, db, ct = _ssm_params(cfg, p, xc)
    h = da[:, 0] * cache["h"] + db[:, 0]                       # [B,di,N]
    y = jnp.einsum("bdn,bn->bd", h, ct[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None].astype(x.dtype)
    out = dense(y, p["out_proj"])
    return out, {"conv": window[:, 1:].astype(cfg.cache_dtype), "h": h}

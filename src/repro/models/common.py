"""Shared model-building machinery.

Parameters are plain nested dicts of jnp arrays. A parallel "definition
tree" of :class:`ParamDef` is the single source of truth from which we
derive (a) abstract ShapeDtypeStructs for the dry-run, (b) PartitionSpecs
for the mesh, and (c) real initialized arrays for smoke tests / training.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis -> mesh axis mapping.
#
# TP over "model", FSDP over "data".  The "pod" axis is deliberately absent:
# params are replicated across pods (only gradient all-reduce crosses DCN).
# A dim is only sharded if its size is divisible by the mesh axis size.
# ---------------------------------------------------------------------------
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),       # FSDP axis for the d_model dim
    "ff": ("model",),
    "qkv": ("model",),        # fused q/k/v output dim (heads*head_dim)
    "heads": ("model",),
    "experts": ("model",),    # expert parallelism
    "expert_ff": (),
    "dinner": ("model",),     # mamba inner dim
    "lru": ("model",),        # RG-LRU width
    "layers": (),             # stacked-layer leading axis: never sharded
    "conv": (),
    "state": (),
    "dtrank": (),
    "none": (),
}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str, ...]        # one logical axis name per dim
    dtype: Any = jnp.bfloat16
    init: str = "lecun"             # lecun | normal | zeros | ones | ssm_a | ssm_dt

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def pspec_for(d: ParamDef, axis_sizes: dict[str, int]) -> P:
    """Map logical dims to mesh axes, dropping non-divisible shardings."""
    used: set[str] = set()
    spec = []
    for size, name in zip(d.shape, d.logical):
        chosen = None
        for ax in LOGICAL_RULES.get(name, ()):
            if ax in used:
                continue
            n = axis_sizes.get(ax, 1)
            if n > 1 and size % n == 0:
                chosen = ax
                used.add(ax)
                break
        spec.append(chosen)
    return P(*spec)


def _path_key(path: tuple[str, ...]) -> int:
    h = hashlib.sha256("/".join(path).encode()).digest()
    return int.from_bytes(h[:4], "little")


def init_array(d: ParamDef, key: jax.Array) -> jax.Array:
    shape, dtype = d.shape, d.dtype
    if d.init == "zeros":
        return jnp.zeros(shape, dtype)
    if d.init == "ones":
        return jnp.ones(shape, dtype)
    if d.init == "ssm_a":  # A_log init: log(1..N) broadcast over d_inner
        n = shape[-1]
        a = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), shape)
        return a.astype(dtype)
    if d.init == "ssm_dt":  # dt bias ~ log-uniform in [1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if d.init == "normal":
        return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
    # lecun: fan_in = product of all but last dim (or last-but-one for stacks)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_abstract(defs):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
                        is_leaf=is_def)


def tree_pspecs(defs, axis_sizes):
    return jax.tree.map(lambda d: pspec_for(d, axis_sizes), defs, is_leaf=is_def)


def tree_init(defs, seed: int):
    from ..compat import tree_flatten_with_path
    leaves, treedef = tree_flatten_with_path(defs, is_leaf=is_def)
    out = []
    base = jax.random.PRNGKey(seed)
    for path, d in leaves:
        pth = tuple(str(p) for p in path)
        out.append(init_array(d, jax.random.fold_in(base, _path_key(pth))))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Basic NN ops (pure functions over param dicts)
# ---------------------------------------------------------------------------

def constrain(x, mesh, *spec):
    """with_sharding_constraint that is a no-op without a mesh and drops
    non-divisible axis entries.  Its transpose applies the same sharding to
    cotangents — this is what keeps XLA from all-gathering backward buffers."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ok(dim, entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return entry if n > 1 and x.shape[dim] % n == 0 else None

    fixed = PartitionSpec(*(ok(i, e) for i, e in enumerate(spec)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fixed))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(x, w_gate, w_in, w_out):
    h = jax.nn.silu(dense(x, w_gate)) * dense(x, w_in)
    return dense(h, w_out)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    return dense(jax.nn.gelu(dense(x, w_in, b_in)), w_out, b_out)


# ---------------------------------------------------------------------------
# RoPE (+ the M-RoPE variant used by qwen2-vl; position ids are a stub input)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; pos: broadcastable to [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # [hd/2]
    angles = pos.astype(jnp.float32)[..., None] * freqs     # [..., S, hd/2]
    angles = angles[..., None, :]                           # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """M-RoPE: head_dim/2 split into len(sections) position streams.

    x: [B, S, H, hd]; pos3: [B, S, 3] (temporal/height/width — stub input).
    """
    import numpy as np
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # [hd/2]
    # choose which of the 3 position streams each frequency uses (static)
    sec_id = jnp.asarray(np.repeat(np.arange(len(sections)), np.array(sections)))
    pos = jnp.take_along_axis(
        pos3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], pos3.shape[:2] + (hd // 2,)).astype(jnp.int32),
        axis=-1) if pos3.shape[-1] == 3 else pos3.astype(jnp.float32)
    angles = pos[..., None, :]                              # [B, S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)

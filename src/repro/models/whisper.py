"""Whisper-style encoder-decoder backbone (whisper-tiny).

Per the assignment the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings [B, enc_seq, d] from input_specs() (no mel
conv stack).  Sinusoidal positions on both sides (adaptation noted in the
config docstring).  Decoder layers: causal self-attn -> cross-attn -> MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from .common import ParamDef, constrain, dense, is_def, rms_norm, \
    tree_abstract, tree_init, tree_pspecs


def _sinusoid(seq: int, d: int, offset=0):
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2) / d)
    ang = pos * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _norm_def(cfg):
    return ParamDef((cfg.d_model,), ("embed",), jnp.float32, init="zeros")


def _mlp_defs(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype
    return {"w_in": ParamDef((d, ff), ("embed", "ff"), dt),
            "b_in": ParamDef((ff,), ("ff",), dt, init="zeros"),
            "w_out": ParamDef((ff, d), ("ff", "embed"), dt),
            "b_out": ParamDef((d,), ("embed",), dt, init="zeros")}


def _enc_layer_defs(cfg):
    return {"ln1": _norm_def(cfg), "attn": attn.attn_defs(cfg),
            "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}


def _dec_layer_defs(cfg):
    return {"ln1": _norm_def(cfg), "self": attn.attn_defs(cfg),
            "ln2": _norm_def(cfg), "cross": attn.attn_defs(cfg),
            "ln3": _norm_def(cfg), "mlp": _mlp_defs(cfg)}


def _mlp(cfg, p, x):
    return dense(jax.nn.gelu(dense(x, p["w_in"], p["b_in"])), p["w_out"], p["b_out"])


def _stack(defs, reps):
    return jax.tree.map(lambda d: ParamDef((reps,) + d.shape,
                                           ("layers",) + d.logical, d.dtype, d.init),
                        defs, is_leaf=is_def)


class Whisper:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.pos_embed == "sinusoidal"
        self.dec_layers = sum(len(p) * r for p, r in cfg.layout)

    def defs(self):
        cfg = self.cfg
        return {
            "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              cfg.param_dtype, init="normal"),
            "enc": _stack(_enc_layer_defs(cfg), cfg.enc_layers),
            "dec": _stack(_dec_layer_defs(cfg), self.dec_layers),
            "enc_norm": _norm_def(cfg),
            "final_norm": _norm_def(cfg),
            "lm_head": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                                cfg.param_dtype),
        }

    def abstract_params(self):
        return tree_abstract(self.defs())

    def pspecs(self, axis_sizes):
        return tree_pspecs(self.defs(), axis_sizes)

    def init(self, seed: int = 0):
        return tree_init(self.defs(), seed)

    # -------------- encoder --------------
    def encode(self, params, frames, mesh=None, dp_axes=("data",)):
        """frames: [B, S_enc, d] (stub embeddings)."""
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, mesh, dp_axes, None, None)

        def body(h, p):
            h = h + attn.attn_forward(cfg, p["attn"], rms_norm(h, p["ln1"]),
                                      causal=False, mesh=mesh, dp=dp_axes)
            h = h + _mlp(cfg, p["mlp"], rms_norm(h, p["ln2"]))
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
        return rms_norm(x, params["enc_norm"])

    # -------------- decoder --------------
    def _dec_embed(self, params, tokens, offset=0):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
        return x + _sinusoid(tokens.shape[1], cfg.d_model, offset).astype(x.dtype)[None]

    def _head(self, params, x, mesh=None, dp_axes=("data",)):
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"]).astype(jnp.float32)
        return constrain(logits, mesh, dp_axes, None, "model")

    def loss(self, params, batch, mesh=None, dp_axes=("data",)):
        """batch: {frames:[B,Se,d], tokens:[B,S], labels:[B,S]}."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], mesh, dp_axes)
        x = self._dec_embed(params, batch["tokens"])
        x = constrain(x, mesh, dp_axes, None, None)

        def body(h, p):
            h = h + attn.attn_forward(cfg, p["self"], rms_norm(h, p["ln1"]),
                                      causal=True, mesh=mesh, dp=dp_axes)
            kv = attn.cross_kv(cfg, p["cross"], enc)
            h = h + attn.cross_attn_forward(cfg, p["cross"], rms_norm(h, p["ln2"]),
                                            kv, mesh, dp_axes)
            h = h + _mlp(cfg, p["mlp"], rms_norm(h, p["ln3"]))
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
        from .lm import chunked_ce
        loss = chunked_ce(cfg, lambda xc: self._head(params, xc, mesh, dp_axes),
                          x, batch["labels"])
        return loss, {"loss": loss}

    # -------------- serving --------------
    def cache_defs(self, batch, max_seq):
        cfg = self.cfg
        self_kv = attn.attn_cache_defs(cfg, batch, max_seq)
        cross_shape = (self.dec_layers, batch, cfg.enc_seq, cfg.num_kv_heads,
                       cfg.head_dim)
        return {
            "self": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.dec_layers,) + s.shape, s.dtype),
                self_kv),
            "cross_k": jax.ShapeDtypeStruct(cross_shape, cfg.cache_dtype),
            "cross_v": jax.ShapeDtypeStruct(cross_shape, cfg.cache_dtype),
        }

    def init_cache(self, batch, max_seq):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_defs(batch, max_seq))

    def cache_pspecs(self, cache_tree, axis_sizes, dp_axes=("data",)):
        model_n = axis_sizes.get("model", 1)

        def spec(leaf):
            seq_ok = model_n > 1 and leaf.shape[2] % model_n == 0
            return P(None, dp_axes, "model" if seq_ok else None, None, None)
        return jax.tree.map(spec, cache_tree)

    def prefill(self, params, frames, tokens, max_seq, mesh=None,
                dp_axes=("data",), pos_ids=None):
        cfg = self.cfg
        b = tokens.shape[0]
        enc = self.encode(params, frames, mesh, dp_axes)
        cache = self.init_cache(b, max_seq)
        x = self._dec_embed(params, tokens)

        def body(h, xs):
            p, sc = xs
            y, new_sc = attn.attn_prefill(cfg, p["self"], rms_norm(h, p["ln1"]),
                                          sc, mesh=mesh, dp=dp_axes)
            h = h + y
            kv = attn.cross_kv(cfg, p["cross"], enc)
            h = h + attn.cross_attn_forward(cfg, p["cross"], rms_norm(h, p["ln2"]),
                                            kv, mesh, dp_axes)
            h = h + _mlp(cfg, p["mlp"], rms_norm(h, p["ln3"]))
            return h, (new_sc, kv[0].astype(cfg.cache_dtype),
                       kv[1].astype(cfg.cache_dtype))

        x, (self_c, ck, cv) = jax.lax.scan(jax.checkpoint(body), x,
                                           (params["dec"], cache["self"]))
        logits = self._head(params, x[:, -1:], mesh, dp_axes)[:, 0]
        return logits, {"self": self_c, "cross_k": ck, "cross_v": cv}

    def decode(self, params, cache, token, pos, mesh=None, dp_axes=("data",),
               pos_ids=None):
        cfg = self.cfg
        x = self._dec_embed(params, token, offset=pos)

        def body(h, xs):
            p, sc, ck, cv = xs
            y, new_sc = attn.attn_decode(cfg, p["self"], rms_norm(h, p["ln1"]),
                                         sc, pos, mesh=mesh, dp=dp_axes)
            h = h + y
            h = h + attn.cross_attn_forward(
                cfg, p["cross"], rms_norm(h, p["ln2"]),
                (ck.astype(cfg.compute_dtype), cv.astype(cfg.compute_dtype)),
                mesh, dp_axes)
            h = h + _mlp(cfg, p["mlp"], rms_norm(h, p["ln3"]))
            return h, new_sc

        x, self_c = jax.lax.scan(body, x, (params["dec"], cache["self"],
                                           cache["cross_k"], cache["cross_v"]))
        logits = self._head(params, x, mesh, dp_axes)[:, 0]
        return logits, {"self": self_c, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}

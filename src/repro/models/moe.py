"""Mixture-of-Experts layer with expert parallelism.

This is where the paper's technique is a first-class feature of the LM
framework: the MoE combine step is literally the paper's incremental-update
pattern

    for a in assignments:  Y[token(a)] += weight(a) * expert_out(a)

i.e. a *group-by destination index + commutative ⊕-reduction* (paper §3.7),
lowered to a segment-reduce (scatter-add).  The dispatch step is the dual
(group tokens by routed expert).  Three execution modes:

* ``local``        — single device / no mesh: sort-by-expert + ragged_dot.
* ``ep_alltoall``  — tokens sequence-sharded over the `model` axis; a
                     capacity-bounded all_to_all moves tokens to their
                     expert's shard and back (shard_map).  Used for
                     train/prefill shapes.
* ``ep_local``     — decode (S too small to shard): tokens replicated over
                     `model`; each shard computes only its local experts and
                     the combine is a psum.  No all_to_all.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .common import ParamDef, dense


def moe_defs(cfg) -> dict[str, ParamDef]:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = cfg.param_dtype
    dm = "embed" if cfg.fsdp_experts else "none"  # FSDP d_model dim or not
    return {
        "router": ParamDef((d, e), ("embed", "none"), dt),
        "w_gate": ParamDef((e, d, ff), ("experts", dm, "expert_ff"), dt),
        "w_in": ParamDef((e, d, ff), ("experts", dm, "expert_ff"), dt),
        "w_out": ParamDef((e, ff, d), ("experts", "expert_ff", dm), dt),
    }


def _router(cfg, p, xt):
    """xt: [T,d] -> (weights [T,k], experts [T,k]) with normalized weights."""
    logits = dense(xt, p["router"]).astype(jnp.float32)
    gw, ge = jax.lax.top_k(logits, cfg.top_k)
    gw = jax.nn.softmax(gw, axis=-1)
    return gw, ge


def _padded_expert_pass(xt_flat, eloc, valid, n_experts, cap_e,
                        w_gate, w_in, w_out):
    """Expert-major padded-buffer grouped matmul (the TPU-native MoE form).

    Rows are scattered into a static [E, cap_e, d] buffer by (expert,
    rank-within-expert) — rank via the paper's group-by cumsum pattern —
    then all experts run as ONE block einsum with zero dense waste beyond
    the capacity padding.  Overflow rows are dropped (capacity semantics).
    Returns per-row outputs gathered back ([N, d]) with dropped rows zero.
    """
    n, d = xt_flat.shape
    onehot = (eloc[:, None] == jnp.arange(n_experts)[None]).astype(jnp.int32)
    onehot = onehot * valid[:, None].astype(jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                               eloc[:, None], axis=1)[:, 0]
    keep = (rank < cap_e) & valid
    slot = jnp.where(keep, rank, cap_e)
    buf = jnp.zeros((n_experts, cap_e + 1, d), xt_flat.dtype) \
        .at[eloc, slot].set(xt_flat)[:, :cap_e]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_in)
    y = jnp.einsum("ecf,efd->ecd", h, w_out)
    out = y[eloc, jnp.where(keep, rank, 0)]
    return out * keep[:, None].astype(out.dtype)


def _cap_e(n_rows: int, n_experts: int, cf: float) -> int:
    cap = math.ceil(n_rows / n_experts * cf)
    return max(8, -(-cap // 8) * 8)


def segment_add(values, segment_ids, num_segments):
    """The paper's group-by-⊕ combine (scatter-add).  jnp path; the Pallas
    one-hot-MXU kernel in repro.kernels.segment_reduce implements the same
    contract for TPU hot loops."""
    return jnp.zeros((num_segments,) + values.shape[1:], values.dtype) \
        .at[segment_ids].add(values)


# ---------------------------------------------------------------------------
# local (single-shard) path
# ---------------------------------------------------------------------------

def moe_local(cfg, p, x):
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    gw, ge = _router(cfg, p, xt)
    k = cfg.top_k
    flat_e = ge.reshape(t * k)
    flat_w = gw.reshape(t * k)
    src = jnp.repeat(jnp.arange(t), k)
    cap_e = _cap_e(t * k, cfg.num_experts, cfg.capacity_factor)
    ys = _padded_expert_pass(jnp.take(xt, src, axis=0), flat_e,
                             jnp.ones((t * k,), bool), cfg.num_experts, cap_e,
                             p["w_gate"], p["w_in"], p["w_out"])
    y = segment_add(ys * flat_w[:, None].astype(ys.dtype), src, t)
    return y.reshape(b, s, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# expert-parallel paths (shard_map over the mesh)
# ---------------------------------------------------------------------------

def _capacity(tokens_per_shard: int, top_k: int, n_shards: int, cf: float) -> int:
    cap = math.ceil(tokens_per_shard * top_k / n_shards * cf)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def moe_ep(cfg, p, x, mesh, dp_axes: tuple[str, ...]):
    """Dispatch to the right EP mode based on static shapes."""
    model_n = mesh.shape["model"]
    if model_n == 1:
        return moe_local(cfg, p, x)
    b, s, d = x.shape
    dp_n = 1
    for a in dp_axes:
        dp_n *= mesh.shape[a]
    if s % model_n == 0 and (b % dp_n == 0) and (b // dp_n) * (s // model_n) >= 64:
        return _moe_ep_alltoall(cfg, p, x, mesh, dp_axes)
    return _moe_ep_localexperts(cfg, p, x, mesh, dp_axes)


def _moe_ep_alltoall(cfg, p, x, mesh, dp_axes):
    m = mesh.shape["model"]
    e_loc = cfg.num_experts // m
    b, s, d = x.shape
    dp_n = 1
    for a in dp_axes:
        dp_n *= mesh.shape[a]
    t_loc = (b // dp_n) * (s // m)
    cap = _capacity(t_loc, cfg.top_k, m, cfg.capacity_factor)
    k = cfg.top_k

    def local_fn(router_w, w_gate, w_in, w_out, x_loc):
        if cfg.fsdp_experts:
            # FSDP: gather the d_model shards of the local experts' weights
            w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
            w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)

        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        gw, ge = _router(cfg, {"router": router_w}, xt)
        flat_e = ge.reshape(t * k)
        flat_w = gw.reshape(t * k)
        src = jnp.repeat(jnp.arange(t), k)
        dest = flat_e // e_loc                                  # [t*k] shard id
        onehot = (dest[:, None] == jnp.arange(m)[None]).astype(jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                  dest[:, None], axis=1)[:, 0]
        keep = pos < cap
        pos_safe = jnp.where(keep, pos, cap)                    # overflow slot

        send_x = jnp.zeros((m, cap + 1, d), xt.dtype).at[dest, pos_safe].set(
            jnp.take(xt, src, axis=0))[:, :cap]
        send_el = jnp.zeros((m, cap + 1), jnp.int32).at[dest, pos_safe].set(
            flat_e % e_loc)[:, :cap]
        send_ok = jnp.zeros((m, cap + 1), jnp.bool_).at[dest, pos_safe].set(
            keep)[:, :cap]

        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0)      # [m,cap,d]
        recv_el = jax.lax.all_to_all(send_el, "model", 0, 0)
        recv_ok = jax.lax.all_to_all(send_ok, "model", 0, 0)

        flat_x = recv_x.reshape(m * cap, d)
        eloc = jnp.where(recv_ok.reshape(m * cap), recv_el.reshape(m * cap), 0)
        cap_e = _cap_e(m * cap, e_loc, cfg.capacity_factor)
        ys = _padded_expert_pass(flat_x, eloc, recv_ok.reshape(m * cap),
                                 e_loc, cap_e, w_gate, w_in, w_out)

        back = jax.lax.all_to_all(ys.reshape(m, cap, d), "model", 0, 0)
        gathered = back[dest, pos_safe.clip(0, cap - 1)]
        contrib = gathered * (flat_w * keep)[:, None].astype(gathered.dtype)
        y = segment_add(contrib, src, t)                        # paper group-by
        return y.reshape(bl, sl, d).astype(x_loc.dtype)

    dp = tuple(dp_axes)
    wdm = "data" if cfg.fsdp_experts else None
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), P("model", wdm, None), P("model", wdm, None),
                  P("model", None, wdm), P(dp, "model", None)),
        out_specs=P(dp, "model", None))
    return fn(p["router"], p["w_gate"], p["w_in"], p["w_out"], x)


def _moe_ep_localexperts(cfg, p, x, mesh, dp_axes):
    """Decode-friendly EP: tokens replicated over `model`; each shard runs
    its local experts on the tokens routed to it; combine via psum."""
    m = mesh.shape["model"]
    e_loc = cfg.num_experts // m
    b, s, d = x.shape
    k = cfg.top_k

    def local_fn(router_w, w_gate, w_in, w_out, x_loc):
        if cfg.fsdp_experts:
            w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
            w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)
        my = jax.lax.axis_index("model")
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        gw, ge = _router(cfg, {"router": router_w}, xt)
        flat_e = ge.reshape(t * k)
        flat_w = gw.reshape(t * k)
        src = jnp.repeat(jnp.arange(t), k)
        mine = (flat_e // e_loc) == my
        xin = jnp.take(xt, src, axis=0)
        eloc = jnp.where(mine, flat_e % e_loc, 0)
        cap_e = _cap_e(t * k, cfg.num_experts, cfg.capacity_factor)
        ys = _padded_expert_pass(xin, eloc, mine, e_loc, cap_e,
                                 w_gate, w_in, w_out)
        contrib = ys * (flat_w * mine)[:, None].astype(ys.dtype)
        y = segment_add(contrib, src, t)
        y = jax.lax.psum(y, "model")
        return y.reshape(bl, sl, d).astype(x_loc.dtype)

    dp = tuple(dp_axes)
    wdm = "data" if cfg.fsdp_experts else None
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), P("model", wdm, None), P("model", wdm, None),
                  P("model", None, wdm), P(dp, None, None)),
        out_specs=P(dp, None, None))
    return fn(p["router"], p["w_gate"], p["w_in"], p["w_out"], x)


def moe_forward(cfg, p, x, mesh=None, dp_axes=("data",)):
    if mesh is None:
        return moe_local(cfg, p, x)
    return moe_ep(cfg, p, x, mesh, dp_axes)

"""Decoder-only LM: embedding -> scanned layer groups -> head.

Layers are stacked per `layout` group and iterated with `lax.scan` (one
compiled body per group) so 80-layer models compile in one-layer time.
Remat (activation checkpointing) wraps the scan body; policy set by
cfg.remat ("full" | "dots" | "none").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .blocks import block_cache_defs, block_decode, block_defs, block_forward, \
    block_prefill
from .common import ParamDef, constrain, is_def, rms_norm, tree_abstract, \
    tree_init, tree_pspecs


def _stack(defs, reps: int):
    return jax.tree.map(
        lambda d: ParamDef((reps,) + d.shape, ("layers",) + d.logical,
                           d.dtype, d.init),
        defs, is_leaf=is_def)


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def chunked_ce(cfg, head_fn, x, labels):
    """Fused cross-entropy: scan over sequence chunks, rematerializing the
    [B, chunk, V] logits in backward instead of saving [B, S, V] fp32 (the
    dominant memory term for big-vocab / unshardable-vocab models)."""
    b, s, _ = x.shape
    chunk = min(cfg.ce_chunk, s)
    if s % chunk != 0 or s == chunk:
        logits = head_fn(x)
        ls = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(ls - true)
    nc = s // chunk
    xs = x.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    ys = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, args):
        xc, yc = args
        logits = head_fn(xc)
        ls = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((ls - true).astype(jnp.float32)), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ys))
    return total / (b * s)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def residual_spec(cfg):
    """Sharding of the inter-block residual stream [B, S, d].

    Attention-family archs: sequence-parallel (Megatron-SP) — the remat-saved
    per-layer carries shrink by the TP degree.  SSM/hybrid: the recurrence
    runs over S, so shard the channel dim instead (d_model is elementwise
    through the scan).  `constrain` drops non-divisible entries (decode S=1).
    """
    if cfg.family in ("ssm", "hybrid"):
        return (None, "model")
    return ("model", None)


class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------- params ----------------
    def defs(self):
        cfg = self.cfg
        embed_logical = ("vocab", "embed") if cfg.shard_embed_vocab \
            else ("none", "embed")
        d = {"embed": ParamDef((cfg.vocab_size, cfg.d_model), embed_logical,
                               cfg.param_dtype, init="normal"),
             "final_norm": ParamDef((cfg.d_model,), ("embed",), jnp.float32,
                                    init="zeros"),
             "lm_head": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                                 cfg.param_dtype)}
        for gi, (pattern, reps) in enumerate(cfg.layout):
            d[f"g{gi}"] = {f"s{i}_{kind}": _stack(block_defs(cfg, kind), reps)
                           for i, kind in enumerate(pattern)}
        return d

    def abstract_params(self):
        return tree_abstract(self.defs())

    def pspecs(self, axis_sizes):
        return tree_pspecs(self.defs(), axis_sizes)

    def init(self, seed: int = 0):
        return tree_init(self.defs(), seed)

    # ---------------- caches ----------------
    def cache_defs(self, batch: int, max_seq: int):
        cfg = self.cfg
        caches = {}
        for gi, (pattern, reps) in enumerate(cfg.layout):
            caches[f"g{gi}"] = {
                f"s{i}_{kind}": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype),
                    block_cache_defs(cfg, kind, batch, max_seq))
                for i, kind in enumerate(pattern)}
        return caches

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_defs(batch, max_seq))

    def cache_pspecs(self, cache_tree, axis_sizes, dp_axes=("data",)):
        """Cache sharding for a concrete (abstract) cache tree: batch over
        dp; global-attn KV *sequence* over `model` (GQA kv-head counts don't
        divide 16-way TP); recurrent state over `model` on the channel dim.
        Shapes are [layers, batch, ...] (stacked for the group scans)."""
        model_n = axis_sizes.get("model", 1)
        dp_n = 1
        for a in (dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)):
            dp_n *= axis_sizes.get(a, 1)

        def spec_for(leaf_name, shape):
            dp = dp_axes if shape[1] % dp_n == 0 and dp_n > 1 else None
            def m(dim):
                return "model" if model_n > 1 and shape[dim] % model_n == 0 else None
            if leaf_name in ("k", "v"):           # [L, B, S, Hkv, hd]
                return P(None, dp, m(2), None, None)
            if leaf_name == "conv":               # [L, B, k-1, ch]
                return P(None, dp, None, m(3))
            if leaf_name == "h":                  # [L,B,ch] or [L,B,ch,N]
                base = (None, dp, m(2))
                return P(*base) if len(shape) == 3 else P(*base, None)
            return P()

        def walk_named(tree):
            res = {}
            for k, v in tree.items():
                if isinstance(v, dict):
                    res[k] = walk_named(v)
                else:
                    res[k] = spec_for(k, v.shape)
            return res
        return walk_named(cache_tree)

    # ---------------- backbone ----------------
    def _embed(self, params, tokens, mesh, dp_axes):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
        return _constrain(x, mesh, P(dp_axes, None, None))

    def _head(self, params, x, mesh=None, dp_axes=("data",)):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"]).astype(jnp.float32)
        # pin batch to dp / vocab to model — the transpose of this constraint
        # stops GSPMD from all-gathering the logits cotangent over batch
        logits = constrain(logits, mesh, dp_axes, None, "model")
        if cfg.logits_softcap:
            c = cfg.logits_softcap
            logits = c * jnp.tanh(logits / c)
        return logits

    def _forward(self, params, x, mesh, dp_axes, pos_ids):
        cfg = self.cfg
        for gi, (pattern, reps) in enumerate(cfg.layout):
            gp = params[f"g{gi}"]

            def body(carry, ps, _pattern=pattern):
                h = constrain(carry, mesh, dp_axes, *residual_spec(cfg))
                for i, kind in enumerate(_pattern):
                    h = block_forward(cfg, kind, ps[f"s{i}_{kind}"], h,
                                      mesh=mesh, dp_axes=dp_axes, pos_ids=pos_ids)
                return constrain(h, mesh, dp_axes, *residual_spec(cfg)), None

            x, _ = jax.lax.scan(_remat(cfg, body), x, gp)
        return x

    # ---------------- public entry points ----------------
    def loss(self, params, batch, mesh=None, dp_axes=("data",)):
        """batch: {tokens:[B,S], labels:[B,S], (pos_ids:[B,S,3])}."""
        x = self._embed(params, batch["tokens"], mesh, dp_axes)
        x = self._forward(params, x, mesh, dp_axes, batch.get("pos_ids"))
        loss = chunked_ce(self.cfg, lambda xc: self._head(params, xc, mesh, dp_axes),
                          x, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, params, tokens, max_seq, mesh=None, dp_axes=("data",),
                pos_ids=None):
        """Returns (last-token logits [B,V], filled cache)."""
        cfg = self.cfg
        b, s = tokens.shape
        cache = self.init_cache(b, max_seq)
        x = self._embed(params, tokens, mesh, dp_axes)
        for gi, (pattern, reps) in enumerate(cfg.layout):
            gp = params[f"g{gi}"]
            gc = cache[f"g{gi}"]

            def body(carry, xs, _pattern=pattern):
                h = constrain(carry, mesh, dp_axes, *residual_spec(cfg))
                ps, cs = xs
                new_cs = {}
                for i, kind in enumerate(_pattern):
                    key = f"s{i}_{kind}"
                    h, new_cs[key] = block_prefill(
                        cfg, kind, ps[key], h, cs[key],
                        mesh=mesh, dp_axes=dp_axes, pos_ids=pos_ids)
                return constrain(h, mesh, dp_axes, *residual_spec(cfg)), new_cs

            x, cache[f"g{gi}"] = jax.lax.scan(_remat(cfg, body), x, (gp, gc))
        logits = self._head(params, x[:, -1:], mesh, dp_axes)[:, 0]
        return logits, cache

    def decode(self, params, cache, token, pos, mesh=None, dp_axes=("data",),
               pos_ids=None):
        """One decode step. token: [B,1]; pos: scalar int32 (# tokens so far).
        Returns (logits [B,V], new cache)."""
        cfg = self.cfg
        x = self._embed(params, token, mesh, dp_axes)
        new_cache = {}
        for gi, (pattern, reps) in enumerate(cfg.layout):
            gp = params[f"g{gi}"]
            gc = cache[f"g{gi}"]

            def body(carry, xs, _pattern=pattern):
                h = carry
                ps, cs = xs
                new_cs = {}
                for i, kind in enumerate(_pattern):
                    key = f"s{i}_{kind}"
                    h, new_cs[key] = block_decode(
                        cfg, kind, ps[key], h, cs[key], pos,
                        mesh=mesh, dp_axes=dp_axes, pos_ids=pos_ids)
                return h, new_cs

            x, new_cache[f"g{gi}"] = jax.lax.scan(body, x, (gp, gc))
        logits = self._head(params, x, mesh, dp_axes)[:, 0]
        return logits, new_cache

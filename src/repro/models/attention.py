"""GQA attention: global-causal, local-window (sliding), bidirectional
(encoder) and cross-attention variants, with chunked (flash-style, O(chunk)
memory) computation for long sequences and ring-buffer caches for local
attention so `long_500k` decode stays O(window).

TP strategy: KV heads are repeated to the full query-head count before the
score einsum, so the head dim shards cleanly at 16-way TP even when
num_kv_heads < 16 (each shard effectively holds a KV-head replica — the
standard GQA + wide-TP layout).  Explicit sharding constraints pin batch to
the dp axes and heads to `model`; their transposes pin the backward
cotangents, which otherwise get all-gathered by GSPMD (observed: a 217 GB
logits gather on whisper before these constraints).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, apply_mrope, apply_rope, constrain, dense

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def attn_defs(cfg, *, cross: bool = False) -> dict[str, ParamDef]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    defs = {
        "wq": ParamDef((d, hq * hd), ("embed", "qkv"), dt),
        "wk": ParamDef((d, hkv * hd), ("embed", "qkv"), dt),
        "wv": ParamDef((d, hkv * hd), ("embed", "qkv"), dt),
        "wo": ParamDef((hq * hd, d), ("qkv", "embed"), dt),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((hq * hd,), ("qkv",), dt, init="zeros")
        defs["bk"] = ParamDef((hkv * hd,), ("qkv",), dt, init="zeros")
        defs["bv"] = ParamDef((hkv * hd,), ("qkv",), dt, init="zeros")
    return defs


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _scores_mask(q_pos, k_pos, *, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _repeat_kv(k, v, hq):
    g = hq // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return k, v


def _msize(mesh) -> int:
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def _attend(q, k, v, q_pos, k_pos, *, causal, window, mesh=None, dp=("data",), sp=True):
    """q: [B,Sq,Hq,hd]; k,v: [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd].  fp32 softmax.

    TP layout: scores shard over heads when Hq divides the model axis;
    otherwise over the QUERY-SEQUENCE dim (SP attention) — without this,
    archs whose head count doesn't divide 16 (minitron 24H, phi3 40H,
    whisper 6H) run attention 16x redundantly on the model axis (measured:
    75% of minitron's train flops; see EXPERIMENTS.md §Perf)."""
    b, sq, hq, hd = q.shape
    k, v = _repeat_kv(k, v, hq)
    heads_tp = hq % _msize(mesh) == 0 or not sp
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    if heads_tp:
        scores = constrain(scores * (hd ** -0.5), mesh, dp, "model", None, None)
    else:
        scores = constrain(scores * (hd ** -0.5), mesh, dp, None, "model", None)
    mask = _scores_mask(q_pos, k_pos, causal=causal, window=window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    if heads_tp:
        return constrain(out, mesh, dp, None, "model", None)
    return constrain(out, mesh, dp, "model", None, None)


def attention_core(q, k, v, *, causal=True, window=0, q_offset=0,
                   chunk_q=1024, mesh=None, dp=("data",), sp=True):
    """Full-sequence attention; scans over query chunks when Sq is large.

    For local-window attention the kv tensor is sliced per chunk so both
    memory AND flops are O(S * window) — genuinely sub-quadratic.
    """
    b, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    if sq <= chunk_q or sq % chunk_q != 0:
        return _attend(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       mesh=mesh, dp=dp, sp=sp)

    n_chunks = sq // chunk_q
    qc = q.reshape(b, n_chunks, chunk_q, *q.shape[2:]).transpose(1, 0, 2, 3, 4)

    if window > 0 and window + chunk_q < sk:
        span = window + chunk_q  # kv span each query chunk can see

        def chunk_fn(_, args):
            i, qi = args
            start = jnp.maximum(i * chunk_q - window, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            qp = q_offset + i * chunk_q + jnp.arange(chunk_q)
            kp = start + jnp.arange(span)
            return None, _attend(qi, ks, vs, qp, kp, causal=causal,
                                 window=window, mesh=mesh, dp=dp, sp=sp)
    else:
        def chunk_fn(_, args):
            i, qi = args
            qp = q_offset + i * chunk_q + jnp.arange(chunk_q)
            return None, _attend(qi, k, v, qp, k_pos, causal=causal,
                                 window=window, mesh=mesh, dp=dp, sp=sp)

    _, out = jax.lax.scan(chunk_fn, None, (jnp.arange(n_chunks), qc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq, *q.shape[2:])
    return out


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x, mesh, dp):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, hq, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, hkv, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, hkv, hd)
    if hq % _msize(mesh) == 0 or not cfg.sp_attn:
        q = constrain(q, mesh, dp, None, "model", None)
    else:  # SP fallback: shard the sequence dim instead of heads
        q = constrain(q, mesh, dp, "model", None, None)
    k = constrain(k, mesh, dp, None, "model", None)
    v = constrain(v, mesh, dp, None, "model", None)
    return q, k, v


def _rope(cfg, q, k, pos, pos_ids):
    if cfg.pos_embed != "rope":
        return q, k
    if cfg.mrope_sections and pos_ids is not None:
        q = apply_mrope(q, pos_ids, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos_ids, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def attn_cache_defs(cfg, batch: int, max_seq: int, *, window: int = 0):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    s = min(window, max_seq) if window > 0 else max_seq
    shp = (batch, s, hkv, hd)
    return {"k": jax.ShapeDtypeStruct(shp, cfg.cache_dtype),
            "v": jax.ShapeDtypeStruct(shp, cfg.cache_dtype)}


def attn_forward(cfg, p, x, *, window=0, causal=True, pos_ids=None,
                 mesh=None, dp=("data",)):
    """Training / encoder forward (no cache). x: [B,S,d]."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, mesh, dp)
    pos = jnp.arange(s)
    q, k = _rope(cfg, q, k, pos, pos_ids)
    out = attention_core(q, k, v, causal=causal, window=window,
                         chunk_q=cfg.attn_chunk, mesh=mesh, dp=dp,
                         sp=cfg.sp_attn)
    return dense(out.reshape(b, s, -1), p["wo"])


def attn_prefill(cfg, p, x, cache, *, window=0, pos_ids=None, mesh=None,
                 dp=("data",)):
    """Prefill: run causal attention AND fill the cache. Returns (y, cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, mesh, dp)
    pos = jnp.arange(s)
    q, k = _rope(cfg, q, k, pos, pos_ids)
    out = attention_core(q, k, v, causal=True, window=window,
                         chunk_q=cfg.attn_chunk, mesh=mesh, dp=dp,
                         sp=cfg.sp_attn)
    w = cache["k"].shape[1]
    if window > 0 and w < s:          # ring buffer keeps the last `w` steps
        new_cache = {"k": k[:, s - w:].astype(cache["k"].dtype),
                     "v": v[:, s - w:].astype(cache["v"].dtype)}
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros_like(cache["k"]), k.astype(cache["k"].dtype), 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros_like(cache["v"]), v.astype(cache["v"].dtype), 0, axis=1)
        new_cache = {"k": kc, "v": vc}
    return dense(out.reshape(b, s, -1), p["wo"]), new_cache


def attn_decode(cfg, p, x, cache, pos, *, window=0, pos_ids=None, mesh=None,
                dp=("data",)):
    """One-token decode. x: [B,1,d]; pos: scalar int32 (tokens so far).

    Global attention: cache [B, S_max, Hkv, hd], seq-sharded over `model`
    (baseline; the flash-combine shard_map variant is the perf hillclimb).
    Local attention: ring buffer [B, W, Hkv, hd] indexed pos % W.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, 1, hq, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, 1, hkv, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, 1, hkv, hd)
    q, k = _rope(cfg, q, k, pos[None] if pos.ndim == 0 else pos, pos_ids)

    cap = cache["k"].shape[1]
    slot = jnp.mod(pos, cap) if window > 0 else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    kc = constrain(kc, mesh, dp, "model", None, None)
    vc = constrain(vc, mesh, dp, "model", None, None)

    idx = jnp.arange(cap)
    if window > 0:
        age = jnp.mod(slot - idx, cap)          # 0 = current token
        k_abs = pos - age
        valid = (k_abs >= 0) & (age < jnp.minimum(window, cap))
    else:
        valid = idx <= pos
    kf, vf = _repeat_kv(kc.astype(q.dtype), vc.astype(q.dtype), hq)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, kf).astype(jnp.float32)
    # keep scores SEQUENCE-sharded: softmax over the sharded axis then
    # reduces to scalar-sized all-reduces (flash-combine), instead of
    # all-gathering the multi-GB KV cache to shard by heads
    scores = constrain(scores * (hd ** -0.5), mesh, dp, None, None, "model")
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, vf).reshape(b, 1, hq * hd)
    return dense(out.astype(x.dtype), p["wo"]), {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_forward(cfg, p, x, enc_kv, mesh=None, dp=("data",)):
    """x: [B,S,d]; enc_kv: (k, v) precomputed from encoder output."""
    b, s, _ = x.shape
    hq, hd = cfg.num_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, hq, hd)
    q = constrain(q, mesh, dp, None, "model", None)
    k, v = enc_kv
    out = attention_core(q, k, v, causal=False, chunk_q=cfg.attn_chunk,
                         mesh=mesh, dp=dp)
    return dense(out.reshape(b, s, -1), p["wo"])


def cross_kv(cfg, p, enc_out):
    b, s, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = dense(enc_out, p["wk"], p.get("bk")).reshape(b, s, hkv, hd)
    v = dense(enc_out, p["wv"], p.get("bv")).reshape(b, s, hkv, hd)
    return k, v

"""RG-LRU recurrent block (recurrentgemma-2b).

Same chunked diagonal-linear-recurrence treatment as the Mamba block (see
ssm.py) — the recurrence is sequential and sits outside the paper's
group-by machinery.  Gate projections are dense [w,w] (the reference model
uses block-diagonal heads; dense is a superset — noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, dense
from .ssm import _assoc, _causal_conv

_C = 8.0  # RG-LRU exponent scale


def rglru_defs(cfg) -> dict[str, ParamDef]:
    d, w, k = cfg.d_model, cfg.lru_width, cfg.ssm_conv
    dt = cfg.param_dtype
    return {
        "in_x": ParamDef((d, w), ("embed", "lru"), dt),
        "in_y": ParamDef((d, w), ("embed", "lru"), dt),
        "conv_w": ParamDef((k, w), ("conv", "lru"), dt),
        "conv_b": ParamDef((w,), ("lru",), dt, init="zeros"),
        "gate_a": ParamDef((w, w), ("lru", "none"), dt),
        "gate_x": ParamDef((w, w), ("lru", "none"), dt),
        "lam": ParamDef((w,), ("lru",), jnp.float32, init="ones"),
        "out": ParamDef((w, d), ("lru", "embed"), dt),
    }


def rglru_cache_defs(cfg, batch: int):
    w, k = cfg.lru_width, cfg.ssm_conv
    return {"conv": jax.ShapeDtypeStruct((batch, k - 1, w), cfg.cache_dtype),
            "h": jax.ShapeDtypeStruct((batch, w), jnp.float32)}


def _gates(p, xc):
    """a_t (decay) and gated input for xc: [B, C, w] (fp32 math)."""
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(x32, p["gate_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(dense(x32, p["gate_x"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r              # [B,C,w]
    a = jnp.exp(log_a)
    gated = i * x32
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated
    return a, b


def rglru_forward(cfg, p, x, *, h0=None, conv0=None, return_state=False):
    """x: [B,S,d] -> [B,S,d]."""
    b, s, _ = x.shape
    w = cfg.lru_width
    xb = dense(x, p["in_x"])
    yg = jax.nn.gelu(dense(x, p["in_y"]))
    xc, conv_tail = _causal_conv(xb, p["conv_w"], p["conv_b"], conv0)

    chunk = min(cfg.scan_chunk, s)
    if s % chunk != 0:
        chunk = s  # fallback: single chunk for odd lengths
    nc = s // chunk
    xcs = xc.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
    h_init = jnp.zeros((b, w), jnp.float32) if h0 is None else h0

    @jax.checkpoint
    def chunk_fn(h, xc_c):
        a, bb = _gates(p, xc_c)
        a_cum, b_cum = jax.lax.associative_scan(_assoc, (a, bb), axis=1)
        h_all = a_cum * h[:, None] + b_cum                    # [B,C,w]
        return h_all[:, -1], h_all

    h_last, hs = jax.lax.scan(chunk_fn, h_init, xcs)
    h_seq = hs.transpose(1, 0, 2, 3).reshape(b, s, w)
    out = dense((h_seq * yg.astype(jnp.float32)).astype(x.dtype), p["out"])
    if return_state:
        return out, {"conv": conv_tail.astype(cfg.cache_dtype), "h": h_last}
    return out


def rglru_decode(cfg, p, x, cache):
    """x: [B,1,d]."""
    k = cfg.ssm_conv
    xb = dense(x, p["in_x"])
    yg = jax.nn.gelu(dense(x, p["in_y"]))
    window = jnp.concatenate([cache["conv"].astype(xb.dtype), xb], axis=1)
    xc = sum(window[:, i] * p["conv_w"][i].astype(xb.dtype) for i in range(k))
    xc = (xc + p["conv_b"].astype(xb.dtype))[:, None]         # [B,1,w]
    a, bb = _gates(p, xc)
    h = a[:, 0] * cache["h"] + bb[:, 0]                       # [B,w]
    out = dense((h[:, None] * yg.astype(jnp.float32)).astype(x.dtype), p["out"])
    return out, {"conv": window[:, 1:].astype(cfg.cache_dtype), "h": h}

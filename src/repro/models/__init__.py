from .lm import LM
from .whisper import Whisper


def get_model(cfg):
    """Facade: the right model class for a config."""
    return Whisper(cfg) if cfg.family == "audio" else LM(cfg)


__all__ = ["LM", "Whisper", "get_model"]

"""Deterministic synthetic LM data pipeline.

Designed for the multi-host setting: every host draws only its slice of the
global batch (host-sharded loading), and the pipeline position (`step`) is
part of its checkpointable state so a restarted/elastically-rescaled job
resumes the exact token stream (fault tolerance; see checkpoint/).
"""
from __future__ import annotations

import numpy as np


class SyntheticLMData:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 with_frames: int = 0, d_model: int = 0,
                 with_pos_ids: bool = False):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.seq = seq_len
        self.seed = seed
        self.host = host_index
        self.step = 0
        self.with_frames = with_frames
        self.d_model = d_model
        self.with_pos_ids = with_pos_ids

    # --- checkpointable state ---
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict, host_index: int | None = None,
                host_count: int | None = None):
        """Elastic restore: host topology may differ from save time."""
        self.step = int(state["step"])
        self.seed = int(state["seed"])
        if host_count is not None:
            assert self.global_batch % host_count == 0
            self.local_batch = self.global_batch // host_count
            self.host = host_index or 0

    def _rng(self):
        # independent of host_count: key on (seed, step) then slice rows
        return np.random.default_rng((self.seed, self.step))

    def next_batch(self) -> dict:
        rng = self._rng()
        tokens = rng.integers(0, self.vocab,
                              size=(self.global_batch, self.seq + 1),
                              dtype=np.int32)
        lo = self.host * self.local_batch
        sl = slice(lo, lo + self.local_batch)
        batch = {"tokens": tokens[sl, :-1], "labels": tokens[sl, 1:]}
        if self.with_frames:
            batch["frames"] = rng.standard_normal(
                (self.global_batch, self.with_frames, self.d_model),
                dtype=np.float32)[sl]
        if self.with_pos_ids:
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32)[None, :, None],
                                  (self.local_batch, self.seq, 3))
            batch["pos_ids"] = np.ascontiguousarray(pos)
        self.step += 1
        return batch

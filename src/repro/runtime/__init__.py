from .ft import TrainRunner

__all__ = ["TrainRunner"]

from .ft import LoopRunner, TrainRunner

__all__ = ["LoopRunner", "TrainRunner"]

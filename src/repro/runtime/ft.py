"""Fault-tolerance / elasticity / straggler runtime around the train loop.

At 1000+ nodes, failures are routine; the runner provides:
* periodic async checkpoints + resume-from-latest (restart-safe);
* **elastic resume**: the checkpoint stores full arrays and the data
  position, so a job restarted with a different host/mesh size re-places
  params onto the new mesh and re-slices the SAME token stream;
* **straggler mitigation**: per-step wall-time watchdog — the SAME
  trailing-median `FaultLedger.note_time` watchdog the core executor and
  the serving layer use (one straggler story across all three layers,
  visible in `explain_faults()`); on a real pod this signal feeds
  preemption/replacement (here: surfaced via `runner.straggler_events`
  and the ledger, tested by injecting a slow step);
* **peer-replicated carry snapshots** (DESIGN.md §13): an in-memory tier
  ABOVE the disk checkpoints — every `peer_every` iterations the loop
  carries are ring-copied to the neighbouring shard (`ppermute` shift) and
  checksummed, so a lost shard restores its carry from the peer without
  touching disk; a torn replica fails its checksum and the previous good
  one is used instead;
* simulated failure injection for tests (`fail_at_step`).
"""
from __future__ import annotations

import time

import numpy as np

from ..checkpoint import CheckpointManager
from ..core.faults import FaultLedger, checksum


class SimulatedFailure(Exception):
    pass


class PeerReplica:
    """In-memory peer-replicated snapshot tier (DESIGN.md §13).

    Disk checkpoints survive a full-job restart but cost serialization +
    I/O per save; losing ONE shard should not need them.  This tier keeps
    the last `depth` carry snapshots in memory, each array ring-copied to
    the neighbouring shard (`jax.lax.ppermute` shift by +1 over the dp
    axis — shard k's block lives on shard k+1, so shard k dying leaves
    every one of its blocks on a survivor) and stamped with the shared
    crc32 `core.faults.checksum`.  `latest_good()` inverse-permutes the
    newest snapshot back and verifies the stamp; a torn replica (a write
    interrupted by the very failure it protects against) fails its
    checksum and the PREVIOUS good snapshot is returned instead.  Without
    a mesh (single-device runs, tests) the "copy" is a host-side mirror —
    same protocol, same stamps, no collective."""

    def __init__(self, mesh=None, dp=("data",), depth: int = 2,
                 ledger: FaultLedger | None = None):
        self.mesh = mesh
        self.dp = tuple(dp)
        self.depth = int(depth)
        self.ledger = ledger
        self.snaps: list[dict] = []     # oldest → newest
        self.torn: list[int] = []       # steps whose replica failed verify
        self._shift = {}                # (shape, dtype) → jitted ring copy
        self.dp_n = 1
        if mesh is not None:
            for a in self.dp:
                self.dp_n *= dict(zip(mesh.axis_names,
                                      mesh.devices.shape))[a]

    # ------------------------- ring copy -------------------------
    def _ring(self, x, inverse: bool):
        """Shift row blocks to the (next/previous) shard.  Arrays that do
        not tile over the mesh (scalars, odd lengths) mirror host-side —
        the protocol and stamps are identical either way."""
        if self.mesh is None or self.dp_n <= 1 or x.ndim == 0 \
                or x.shape[0] % self.dp_n:
            return np.array(x)          # host mirror (defensive copy)
        import jax
        from jax.sharding import PartitionSpec as P
        from ..compat import shard_map
        key = (tuple(x.shape), str(x.dtype), inverse)
        fn = self._shift.get(key)
        if fn is None:
            n = self.dp_n
            perm = [((i + 1) % n, i) if inverse else (i, (i + 1) % n)
                    for i in range(n)]

            def body(b):
                return jax.lax.ppermute(b, self.dp, perm)
            fn = jax.jit(shard_map(body, mesh=self.mesh,
                                   in_specs=(P(self.dp),),
                                   out_specs=P(self.dp)))
            self._shift[key] = fn
        return fn(x)

    # ------------------------- write / read -------------------------
    def mirror(self, li: int, it: int, step: int, carry: dict) -> None:
        import jax.numpy as jnp
        snap = {"li": int(li), "it": int(it), "step": int(step),
                "data": {}, "crc": {}}
        for name, v in carry.items():
            arr = jnp.asarray(v)
            snap["crc"][name] = checksum(arr)
            snap["data"][name] = self._ring(arr, inverse=False)
        self.snaps.append(snap)
        del self.snaps[:-self.depth]

    def latest_good(self):
        """(li, it, step, carry) from the newest snapshot whose every
        array verifies against its stamp; torn snapshots are skipped to
        the previous good one.  None when nothing usable remains."""
        import jax.numpy as jnp
        for snap in reversed(self.snaps):
            carry = {}
            ok = True
            for name, v in snap["data"].items():
                back = jnp.asarray(self._ring(jnp.asarray(v), inverse=True))
                if checksum(back) != snap["crc"][name]:
                    ok = False
                    break
                carry[name] = back
            if ok:
                return snap["li"], snap["it"], snap["step"], carry
            self.torn.append(snap["step"])
            if self.ledger is not None:
                self.ledger.record(
                    "escalate", f"loop{snap['li']}",
                    f"peer replica at iteration {snap['it']} is torn "
                    f"(checksum mismatch) — previous good snapshot used")
        return None


class TrainRunner:
    def __init__(self, step_fn, params, opt_state, data, ckpt_dir: str,
                 ckpt_every: int = 10, straggler_factor: float = 3.0,
                 shardings=None, ledger: FaultLedger | None = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.mgr = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.shardings = shardings
        self.step = 0
        # ONE straggler watchdog for the whole system: the shared
        # FaultLedger trailing-median idiom (same as core rounds and
        # served batches), not a private list only this class can see
        self.faults = ledger if ledger is not None else \
            FaultLedger(name="train")
        self.faults.straggler_factor = straggler_factor
        self.straggler_events: list[int] = []   # flagged step indices

    def explain_faults(self) -> str:
        return self.faults.explain()

    def maybe_resume(self):
        latest = self.mgr.latest()
        if latest is None:
            return False
        self.step, self.params, self.opt_state, extra = self.mgr.restore(
            latest, self.params, self.opt_state, self.shardings)
        if "data" in extra:
            self.data.restore(extra["data"],
                              host_index=self.data.host,
                              host_count=self.data.global_batch
                              // self.data.local_batch)
        return True

    def run(self, num_steps: int, fail_at_step: int | None = None):
        metrics = None
        while self.step < num_steps:
            if fail_at_step is not None and self.step == fail_at_step:
                raise SimulatedFailure(f"injected failure at {self.step}")
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if self.faults.note_time("train.step",
                                     time.perf_counter() - t0):
                self.straggler_events.append(self.step)
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.mgr.save(self.step, self.params, self.opt_state,
                              extra={"data": self.data.state()})
        self.mgr.wait()
        return metrics


class LoopRunner:
    """Mid-loop checkpoint/resume for ITERATIVE PLANS (DESIGN.md §11) —
    the TrainRunner idiom applied to the core executor's SeqLoops.

    Drives ``CompiledProgram.run_stepwise`` (host-driven loops) and
    snapshots every loop carry through CheckpointManager every ``every``
    iterations, keyed ``loop<i>/<carry-name>`` with the iteration count in
    the checkpoint metadata.  A plan killed at iteration k (crash, or an
    injected ``lower.loop_iter`` fault) restarts with ``resume=True``:
    nodes before the loop re-execute (pure + deterministic), the carry is
    restored from the latest snapshot, and the final outputs are
    BIT-IDENTICAL to an uninterrupted stepwise run — both execute the
    exact same per-iteration body computations on the same carry values
    (npz array round-trips are exact).  Per-iteration wall times feed the
    program's straggler watchdog (`explain_faults()`).

    With ``peer_every`` > 0 the carries ADDITIONALLY mirror to the
    in-memory peer-replica tier (DESIGN.md §13) every ``peer_every``
    iterations: resume prefers the newest GOOD peer snapshot over the disk
    tier when the peer is fresher (memory beats disk on recency AND
    latency; disk survives what memory cannot — a full-job restart still
    restores from npz).  Both tiers verify the shared crc32 stamp and
    skip torn snapshots to the previous good one.

    Out-of-core runs (DESIGN.md §12) ride the same machinery unchanged:
    a ChunkLoop is a top-level SeqLoop to run_stepwise, so its observer
    fires per CHUNK and a killed streamed run resumes from the last chunk
    checkpoint, fast-forwarding past completed tiles."""

    def __init__(self, cp, ckpt_dir: str, every: int = 1, keep: int = 3,
                 async_write: bool = False, peer_every: int = 0,
                 mesh=None, dp=("data",)):
        self.cp = cp
        self.mgr = CheckpointManager(ckpt_dir, keep=keep,
                                     async_write=async_write)
        self.every = int(every)
        self.saves = 0
        self.resumed_from = None       # checkpoint step of the last resume
        self.peer_every = int(peer_every)
        self.peer = PeerReplica(mesh=mesh, dp=dp, ledger=cp.faults) \
            if peer_every else None
        self.peer_restores = 0
        self._step = 0
        self._t_last = 0.0

    def run(self, inputs: dict, resume: bool = True) -> dict:
        loop_state = None
        self.resumed_from = None
        if resume:
            latest = self.mgr.latest()
            if latest is not None:
                step, flat, extra = self.mgr.restore_flat(latest)
                loop_state = {}
                for li_s, it in (extra.get("loops") or {}).items():
                    li = int(li_s)
                    carry = {k.split("/", 1)[1]: v for k, v in flat.items()
                             if k.startswith(f"loop{li}/")}
                    loop_state[li] = (int(it), carry)
                self.resumed_from = step
                self._step = step
            good = self.peer.latest_good() if self.peer is not None \
                else None
            if good is not None:
                li, it, step, carry = good
                disk_it = loop_state.get(li, (-1, None))[0] \
                    if loop_state else -1
                if it > disk_it:
                    loop_state = loop_state or {}
                    loop_state[li] = (it, {c: np.asarray(v)
                                           for c, v in carry.items()})
                    self.resumed_from = step
                    self._step = max(self._step, step)
                    self.peer_restores += 1
                    self.cp.faults.recovered(
                        f"loop{li}",
                        f"carry restored from peer replica (iteration "
                        f"{it}, ring copy verified against checksum; disk "
                        f"tier was at iteration {max(disk_it, 0)})")
        self._t_last = time.perf_counter()
        out = self.cp.run_stepwise(inputs, loop_state=loop_state,
                                   observer=self._observer)
        self.mgr.wait()
        return out

    def _observer(self, li, it, carry):
        self._step += 1
        now = time.perf_counter()
        self.cp.faults.note_time(f"loop{li}.iter", now - self._t_last)
        self._t_last = now
        if self.every and it % self.every == 0:
            self.mgr.save(self._step,
                          {f"loop{li}/{c}": v for c, v in carry.items()},
                          extra={"loops": {str(li): int(it)}})
            self.saves += 1
        if self.peer is not None and it % self.peer_every == 0:
            self.peer.mirror(li, it, self._step, dict(carry))

"""Fault-tolerance / elasticity / straggler runtime around the train loop.

At 1000+ nodes, failures are routine; the runner provides:
* periodic async checkpoints + resume-from-latest (restart-safe);
* **elastic resume**: the checkpoint stores full arrays and the data
  position, so a job restarted with a different host/mesh size re-places
  params onto the new mesh and re-slices the SAME token stream;
* **straggler mitigation**: per-step wall-time watchdog — a step exceeding
  `straggler_factor` x the trailing-median time is logged and counted; on a
  real pod this signal feeds preemption/replacement (here: surfaced via
  `runner.straggler_events` and tested by injecting a slow step);
* simulated failure injection for tests (`fail_at_step`).
"""
from __future__ import annotations

import time

from ..checkpoint import CheckpointManager


class SimulatedFailure(Exception):
    pass


class TrainRunner:
    def __init__(self, step_fn, params, opt_state, data, ckpt_dir: str,
                 ckpt_every: int = 10, straggler_factor: float = 3.0,
                 shardings=None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.mgr = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.shardings = shardings
        self.step = 0
        self.straggler_events: list[int] = []
        self._times: list[float] = []

    def maybe_resume(self):
        latest = self.mgr.latest()
        if latest is None:
            return False
        self.step, self.params, self.opt_state, extra = self.mgr.restore(
            latest, self.params, self.opt_state, self.shardings)
        if "data" in extra:
            self.data.restore(extra["data"],
                              host_index=self.data.host,
                              host_count=self.data.global_batch
                              // self.data.local_batch)
        return True

    def run(self, num_steps: int, fail_at_step: int | None = None):
        metrics = None
        while self.step < num_steps:
            if fail_at_step is not None and self.step == fail_at_step:
                raise SimulatedFailure(f"injected failure at {self.step}")
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            dt = time.perf_counter() - t0
            if len(self._times) >= 3:
                med = sorted(self._times[-20:])[len(self._times[-20:]) // 2]
                if dt > self.straggler_factor * med:
                    self.straggler_events.append(self.step)
            self._times.append(dt)
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.mgr.save(self.step, self.params, self.opt_state,
                              extra={"data": self.data.state()})
        self.mgr.wait()
        return metrics

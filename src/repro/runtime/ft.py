"""Fault-tolerance / elasticity / straggler runtime around the train loop.

At 1000+ nodes, failures are routine; the runner provides:
* periodic async checkpoints + resume-from-latest (restart-safe);
* **elastic resume**: the checkpoint stores full arrays and the data
  position, so a job restarted with a different host/mesh size re-places
  params onto the new mesh and re-slices the SAME token stream;
* **straggler mitigation**: per-step wall-time watchdog — a step exceeding
  `straggler_factor` x the trailing-median time is logged and counted; on a
  real pod this signal feeds preemption/replacement (here: surfaced via
  `runner.straggler_events` and tested by injecting a slow step);
* simulated failure injection for tests (`fail_at_step`).
"""
from __future__ import annotations

import time

from ..checkpoint import CheckpointManager


class SimulatedFailure(Exception):
    pass


class TrainRunner:
    def __init__(self, step_fn, params, opt_state, data, ckpt_dir: str,
                 ckpt_every: int = 10, straggler_factor: float = 3.0,
                 shardings=None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.mgr = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.shardings = shardings
        self.step = 0
        self.straggler_events: list[int] = []
        self._times: list[float] = []

    def maybe_resume(self):
        latest = self.mgr.latest()
        if latest is None:
            return False
        self.step, self.params, self.opt_state, extra = self.mgr.restore(
            latest, self.params, self.opt_state, self.shardings)
        if "data" in extra:
            self.data.restore(extra["data"],
                              host_index=self.data.host,
                              host_count=self.data.global_batch
                              // self.data.local_batch)
        return True

    def run(self, num_steps: int, fail_at_step: int | None = None):
        metrics = None
        while self.step < num_steps:
            if fail_at_step is not None and self.step == fail_at_step:
                raise SimulatedFailure(f"injected failure at {self.step}")
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            dt = time.perf_counter() - t0
            if len(self._times) >= 3:
                med = sorted(self._times[-20:])[len(self._times[-20:]) // 2]
                if dt > self.straggler_factor * med:
                    self.straggler_events.append(self.step)
            self._times.append(dt)
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.mgr.save(self.step, self.params, self.opt_state,
                              extra={"data": self.data.state()})
        self.mgr.wait()
        return metrics


class LoopRunner:
    """Mid-loop checkpoint/resume for ITERATIVE PLANS (DESIGN.md §11) —
    the TrainRunner idiom applied to the core executor's SeqLoops.

    Drives ``CompiledProgram.run_stepwise`` (host-driven loops) and
    snapshots every loop carry through CheckpointManager every ``every``
    iterations, keyed ``loop<i>/<carry-name>`` with the iteration count in
    the checkpoint metadata.  A plan killed at iteration k (crash, or an
    injected ``lower.loop_iter`` fault) restarts with ``resume=True``:
    nodes before the loop re-execute (pure + deterministic), the carry is
    restored from the latest snapshot, and the final outputs are
    BIT-IDENTICAL to an uninterrupted stepwise run — both execute the
    exact same per-iteration body computations on the same carry values
    (npz array round-trips are exact).  Per-iteration wall times feed the
    program's straggler watchdog (`explain_faults()`).

    Out-of-core runs (DESIGN.md §12) ride the same machinery unchanged:
    a ChunkLoop is a top-level SeqLoop to run_stepwise, so its observer
    fires per CHUNK and a killed streamed run resumes from the last chunk
    checkpoint, fast-forwarding past completed tiles."""

    def __init__(self, cp, ckpt_dir: str, every: int = 1, keep: int = 3,
                 async_write: bool = False):
        self.cp = cp
        self.mgr = CheckpointManager(ckpt_dir, keep=keep,
                                     async_write=async_write)
        self.every = int(every)
        self.saves = 0
        self.resumed_from = None       # checkpoint step of the last resume
        self._step = 0
        self._t_last = 0.0

    def run(self, inputs: dict, resume: bool = True) -> dict:
        loop_state = None
        self.resumed_from = None
        if resume:
            latest = self.mgr.latest()
            if latest is not None:
                step, flat, extra = self.mgr.restore_flat(latest)
                loop_state = {}
                for li_s, it in (extra.get("loops") or {}).items():
                    li = int(li_s)
                    carry = {k.split("/", 1)[1]: v for k, v in flat.items()
                             if k.startswith(f"loop{li}/")}
                    loop_state[li] = (int(it), carry)
                self.resumed_from = step
                self._step = step
        self._t_last = time.perf_counter()
        out = self.cp.run_stepwise(inputs, loop_state=loop_state,
                                   observer=self._observer)
        self.mgr.wait()
        return out

    def _observer(self, li, it, carry):
        self._step += 1
        now = time.perf_counter()
        self.cp.faults.note_time(f"loop{li}.iter", now - self._t_last)
        self._t_last = now
        if self.every and it % self.every == 0:
            self.mgr.save(self._step,
                          {f"loop{li}/{c}": v for c, v in carry.items()},
                          extra={"loops": {str(li): int(it)}})
            self.saves += 1

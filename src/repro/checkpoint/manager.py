"""Fault-tolerant checkpointing.

Design for 1000+ nodes (documented; exercised at container scale):
* **Shard-agnostic format**: leaves are saved as FULL logical arrays
  (device_get gathers shards), so a restore may use a different mesh shape
  or host count — this is what makes resume *elastic*.
* **Atomic**: write to `step_XXXX.tmp/` then rename; a crash mid-write
  never corrupts the newest valid checkpoint; `latest()` scans only
  completed directories.
* **Verified**: every snapshot carries per-array crc32 stamps
  (`checksums.json`, the shared `core.faults.checksum`, same stamp the
  peer-replica tier uses); `latest()` verifies and SKIPS a torn/corrupted
  snapshot to the previous good one instead of restoring garbage.
* **Async**: the device→host copy is synchronous (cheap, avoids donation
  races), the disk write happens on a background thread so the train loop
  isn't stalled on I/O.
* The data-pipeline position is part of the checkpoint, so the token
  stream resumes exactly (no repeated/skipped batches after failover).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    from repro.compat import tree_flatten_with_path
    leaves = tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten_like(template, flat: dict):
    from repro.compat import tree_flatten_with_path
    leaves, treedef = tree_flatten_with_path(template)
    vals = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree.unflatten(treedef, vals)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self.skipped: list[int] = []    # steps latest() refused to restore
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------- write -------------------------
    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        self.wait()
        snap = {
            "params": _flatten(params),
            "opt": _flatten(opt_state) if opt_state is not None else {},
        }
        meta = {"step": int(step), "extra": extra or {}}

        from repro.core.faults import checksum
        sums = {fname: {k: checksum(v) for k, v in snap[part].items()}
                for part, fname in (("params", "params.npz"),
                                    ("opt", "opt.npz"))}

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "params.npz"), **snap["params"])
            np.savez(os.path.join(tmp, "opt.npz"), **snap["opt"])
            with open(os.path.join(tmp, "checksums.json"), "w") as f:
                json.dump(sums, f)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic commit
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------- read -------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def verify(self, step: int) -> bool:
        """Check every array in the snapshot against its crc32 stamp.
        Pre-checksum snapshots (no checksums.json) are accepted as-is —
        the stamp protects against torn/corrupted bytes, and a legacy
        snapshot's absence of stamps is not evidence of either."""
        from repro.core.faults import checksum
        d = os.path.join(self.dir, f"step_{step:08d}")
        cpath = os.path.join(d, "checksums.json")
        if not os.path.exists(cpath):
            return True
        try:
            with open(cpath) as f:
                sums = json.load(f)
            for fname, keys in sums.items():
                zf = np.load(os.path.join(d, fname))
                for k, crc in keys.items():
                    if checksum(zf[k]) != int(crc):
                        return False
        except Exception:               # noqa: BLE001 — torn bytes, any form
            return False
        return True

    def latest(self) -> int | None:
        """Newest snapshot that VERIFIES.  A torn or bit-flipped snapshot
        is skipped (recorded in `self.skipped`) and the previous good one
        is returned instead — restoring garbage is strictly worse than
        restoring slightly older state."""
        for s in reversed(self.steps()):
            if self.verify(s):
                return s
            self.skipped.append(s)
        return None

    def restore(self, step: int, params_template, opt_template=None,
                shardings=None):
        """Returns (step, params, opt_state, extra).  `shardings` (optional
        pytree of NamedSharding for the CURRENT mesh) makes the restore
        elastic: full arrays are re-placed onto whatever mesh is alive."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        pf = np.load(os.path.join(d, "params.npz"))
        params = _unflatten_like(params_template,
                                 {k: pf[k] for k in pf.files})
        opt = None
        if opt_template is not None:
            of = np.load(os.path.join(d, "opt.npz"))
            opt = _unflatten_like(opt_template, {k: of[k] for k in of.files})
        if shardings is not None:
            params = jax.device_put(params, shardings)
        return meta["step"], params, opt, meta["extra"]

    def restore_flat(self, step: int):
        """Template-free read: (step, {path: np.ndarray}, extra).  The
        mid-loop resume path (runtime/ft.LoopRunner) uses this — after a
        crash there is no live pytree to unflatten into; the flat keys
        (``loop<i>/<carry-name>``) are self-describing."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        pf = np.load(os.path.join(d, "params.npz"))
        return meta["step"], {k: pf[k] for k in pf.files}, meta["extra"]

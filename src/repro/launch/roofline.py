"""Roofline terms from the compiled dry-run artifact.

Hardware model (TPU v5e-class, per assignment):
  peak 197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.

Terms (seconds), per (arch x shape x mesh):
  compute    = HLO_FLOPs_per_chip / peak
  memory     = HBM_bytes_per_chip / hbm_bw
  collective = collective_bytes_per_chip / link_bw

HLO_FLOPs/HBM/collective bytes come from hlo_analysis (trip-expanded,
per-device module).  MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) /
2·N_active·B (decode), N excluding the embedding gather.
"""
from __future__ import annotations

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def param_counts(model) -> tuple[int, int]:
    """(total, active) param counts, excluding the embedding table."""
    cfg = model.cfg
    total = 0
    expert = 0
    from repro.compat import tree_flatten_with_path
    for path, leaf in tree_flatten_with_path(model.abstract_params())[0]:
        keys = [getattr(p, "key", str(p)) for p in path]
        if keys[-1] == "embed" and len(keys) == 1:
            continue
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "moe" in keys and keys[-1] in ("w_gate", "w_in", "w_out"):
            expert += n
    active = total - expert
    if cfg.num_experts:
        active += expert * cfg.top_k / cfg.num_experts
    return int(total), int(active)


def model_flops(model, shape_cfg) -> float:
    """Global useful model FLOPs for one step of the cell."""
    total, active = param_counts(model)
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * active * b * s
    if shape_cfg.kind == "prefill":
        return 2.0 * active * b * s
    return 2.0 * active * b  # decode: one token


def roofline(hlo_stats: dict, model, shape_cfg, n_chips: int) -> dict:
    f = hlo_stats["flops"]                      # per chip
    hbm = hlo_stats["hbm_bytes"]                # per chip
    coll = hlo_stats["collective_bytes"]        # per chip
    mf = model_flops(model, shape_cfg)
    terms = {
        "compute_s": f / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    useful_s = (mf / n_chips) / PEAK_FLOPS
    return {
        **terms,
        "dominant": dominant.removesuffix("_s"),
        "model_flops_global": mf,
        "hlo_flops_per_chip": f,
        "useful_ratio": (mf / n_chips) / f if f else 0.0,
        # fraction of the roofline-limited time that is useful compute:
        "roofline_fraction": useful_s / bound if bound else 0.0,
        "collective_bytes_global": coll * n_chips,
    }

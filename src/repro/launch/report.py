"""Render results/dryrun/*.json into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def _fmt_s(x):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load(dirname):
    recs = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        recs[r["cell"]] = r
    return recs


def roofline_table(recs, mesh="pod16x16", tag=None) -> str:
    rows = ["| arch | shape | compute | memory | collective | bound | "
            "bytes/dev (TPU-est) GB | MODEL_FLOPs/HLO_FLOPs | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for cell, r in sorted(recs.items()):
        if r.get("mesh") != mesh and r.get("status") != "skipped":
            continue
        if r.get("status") == "skipped":
            if mesh == "pod16x16" and "pod16x16" in cell:
                a, s, _ = cell.split("__")[:3]
                rows.append(f"| {a} | {s} | - | - | - | skipped | - | - | - |")
            continue
        if tag is not None and r.get("overrides"):
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(ro['compute_s'])} | "
            f"{_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} | "
            f"{ro['dominant']} | {_fmt_bytes(r.get('bytes_per_device_tpu_est'))} | "
            f"{ro['useful_ratio']:.3f} | {ro['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def multipod_table(recs) -> str:
    rows = ["| arch | shape | compile_s | bytes/dev GB | collective GB/chip | "
            "per-chip FLOPs vs 1-pod |", "|---|---|---|---|---|---|"]
    for cell, r in sorted(recs.items()):
        if r.get("mesh") != "pod2x16x16" or r.get("status") != "ok":
            continue
        single = recs.get(cell.replace("pod2x16x16", "pod16x16"), {})
        ratio = "-"
        if single.get("status") == "ok":
            a = r["roofline"]["hlo_flops_per_chip"]
            b = single["roofline"]["hlo_flops_per_chip"]
            ratio = f"{a / b:.2f}x"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{_fmt_bytes(r.get('bytes_per_device_tpu_est'))} | "
            f"{r['hlo_stats']['collective_bytes'] / 1e9:.1f} | {ratio} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    recs = load(args.dir)
    n_ok = sum(r.get("status") == "ok" for r in recs.values())
    n_skip = sum(r.get("status") == "skipped" for r in recs.values())
    print(f"cells: {len(recs)} ({n_ok} ok, {n_skip} skipped)\n")
    print("### Single-pod (16x16 = 256 chips) baseline roofline\n")
    print(roofline_table(recs, "pod16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips) dry-run\n")
    print(multipod_table(recs))


if __name__ == "__main__":
    main()

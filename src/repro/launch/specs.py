"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
zero allocation) for every model input of every (arch x shape) cell, plus
the matching PartitionSpecs.  Used by the dry-run and the launchers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config
from ..models import get_model
from ..optim.adamw import AdamWState

I32 = jnp.int32


def _dp(dp_axes, n, dp_n):
    """dp spec entry only when the dim divides the dp extent."""
    return tuple(dp_axes) if dp_n > 1 and n % dp_n == 0 else None


def input_specs(arch: str, shape_name: str, *, axis_sizes=None,
                dp_axes=("data",)):
    """Returns (specs, pspecs) dicts for the cell's step function inputs
    (excluding params/opt-state, which come from the model)."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    axis_sizes = axis_sizes or {}
    dp_n = 1
    for a in dp_axes:
        dp_n *= axis_sizes.get(a, 1)
    b, s = shp.global_batch, shp.seq_len
    model = get_model(cfg)

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, I32)

    specs: dict = {}
    pspecs: dict = {}
    bspec = _dp(dp_axes, b, dp_n)

    if shp.kind == "train":
        specs["tokens"] = tok((b, s))
        specs["labels"] = tok((b, s))
        pspecs["tokens"] = P(bspec, None)
        pspecs["labels"] = P(bspec, None)
    elif shp.kind == "prefill":
        specs["tokens"] = tok((b, s))
        pspecs["tokens"] = P(bspec, None)
    else:  # decode: one new token with a cache of seq_len
        specs["token"] = tok((b, 1))
        specs["pos"] = jax.ShapeDtypeStruct((), I32)
        pspecs["token"] = P(bspec, None)
        pspecs["pos"] = P()
        cache = model.cache_defs(b, s)
        specs["cache"] = cache
        pspecs["cache"] = model.cache_pspecs(cache, axis_sizes, dp_axes)

    if cfg.family == "vlm" and shp.kind != "decode":
        specs["pos_ids"] = tok((b, s, 3))
        pspecs["pos_ids"] = P(bspec, None, None)
    if cfg.family == "audio" and shp.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                               jnp.float32)
        pspecs["frames"] = P(bspec, None, None)
    return specs, pspecs


def opt_state_specs(params_abs, dtype=jnp.float32):
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, dtype)
    return AdamWState(jax.ShapeDtypeStruct((), I32),
                      jax.tree.map(mk, params_abs),
                      jax.tree.map(mk, params_abs))


def opt_state_pspecs(param_pspecs):
    return AdamWState(P(), param_pspecs, param_pspecs)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh (16x16 single-pod / 2x16x16 multi-pod), print
memory_analysis / cost_analysis, and derive roofline terms from the
partitioned HLO (trip-count-expanded; see hlo_analysis.py).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch ... --multi-pod
"""

import argparse
import json
import time
import traceback


BASELINE_KNOBS = dict(microbatch=1, opt_dtype="f32", attn_chunk=1024,
                      fsdp_experts=True, shard_embed_vocab=True,
                      sp_attn=False, capacity_factor=1.25)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, overrides: dict | None = None,
             tag: str = "", baseline: bool = False) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import SHAPES, get_config
    from ..models import get_model
    from ..train.step import make_train_step
    from ..serve.step import make_decode_step, make_prefill_step
    from . import hlo_analysis, roofline
    from .cells import skip_reason
    from .mesh import axis_sizes, dp_axes_of, make_production_mesh
    from .specs import input_specs, opt_state_pspecs, opt_state_specs

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    reason = skip_reason(arch, shape_name)
    if reason:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        _write(out_dir, cell_id, rec)
        print(json.dumps(rec))
        return rec

    t0 = time.time()
    cfg = get_config(arch)
    if baseline:  # pre-hillclimb knobs (§Perf baseline)
        cfg = cfg.replace(**BASELINE_KNOBS)
    if overrides:
        cfg = cfg.replace(**overrides)
    shp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = axis_sizes(mesh)
    n_chips = int(mesh.devices.size)
    dp = dp_axes_of(mesh)
    model = get_model(cfg)

    params_abs = model.abstract_params()
    params_ps = model.pspecs(sizes)
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    in_specs, in_ps = input_specs(arch, shape_name, axis_sizes=sizes, dp_axes=dp)

    if shp.kind == "train":
        import jax.numpy as jnp
        step = make_train_step(cfg, mesh, dp)
        opt_abs = opt_state_specs(
            params_abs, jnp.bfloat16 if cfg.opt_dtype == "bf16" else jnp.float32)
        opt_ps = opt_state_pspecs(params_ps)
        batch_abs = {k: v for k, v in in_specs.items()}
        batch_ps = {k: v for k, v in in_ps.items()}
        fn = jax.jit(step,
                     in_shardings=(ns(params_ps), ns(opt_ps), ns(batch_ps)),
                     out_shardings=(ns(params_ps), ns(opt_ps), None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
    elif shp.kind == "prefill":
        step = make_prefill_step(cfg, shp.seq_len, mesh, dp)
        cache_abs = model.cache_defs(shp.global_batch, shp.seq_len)
        cache_ps = model.cache_pspecs(cache_abs, sizes, dp)
        fn = jax.jit(step,
                     in_shardings=(ns(params_ps), ns(in_ps)),
                     out_shardings=(None, ns(cache_ps)))
        lowered = fn.lower(params_abs, in_specs)
    else:  # decode
        step = make_decode_step(cfg, mesh, dp)
        cache_ps = in_ps["cache"]
        fn = jax.jit(step,
                     in_shardings=(ns(params_ps), ns(cache_ps),
                                   ns(in_ps["token"]), ns(in_ps["pos"])),
                     out_shardings=(None, ns(cache_ps)),
                     donate_argnums=(1,))
        lowered = fn.lower(params_abs, in_specs["cache"], in_specs["token"],
                           in_specs["pos"])
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes"):
            mem_rec[f] = getattr(mem, f, None)
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: list with one dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    stats = hlo_analysis.analyze(hlo)
    roof = roofline.roofline(stats, model, shp, n_chips)

    rec = {
        "cell": cell_id, "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "bytes_per_device": (mem_rec.get("argument_size_in_bytes") or 0) +
                            (mem_rec.get("temp_size_in_bytes") or 0),
        # minus the CPU-backend f32-upcast copies of bf16 scan state that a
        # TPU build (bf16-native MXU) would not materialize — see hlo_analysis
        "bytes_per_device_tpu_est": (mem_rec.get("argument_size_in_bytes") or 0) +
                                    (mem_rec.get("temp_size_in_bytes") or 0) -
                                    stats.get("upcast_artifact_bytes", 0),
        "cost_analysis_flops_unscaled": cost.get("flops"),
        "hlo_stats": {k: v for k, v in stats.items() if k != "trip_counts"},
        "trip_counts": stats["trip_counts"],
        "roofline": roof,
        "overrides": overrides or {},
    }
    if save_hlo:
        with open(os.path.join(out_dir, cell_id + ".hlo.txt"), "w") as f:
            f.write(hlo)
    _write(out_dir, cell_id, rec)
    print(json.dumps({k: rec[k] for k in
                      ("cell", "status", "compile_s", "bytes_per_device",
                       "bytes_per_device_tpu_est")} | {"roofline": roof}))
    return rec


def _write(out_dir, cell_id, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--baseline", action="store_true",
                    help="pin pre-hillclimb perf knobs")
    ap.add_argument("--override", default="",
                    help="comma k=v model-config overrides (perf experiments)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override.split(","):
        if kv:
            k, v = kv.split("=")
            overrides[k] = type_guess(v)
    try:
        run_cell(args.arch, args.shape, args.multi_pod, args.out,
                 args.save_hlo, overrides or None, args.tag, args.baseline)
    except Exception:
        rec = {"cell": f"{args.arch}__{args.shape}", "status": "error",
               "error": traceback.format_exc()}
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        _write(args.out, f"{args.arch}__{args.shape}__{mesh_name}" +
               (f"__{args.tag}" if args.tag else ""), rec)
        print(rec["error"])
        raise SystemExit(1)


def type_guess(v: str):
    for t in (int, float):
        try:
            return t(v)
        except ValueError:
            pass
    return v


if __name__ == "__main__":
    main()

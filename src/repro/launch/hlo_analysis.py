"""Static analysis of compiled (post-SPMD) HLO text.

``Compiled.cost_analysis()`` does NOT multiply `while` (lax.scan) body costs
by the trip count (verified empirically), which under-counts an 80-layer
scanned model by ~80x.  This module re-derives roofline inputs from
``compiled.as_text()``:

* dot FLOPs, expanded through the call graph (fusion `calls=`,
  `while` bodies x statically-extracted trip counts, `conditional` = max
  branch),
* an HBM-traffic estimate using a fusion-boundary model (only fusion/dot/
  collective/copy/etc. inputs+outputs touch HBM; intra-fusion temporaries
  are free),
* per-type collective bytes (operand sizes, per the assignment spec), also
  trip-expanded.

Operands in compiled HLO are bare `%name` references, so each computation
keeps a symbol table (header parameters + op outputs) to resolve shapes.
All numbers are per-device: the compiled module is the partitioned program.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],{}]+))")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast",
             "after-all", "iota"}
# ops XLA-TPU fuses into consumers: no HBM traffic of their own in the
# write-once/read-once model (v2); layout-changing transposes still count
_FUSED_OPS = _FREE_OPS | {"broadcast", "reshape", "convert", "copy-done",
                          "copy-start"}


def _dims(s: str) -> list[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    n_total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        n_total += n
    return n_total


@dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclass
class Comp:
    name: str
    ops: list[Op] = field(default_factory=list)
    sym: dict = field(default_factory=dict)  # name -> type string


def _split_operands(rest: str) -> tuple[list[str], str]:
    """Split 'a, %b, ...), attr=..., ...' into (operand names, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                ops = []
                d2 = 0
                cur = ""
                for c in inner:
                    if c in "([{":
                        d2 += 1
                    elif c in ")]}":
                        d2 -= 1
                    if c == "," and d2 == 0:
                        ops.append(cur.strip())
                        cur = ""
                    else:
                        cur += c
                if cur.strip():
                    ops.append(cur.strip())
                names = [o.lstrip("%") for o in ops]
                return names, attrs
    return [], rest


def parse_computations(hlo: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    for line in hlo.splitlines():
        hm = _HDR_RE.match(line)
        if hm:
            cur = Comp(hm.group(2))
            comps[cur.name] = cur
            for pname, ptype in _PARAM_RE.findall(hm.group(3)):
                cur.sym[pname] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            operands, attrs = _split_operands(om.group(4))
            op = Op(om.group(1), om.group(2), om.group(3), operands, attrs)
            cur.ops.append(op)
            cur.sym[op.name] = op.out_type
    return comps


def _operand_bytes(comp: Comp, op: Op) -> int:
    total = 0
    for o in op.operands:
        t = comp.sym.get(o)
        if t:
            total += _shape_bytes(t)
        elif "[" in o:  # inline-typed operand (rare)
            total += _shape_bytes(o)
    return total


def _dot_flops(comp: Comp, op: Op) -> float:
    out = _shape_elems(op.out_type)
    lhs_t = comp.sym.get(op.operands[0], "") if op.operands else ""
    if not lhs_t and op.operands and "[" in op.operands[0]:
        lhs_t = op.operands[0]       # inline-typed operand (older HLO text)
    m = _SHAPE_RE.search(lhs_t)
    contract = 1
    if m:
        lhs_dims = _dims(m.group(2))
        cm = _CONTRACT.search(op.attrs)
        if cm:
            for i in _dims(cm.group(1)):
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out * contract


def _trip_count(comps: dict[str, Comp], cond_name: str) -> int:
    """Largest integer literal in the loop-condition computation — for
    jax.lax.scan this is the `compare(i, constant(N), LT)` bound."""
    best = 1
    comp = comps.get(cond_name)
    if comp is None:
        return best
    for op in comp.ops:
        for c in _CONST.findall(f"{op.opcode}({','.join(op.operands)}){op.attrs}"):
            best = max(best, int(c))
    return best


def _collective_kind(opcode: str) -> str | None:
    base = opcode.removesuffix("-start").removesuffix("-done")
    return base if base in COLLECTIVES else None


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0          # v2: 2x outputs of non-fused ops
    hbm_bytes_boundary: float = 0.0  # v1 upper bound: operands+outputs
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_bytes_boundary += other.hbm_bytes_boundary * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult


def analyze(hlo: str, entry: str | None = None) -> dict:
    comps = parse_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else max(comps, key=lambda k: len(comps[k].ops))

    memo: dict[str, Cost] = {}
    trip_log: dict[str, int] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        comp = comps[name]
        c = Cost()
        for op in comp.ops:
            kind = _collective_kind(op.opcode)
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trip = _trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    trip_log[bm.group(1)] = trip
                    c.add(cost_of(bm.group(1), stack + (name,)), trip)
                continue
            if op.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if fm:
                    c.flops += cost_of(fm.group(1), stack + (name,)).flops
                c.hbm_bytes += 2 * _shape_bytes(op.out_type)
                c.hbm_bytes_boundary += _shape_bytes(op.out_type) + \
                    _operand_bytes(comp, op)
                continue
            if op.opcode == "call":
                fm = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if fm:
                    c.add(cost_of(fm.group(1), stack + (name,)))
                continue
            if op.opcode == "conditional":
                brs = re.findall(r"(?:true_computation|false_computation|"
                                 r"branch_computations=\{[^}]*)=?%?([\w.\-]+)",
                                 op.attrs)
                subs = [cost_of(b, stack + (name,)) for b in brs if b in comps]
                if subs:
                    c.add(max(subs, key=lambda s: s.flops))
                continue
            if kind:
                b = _operand_bytes(comp, op)
                c.coll_bytes[kind] = c.coll_bytes.get(kind, 0.0) + b
                c.coll_count[kind] = c.coll_count.get(kind, 0.0) + 1
                c.hbm_bytes += 2 * _shape_bytes(op.out_type)
                c.hbm_bytes_boundary += b + _shape_bytes(op.out_type)
                continue
            if op.opcode == "dot":
                c.flops += _dot_flops(comp, op)
                c.hbm_bytes += 2 * _shape_bytes(op.out_type)
                c.hbm_bytes_boundary += _shape_bytes(op.out_type) + \
                    _operand_bytes(comp, op)
                continue
            if op.opcode == "custom-call":
                if "matmul" in op.attrs or "dot" in op.attrs:
                    c.flops += _dot_flops(comp, op)
                c.hbm_bytes += 2 * _shape_bytes(op.out_type)
                c.hbm_bytes_boundary += _shape_bytes(op.out_type) + \
                    _operand_bytes(comp, op)
                continue
            if op.opcode in _FUSED_OPS:
                if op.opcode not in _FREE_OPS:
                    c.hbm_bytes_boundary += _shape_bytes(op.out_type) + \
                        _operand_bytes(comp, op)
                continue
            # streaming op (copy, dynamic-slice/update, gather, reduce, ...)
            c.hbm_bytes += 2 * _shape_bytes(op.out_type)
            c.hbm_bytes_boundary += _shape_bytes(op.out_type) + \
                _operand_bytes(comp, op)
        memo[name] = c
        return c

    total = cost_of(entry)
    # entry arguments are read once from HBM
    arg_bytes = sum(_shape_bytes(t) for t in comps[entry].sym.values()) \
        if entry in comps else 0
    return {
        "flops": total.flops,
        "hbm_bytes": total.hbm_bytes + arg_bytes,
        "hbm_bytes_boundary": total.hbm_bytes_boundary,
        "collective_bytes": sum(total.coll_bytes.values()),
        "collectives": {k: {"bytes": v, "count": total.coll_count.get(k, 0)}
                        for k, v in total.coll_bytes.items()},
        "trip_counts": trip_log,
        "n_computations": len(comps),
        "upcast_artifact_bytes": _upcast_artifact(comps),
    }


def _upcast_artifact(comps: dict[str, Comp]) -> int:
    """CPU-backend artifact: XLA-CPU upcasts bf16 dot operands to f32 and
    hoists the convert out of `while` loops, materializing f32 copies of
    whole scan-xs stacks in the loop state.  TPU consumes bf16 natively, so
    these buffers would not exist there.  Conservative estimate: f32 while-
    state entries that have an identical-shape bf16 twin in the same tuple
    (pure copies)."""
    seen_tuples: set[str] = set()
    artifact = 0
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode != "while" or op.out_type in seen_tuples:
                continue
            seen_tuples.add(op.out_type)
            entries = re.findall(r"(\w+)(\[[\d,]*\])", op.out_type)
            bf16_counts: dict[str, int] = {}
            for dt, dims in entries:
                if dt == "bf16":
                    bf16_counts[dims] = bf16_counts.get(dims, 0) + 1
            for dt, dims in entries:
                if dt == "f32" and bf16_counts.get(dims, 0) > 0:
                    bf16_counts[dims] -= 1
                    artifact += _shape_bytes(f"f32{dims}")
    return artifact

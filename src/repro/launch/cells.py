"""Enumeration of the 40 assigned (architecture x shape) dry-run cells,
with the mandated skips (long_500k needs sub-quadratic attention)."""
from __future__ import annotations

from ..configs import SHAPES, get_config, list_archs

# families allowed to run long_500k (sub-quadratic sequence mixing)
_SUBQUADRATIC = ("ssm", "hybrid")


def skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return "full quadratic attention at 524288 ctx — skipped per assignment"
    return None


def all_cells() -> list[tuple[str, str, str | None]]:
    """[(arch, shape, skip_reason)] — 40 rows."""
    out = []
    for arch in list_archs():
        for shape in SHAPES:
            out.append((arch, shape, skip_reason(arch, shape)))
    return out

"""Sweep driver: run every (arch x shape x mesh) dry-run cell in an
isolated subprocess (fresh XLA state per cell; one failure can't kill the
sweep).  Resumable: cells with an existing ok/skipped JSON are not re-run.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cell_done(out_dir: str, arch: str, shape: str, mesh_name: str) -> bool:
    f = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if not os.path.exists(f):
        return False
    try:
        return json.load(open(f)).get("status") in ("ok", "skipped")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--only-mesh", choices=["single", "multi"], default=None)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--baseline", action="store_true")
    args = ap.parse_args()

    from ..launch.cells import all_cells
    meshes = [("pod16x16", []), ("pod2x16x16", ["--multi-pod"])]
    if args.only_mesh == "single":
        meshes = meshes[:1]
    if args.only_mesh == "multi":
        meshes = meshes[1:]

    todo = []
    for mesh_name, flags in meshes:
        for arch, shape, _skip in all_cells():
            if not cell_done(args.out, arch, shape, mesh_name):
                todo.append((arch, shape, mesh_name, flags))
    print(f"[sweep] {len(todo)} cells to run", flush=True)

    t0 = time.time()
    fails = 0
    for i, (arch, shape, mesh_name, flags) in enumerate(todo):
        t1 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", args.out] + flags + \
            (["--baseline"] if args.baseline else [])
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=args.timeout,
                           env={**os.environ, "PYTHONPATH": "src"})
        status = "ok" if r.returncode == 0 else "FAIL"
        fails += status == "FAIL"
        print(f"[sweep {i+1}/{len(todo)}] {arch} {shape} {mesh_name}: {status} "
              f"({time.time()-t1:.0f}s, total {time.time()-t0:.0f}s)", flush=True)
        if status == "FAIL":
            print(r.stdout[-2000:], r.stderr[-2000:], flush=True)
    print(f"[sweep] done, {fails} failures", flush=True)


if __name__ == "__main__":
    main()

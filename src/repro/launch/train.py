"""Training launcher: end-to-end driver (data → train_step → checkpoints,
fault-tolerant resume, optional mesh).

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 20 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M preset: 768)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from ..configs import get_config, smoke_config
    from ..data import SyntheticLMData
    from ..models import get_model
    from ..optim.adamw import adamw_init
    from ..runtime import TrainRunner
    from ..train import make_train_step

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.d_model:
        hd = max(16, args.d_model // max(cfg.num_heads, 1))
        cfg = cfg.replace(d_model=args.d_model, d_ff=args.d_model * 4,
                          head_dim=hd)
    if args.layers:
        cfg = cfg.replace(layout=tuple((pat, args.layers)
                                       for pat, _ in cfg.layout[:1]))

    model = get_model(cfg)
    params = model.init(args.seed)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    data = SyntheticLMData(cfg.vocab_size, args.global_batch, args.seq,
                           seed=args.seed,
                           with_frames=cfg.enc_seq if cfg.family == "audio" else 0,
                           d_model=cfg.d_model,
                           with_pos_ids=cfg.family == "vlm")
    step_fn = jax.jit(make_train_step(cfg, None, ("data",), lr=args.lr,
                                      compress_grads=False))
    opt = adamw_init(params)

    runner = TrainRunner(step_fn, params, opt, data,
                         ckpt_dir=args.ckpt or "/tmp/repro_ckpt",
                         ckpt_every=args.ckpt_every)
    if args.resume and runner.maybe_resume():
        print(f"[train] resumed from step {runner.step}")

    t0 = time.time()
    last = runner.step
    while runner.step < args.steps:
        nxt = min(runner.step + args.log_every, args.steps)
        m = runner.run(nxt)
        dt = time.time() - t0
        sps = (runner.step - last) / max(dt, 1e-9)
        t0, last = time.time(), runner.step
        print(f"[train] step {runner.step:5d} loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.3f} ({sps:.2f} steps/s)")
    if args.ckpt:
        runner.mgr.save(runner.step, runner.params, runner.opt_state,
                        extra={"data": data.state()})
        runner.mgr.wait()
    return float(m["loss"])


if __name__ == "__main__":
    main()

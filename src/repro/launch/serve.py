"""Serving launcher: batched prefill + decode loop with KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..configs import get_config, smoke_config
    from ..models import get_model
    from ..serve import make_decode_step, make_prefill_step

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(args.seed)
    rng = np.random.default_rng(args.seed)
    max_seq = args.prompt_len + args.gen

    batch = {"tokens": rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["pos_ids"] = np.broadcast_to(
            np.arange(args.prompt_len, dtype=np.int32)[None, :, None],
            (args.batch, args.prompt_len, 3)).copy()

    prefill = jax.jit(make_prefill_step(cfg, max_seq))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens "
          f"in {dt:.2f}s ({args.batch * gen.shape[1] / dt:.1f} tok/s)")
    print("[serve] sample token ids:", gen[0][:12].tolist())
    return gen


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips; multi-pod adds
a leading `pod` axis (2 pods = 512 chips).  The dry-run forces 512 host
devices via XLA_FLAGS (see dryrun.py); the single-pod mesh then uses the
first 256.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (see dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires forced host devices)."""
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

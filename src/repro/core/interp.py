"""Reference sequential interpreter of the loop language — the correctness
oracle for the compiler (paper Theorem A.1 is validated empirically by
comparing compiled output against this, see tests/test_core_properties.py).

Semantics notes (paper §3.4): an array read whose index is out of range
denotes the EMPTY BAG, which propagates — the enclosing statement instance
contributes nothing.  Same for a destination index out of range.
"""
from __future__ import annotations

import math

import numpy as np

from .loop_ast import (Assign, BinOp, Call, Const, DIndex, DVar, ForIn,
                       ForRange, If, IncUpdate, Index, Program, Stmt, UnOp,
                       Var, While)


class _Missing(Exception):
    pass


_BIN = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b,
    "*": lambda a, b: a * b, "/": lambda a, b: a / b,
    "//": lambda a, b: a // b, "%": lambda a, b: a % b,
    "**": lambda a, b: a ** b,
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
    "and": lambda a, b: bool(a) and bool(b),
    "or": lambda a, b: bool(a) or bool(b),
}

_FN = {"sqrt": math.sqrt, "exp": math.exp, "log": math.log, "abs": abs,
       "sin": math.sin, "cos": math.cos, "tanh": math.tanh,
       "sigmoid": lambda x: 1.0 / (1.0 + math.exp(-x)),
       "float": float, "int": int, "min": min, "max": max,
       "where": lambda c, a, b: a if c else b}

_AGG = {"+": lambda a, b: a + b, "*": lambda a, b: a * b,
        "min": min, "max": max}


def _index(env, name, idxs):
    arr = env[name]
    ii = tuple(int(i) for i in idxs)
    for d, i in zip(arr.shape, ii):
        if i < 0 or i >= d:
            raise _Missing()
    return arr[ii]


def run(prog: Program, inputs: dict) -> dict:
    env = {}
    for name, t in prog.params.items():
        v = inputs[name]
        if t.kind in ("vector", "matrix", "map"):
            env[name] = np.array(v, dtype=np.float64 if t.dtype == "float"
                                 else np.int64)
        elif t.kind == "bag":
            env[name] = tuple(np.asarray(c) for c in v) if isinstance(v, tuple) \
                else (np.asarray(v),)
        else:
            env[name] = v

    def ev(e) -> float:
        if isinstance(e, Var):
            v = env[e.name]
            if isinstance(v, _Missing):
                raise _Missing()
            return v
        if isinstance(e, Const):
            return e.value
        if isinstance(e, Index):
            return _index(env, e.array, [ev(i) for i in e.idxs])
        if isinstance(e, BinOp):
            return _BIN[e.op](ev(e.lhs), ev(e.rhs))
        if isinstance(e, UnOp):
            return -ev(e.e) if e.op == "neg" else not ev(e.e)
        if isinstance(e, Call):
            return _FN[e.fn](*[ev(a) for a in e.args])
        raise TypeError(e)

    def exec_stmt(s: Stmt):
        if isinstance(s, (Assign, IncUpdate)):
            try:
                val = ev(s.value)
                if isinstance(s.dest, DVar):
                    if isinstance(s, IncUpdate):
                        env[s.dest.name] = _AGG[s.op](env[s.dest.name], val)
                    else:
                        env[s.dest.name] = val
                else:
                    arr = env[s.dest.array]
                    ii = tuple(int(ev(i)) for i in s.dest.idxs)
                    for d, i in zip(arr.shape, ii):
                        if i < 0 or i >= d:
                            raise _Missing()
                    if isinstance(s, IncUpdate):
                        arr[ii] = _AGG[s.op](arr[ii], val)
                    else:
                        arr[ii] = val
            except _Missing:
                pass  # empty-bag semantics: contributes nothing
        elif isinstance(s, ForRange):
            lo, hi = int(ev(s.lo)), int(ev(s.hi))
            for i in range(lo, hi):
                env[s.var] = i
                for b in s.body:
                    exec_stmt(b)
        elif isinstance(s, ForIn):
            cols = env[s.bag]
            if isinstance(cols, np.ndarray):
                cols = (cols,)
            n = len(cols[0])
            for r in range(n):
                if s.with_index:
                    env[s.pats[0]] = r
                    for j, p in enumerate(s.pats[1:]):
                        env[p] = cols[j][r]
                else:
                    for j, p in enumerate(s.pats):
                        env[p] = cols[j][r]
                for b in s.body:
                    exec_stmt(b)
        elif isinstance(s, While):
            while ev(s.cond):
                for b in s.body:
                    exec_stmt(b)
        elif isinstance(s, If):
            try:
                c = ev(s.cond)
            except _Missing:
                return
            for b in (s.then if c else s.els):
                exec_stmt(b)

    for s in prog.body:
        exec_stmt(s)
    return {n: env[n] for n in prog.outputs}

"""Per-round lineage for surgical shard recovery (DESIGN.md §13).

Spark survives worker loss because every RDD partition carries its
lineage — the deterministic recipe that recomputes just that partition
from surviving parents (Gittens et al. 1607.01335 call this out as the
decisive operational advantage over C+MPI at scale).  Our plan IR
already contains everything such a recipe needs: the §4 round taxonomy
fixes HOW each node executes on a mesh, `dist_analysis` fixes WHERE
each operand lives, and rounds are pure functions of their inputs.
This pass makes the recipe explicit: it annotates every top-level plan
node (and every member of a `FusedRound` region) with a `RoundLineage`
describing, for shard k of the round's output,

  * which input arrays feed it and how each is reachable after shard k's
    worker died —
      ``rep``      replicated: every surviving device holds a full copy,
                   re-reading it is free;
      ``aligned``  ONED_ROW/ONED_VAR block aligned with the round axis:
                   the recompute needs BLOCK k of the array, re-fetched
                   from the host/global copy or replayed from the last
                   loop-carry snapshot;
      ``gathered`` sharded but read through an all_gather inside the
                   round: any surviving shard already materialized the
                   full array during the round, so recovery reads the
                   gathered copy;
  * what the round writes and under which taxonomy class (``store`` /
    ``reduce`` / ``scalar`` / ``rebalance`` — the class picks the
    recovery protocol in distributed.py: aligned stores recompute shard
    k's block surgically, reduce rounds with a replicated destination
    need nothing, reduce rounds with a sharded destination replay the
    cached round executable and re-slice);
  * its `depth` — the longest producer chain from program inputs to
    this round, i.e. how many upstream rounds a from-scratch
    reconstruction of its inputs would replay.  Recovery itself never
    replays the chain (inputs survive in the host env / peer replicas);
    the depth is the ledger's measure of how much work lineage-based
    recovery SAVED versus a restart, reported on every ``recovered:``
    line.

The pass is analysis-only: it never reorders, rewrites or re-classifies
nodes, and single-device execution ignores the annotation entirely.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from . import plan as P
from .dist_analysis import Dist, aligned_reads, gathers_of, round_axis

__all__ = ["RoundLineage", "compute_lineage", "pass_lineage",
           "explain_lineage"]


@dataclass(frozen=True)
class RoundLineage:
    """The recovery recipe for one round's lost output shard."""

    axis: Optional[str]                    # shard axis var; None = replicated
    writes: tuple[tuple[str, str], ...]    # (array, store|reduce|scalar|...)
    reads: tuple[tuple[str, str], ...]     # (array, rep|aligned|gathered)
    depth: int = 0                         # longest producer chain feeding us

    @property
    def recoverable(self) -> bool:
        """A replicated round loses nothing when a worker dies (every
        survivor holds the result); a sharded round is recoverable
        because every read kind above names a surviving source."""
        return True

    def read_kind(self, array: str) -> Optional[str]:
        for name, kind in self.reads:
            if name == array:
                return kind
        return None

    def pretty(self) -> str:
        w = ", ".join(f"{a}:{k}" for a, k in self.writes) or "·"
        r = ", ".join(f"{a}:{k}" for a, k in self.reads) or "·"
        ax = self.axis or "rep"
        return f"axis={ax} depth={self.depth} writes[{w}] reads[{r}]"


def _write_kind(node) -> str:
    if isinstance(node, P.Rebalance):
        return "rebalance"
    if isinstance(node, P.ScalarReduce):
        return "scalar"
    if isinstance(node, P.REDUCE_NODES):
        return "reduce"
    return "store"


def _read_kind(node, name: str, axis, dists: dict) -> str:
    d = dists.get(name, Dist.REP)
    if d == Dist.REP:
        return "rep"
    if axis is not None and name in aligned_reads(node, axis):
        return "aligned"
    return "gathered"


def _leaf_lineage(node, dists: dict, depth_of: dict) -> RoundLineage:
    axis = round_axis(node)
    dest = getattr(node, "dest", None)
    writes = ((dest, _write_kind(node)),) if dest is not None else ()
    reads = tuple(
        (name, _read_kind(node, name, axis, dists))
        for name in sorted(gathers_of(node)) if name != dest)
    depth = 1 + max((depth_of.get(name, 0) for name, _k in reads), default=0)
    return RoundLineage(axis=axis, writes=writes, reads=reads, depth=depth)


def _fused_lineage(parts, dists: dict, depth_of: dict) -> RoundLineage:
    """A Fused node (one space, parallel parts) or a FusedRound region
    (sequential members) recovers as one unit: the union of its members'
    recipes.  An array both written and read inside the region counts
    only as a write — the region re-derives it during replay."""
    writes: list = []
    reads: dict = {}
    depth = 0
    axis = None
    written: set = set()
    for p in parts:
        sub = (_fused_lineage(p.parts, dists, depth_of)
               if isinstance(p, (P.Fused, P.FusedRound))
               else _leaf_lineage(p, dists, depth_of))
        if sub.axis is not None:
            axis = axis or sub.axis
        depth = max(depth, sub.depth)
        for a, k in sub.writes:
            if a not in written:
                written.add(a)
                writes.append((a, k))
        for a, k in sub.reads:
            if a not in written:
                # later members' aligned reads of earlier members' outputs
                # never degrade an already-recorded external read kind
                reads.setdefault(a, k)
    return RoundLineage(axis=axis, writes=tuple(writes),
                        reads=tuple(sorted(reads.items())), depth=depth)


def compute_lineage(nodes, dists: dict) -> None:
    """Annotate every node in `nodes` (recursing into SeqLoop bodies and
    FusedRound regions) with `node.lineage`.  `dists` is the program's
    {array: Dist} map from the distribution analysis."""
    depth_of: dict = {}

    def visit(ns):
        for n in ns:
            if isinstance(n, P.SeqLoop):
                # the loop body re-runs every iteration; carries written
                # inside feed the next iteration's reads, so a carry's
                # depth is the deepest body round + 1 (one replayed round
                # per carry per iteration — recovery restores carries
                # from the peer-replica / checkpoint tier instead)
                visit(n.body)
                body_depth = max((m.lineage.depth for m in n.body
                                  if getattr(m, "lineage", None) is not None),
                                 default=0)
                n.lineage = RoundLineage(
                    axis=None,
                    writes=tuple((c, "carry") for c in n.carry),
                    reads=(), depth=body_depth + 1)
                for c in n.carry:
                    depth_of[c] = n.lineage.depth
                continue
            if isinstance(n, (P.Fused, P.FusedRound)):
                if isinstance(n, P.FusedRound):
                    visit(n.parts)     # members also carry their own recipe
                    lin = _fused_lineage(n.parts, dists, depth_of)
                else:
                    lin = _fused_lineage(n.parts, dists, depth_of)
                n.lineage = lin
            else:
                n.lineage = _leaf_lineage(n, dists, depth_of)
            for a, _k in n.lineage.writes:
                depth_of[a] = n.lineage.depth

    visit(nodes)


def pass_lineage(nodes, prog, config):
    """Pipeline pass (after round-fusion): record every round's recovery
    recipe.  `config.lineage=False` leaves nodes unannotated — the
    distributed executor then treats any shard loss as a ladder event
    (the pre-§13 behaviour)."""
    if not getattr(config, "lineage", True):
        return nodes
    from .dist_analysis import collect
    compute_lineage(nodes, collect(nodes))
    return nodes


def explain_lineage(nodes, name: str = "") -> str:
    """Golden-testable rendering of the recovery recipes, one line per
    annotated round, mirroring explain_rounds()' shape."""
    out = [f"== round lineage{': ' + name if name else ''} =="]

    def visit(ns, indent=0):
        for n in ns:
            lin = getattr(n, "lineage", None)
            pre = "  " * indent
            head = n.describe() if hasattr(n, "describe") else type(n).__name__
            out.append(f"{pre}{head}")
            if lin is not None:
                out.append(f"{pre}    lineage: {lin.pretty()}")
            if isinstance(n, P.SeqLoop):
                visit(n.body, indent + 1)
            elif isinstance(n, (P.Fused, P.FusedRound)):
                visit(n.parts, indent + 1)

    visit(nodes)
    return "\n".join(out)

"""Chunked out-of-core execution (DESIGN.md §12).

The capacity tier of the degradation ladder: when a call's estimated
peak (core/memest.py) exceeds the memory budget — or an all-resident
attempt dies with a classified capacity error — the plan is rewritten
so its bag-consuming nodes stream the bag through device-resident
destination accumulators in fixed-size row tiles, the
`kernels/flash_attention.py` streaming-accumulator idiom lifted from
one Pallas kernel to the plan level:

  * `chunk_plan` groups maximal runs of chunk-safe single-bag nodes
    into `ChunkLoop`s — a `SeqLoop` subclass, so the loop inherits the
    plan's explain/carry/checkpoint contracts (`plan.seq_loops`
    enumerates it; `runtime/ft.LoopRunner` checkpoints its carry per
    chunk with zero new code);
  * `ChunkRunner` keeps the bag columns HOST-side (numpy), jits one
    step function per loop+shape class with the destination dict
    donated (peak device bytes = O(tile + dests)), and overlaps the
    next tile's host→device transfer with the current step's async
    dispatch (double-buffered prefetch);
  * the tile rides the executor's existing pad/mask machinery
    (`ExecContext.bag_offsets`/`bag_limits`, paper §3.4): the offset
    globalizes the bag index var, the limit masks the zero-padded tail
    of the last tile, so no node body changes at all.

Bit-identity: the scatter backend of SegmentReduce ⊕-accumulates
directly into the RUNNING destination (`dest.at[keys].add(val)`), so
splitting the bag into tiles only reassociates the fold as
`(((dest ⊕ t1) ⊕ t2) ⊕ …)` — the same left-fold, in the same row
order, as the single all-resident scatter.  `chunk_plan` therefore
pins grouped SegmentReduces to the scatter backend and disables
hot-key salting inside chunk bodies (a [K,S] salted partial is folded
per tile — a different association).  ScalarReduce chunks combine
per-tile partials with ⊕ — exact for min/max, reassociated (allclose)
for float +/*.

Fault sites `lower.chunk_step` / `lower.chunk_prefetch` fire before
every step dispatch and tile transfer; transients retry at chunk
granularity, capacity errors propagate to the halving wrapper in
`CompiledProgram._run_chunked`.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from . import faults as F
from . import plan as P
from .dist_analysis import aligned_reads, gathers_of

__all__ = ["ChunkLoop", "chunk_plan", "choose_chunk_rows", "ChunkRunner",
           "DEFAULT_CHUNK_ROWS"]

DEFAULT_CHUNK_ROWS = 4096


# ---------------------------------------------------------------------------
# the plan node
# ---------------------------------------------------------------------------

@dataclass
class ChunkLoop(P.SeqLoop):
    """Outer streaming loop over row tiles of one bag.  `cond` is None —
    the trip count is ceil(rows/tile), known only at run time from the
    concrete bag, so the ChunkRunner drives it host-side.  Reaching the
    plain executor (e.g. the interp oracle's plan walk, or an
    all-resident run of a chunked plan) degrades to simple sequencing of
    the body with the whole bag as one tile — same results."""
    chunk_bag: str = ""

    def describe(self) -> str:
        return (f"ChunkLoop(stream {self.chunk_bag} tiles, "
                f"carry={','.join(self.carry)})")


# ---------------------------------------------------------------------------
# the chunking pass
# ---------------------------------------------------------------------------

_CHUNK_LEAVES = (P.SegmentReduce, P.Scatter, P.ScalarReduce, P.AxisReduce,
                 P.MapExpr)


def _bag_axis(node):
    space = getattr(node, "space", None)
    if space is None:
        return None, None
    bags = [a for a in space.axes if a.kind == "bag"]
    if len(bags) != 1:
        return None, None
    return bags[0].bag, bags[0].var


def _chunkable(node) -> bool:
    """One bag axis, and every row tile's contribution ⊕-folds into the
    destination independently of the other tiles."""
    if isinstance(node, P.Fused):
        return (_bag_axis(node)[0] is not None
                and all(isinstance(p, _CHUNK_LEAVES) for p in node.parts))
    if not isinstance(node, _CHUNK_LEAVES):
        return False
    bag, var = _bag_axis(node)
    if bag is None:
        return False
    if isinstance(node, P.MapExpr) and not isinstance(node, P.AxisReduce):
        # a store only chunks when each tile writes its own rows: the bag
        # axis var must key the destination
        if node.key_axes is None or var not in node.key_axes:
            return False
    return True


def _reads_ok(node, gdests: set, bag_var: str) -> bool:
    """May `node` join a group whose earlier members write `gdests`?
    Only if every read of those still-accumulating destinations is
    row-local (leading-indexed by the bag axis var): tile c reads only
    rows tile c just wrote.  Any other read would observe a partial
    fold."""
    if not gdests:
        return True
    aligned = aligned_reads(node, bag_var)
    gathered = set(gathers_of(node))
    for name in gdests:
        if name in gathered and name not in aligned:
            return False
        if name not in gathered and name in getattr(node, "reads", frozenset()):
            return False              # scalar/whole-array read of a partial
    return True


def _pin_bit_identical(node):
    """Copy a node for a chunk body, pinning choices that keep the tiled
    fold bit-identical to the all-resident one (module docstring)."""
    n2 = copy.copy(node)
    if isinstance(n2, P.Fused):
        n2.parts = [_pin_bit_identical(p) for p in node.parts]
        return n2
    if isinstance(n2, P.SegmentReduce):
        if "scatter" in (n2.candidates or ()):
            n2.backend = "scatter"
        n2.salt = 1                   # no hot-key spreading inside a tile
    return n2


def _make_loop(group: list, bag: str) -> ChunkLoop:
    body = [_pin_bit_identical(n) for n in group]
    carry: list = []
    for n in group:
        for d in P.dests_of(n):
            if d not in carry:
                carry.append(d)
    reads = frozenset().union(*(getattr(n, "reads", frozenset())
                                for n in group))
    return ChunkLoop(stmt=group[0].stmt, space=group[0].space,
                     reads=reads, cond=None, body=body,
                     carry=tuple(carry), chunk_bag=bag)


def chunk_plan(nodes, prog=None):
    """Rewrite a plan so bag-consuming nodes stream: returns
    (new_plan, n_chunk_loops).  Non-bag nodes and unchunkable shapes run
    all-resident between the streaming loops — correctness never depends
    on a node being grouped, only peak memory does."""
    out: list = []
    nloops = 0
    group: list = []
    gbag = gvar = None
    gdests: set = set()

    def flush():
        nonlocal group, gbag, gvar, gdests, nloops
        if group:
            out.append(_make_loop(group, gbag))
            nloops += 1
        group, gbag, gvar, gdests = [], None, None, set()

    for n in P.flatten(nodes):
        if isinstance(n, P.SeqLoop):
            flush()
            body2, k = chunk_plan(n.body, prog)
            if k:
                n2 = copy.copy(n)
                n2.body = body2
                out.append(n2)
                nloops += k
            else:
                out.append(n)
            continue
        if _chunkable(n):
            bag, var = _bag_axis(n)
            # a second writer of a group destination must NOT interleave
            # with the first at tile granularity: the all-resident fold
            # finishes one node's contributions before the next begins
            same_dest = any(d in gdests for d in P.dests_of(n))
            if group and (bag != gbag or same_dest
                          or not _reads_ok(n, gdests, gvar)):
                flush()
            if not group:
                gbag, gvar = bag, var
            group.append(n)
            gdests.update(P.dests_of(n))
        else:
            flush()
            out.append(n)
    flush()
    return out, nloops


# ---------------------------------------------------------------------------
# chunk sizing
# ---------------------------------------------------------------------------

def choose_chunk_rows(est, budget: int, n_rows: int | None = None) -> int:
    """Largest power-of-two tile with fixed + rows·per_row ≤ budget
    (per_row already charges two tiles for the prefetch double buffer)."""
    per = max(1, est.per_row())
    avail = int(budget) - est.fixed_bytes
    if avail <= per:
        rows = 1
    else:
        rows = 1 << (int(avail // per).bit_length() - 1)
    if n_rows:
        rows = min(rows, int(n_rows))
    return max(1, rows)


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

class ChunkRunner:
    """Executes the chunked form of a CompiledProgram's plan.  Bags stay
    host-side numpy; everything else follows prepare_env.  One jitted
    step function per (ChunkLoop, tile/shape class), destinations
    donated across chunks."""

    def __init__(self, cp):
        self.cp = cp
        self._plan = None
        self._nloops = 0
        self._step_cache: dict = {}
        self.last_chunk_rows: int | None = None
        self.chunks_run = 0

    @property
    def plan(self):
        if self._plan is None:
            self._plan, self._nloops = chunk_plan(self.cp.plan,
                                                  self.cp.program)
        return self._plan

    @property
    def n_chunk_loops(self) -> int:
        _ = self.plan
        return self._nloops

    def explain(self) -> str:
        return P.explain(self.plan, name=f"{self.cp.program.name} [chunked]",
                         decisions=self.cp.executor.decisions)

    # ---- env ----
    def prepare_env(self, inputs: dict) -> dict:
        env = {}
        for name, t in self.cp.program.params.items():
            v = inputs[name]
            if t.kind == "dim":
                env[name] = int(v)
            elif t.kind == "bag":
                cols = v if isinstance(v, tuple) else (v,)
                # numpy mirror of prepare_env's device placement: same
                # canonicalized dtypes, so tiles match all-resident bits
                env[name] = tuple(
                    np.asarray(c, jax.dtypes.canonicalize_dtype(
                        np.asarray(c).dtype)) for c in cols)
            elif t.kind in ("vector", "matrix", "map"):
                env[name] = jnp.asarray(
                    v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = jnp.asarray(v)
        return env

    # ---- driving ----
    def run(self, inputs: dict, *, chunk_rows: int,
            observer=None, loop_state=None) -> dict:
        """Same contract as CompiledProgram.run / run_stepwise: observer
        (when given) fires per top-level loop iteration — per CHUNK for a
        ChunkLoop — and `loop_state` fast-forwards both loop kinds, which
        is what makes LoopRunner resume chunk-granular."""
        env = self.prepare_env(inputs)
        self.last_chunk_rows = int(chunk_rows)
        li = 0
        for node in self.plan:
            if isinstance(node, ChunkLoop):
                st = (loop_state or {}).get(li)
                self._stream(node, env, chunk_rows, li=li,
                             observer=observer, state=st)
                li += 1
            elif isinstance(node, P.SeqLoop):
                st = (loop_state or {}).get(li)
                self._host_loop(node, env, chunk_rows, li=li,
                                observer=observer, state=st)
                li += 1
            else:
                self._resident(node, env)
        return {n: env[n] for n in self.cp.program.outputs}

    def _resident(self, node, env):
        from .lower import _EMPTY_CTX
        self.cp.executor.execute([node], env, _EMPTY_CTX)

    def _host_loop(self, node, env, chunk_rows, *, li, observer, state):
        """A SeqLoop whose body streams: host-driven (the chunk loop
        inside cannot live in a lax.while_loop), checkpointed per
        ITERATION exactly like run_stepwise's host-driven loops."""
        ex = self.cp.executor
        it = 0
        if state is not None:
            it, carry = state
            for c in node.carry:
                env[c] = jnp.asarray(carry[c])
        while bool(ex.eval_scalar(node.cond, env)):
            F.site("lower.loop_iter", loop=li, iteration=it)
            for b in node.body:
                if isinstance(b, ChunkLoop):
                    self._stream(b, env, chunk_rows, li=None,
                                 observer=None, state=None)
                else:
                    self._resident(b, env)
            it += 1
            if observer is not None:
                observer(li, it, {c: env[c] for c in node.carry})

    # ---- the stream ----
    def _stream(self, node: ChunkLoop, env, chunk_rows, *, li,
                observer, state):
        bag = node.chunk_bag
        cols = env[bag]
        n = int(cols[0].shape[0]) if cols else 0
        if n == 0:
            return                     # ⊕ over an empty bag contributes identity
        tile = max(1, min(int(chunk_rows), n))
        nchunks = -(-n // tile)
        start = 0
        # fresh device copies: the step donates the dest dict every chunk,
        # and jnp.asarray would alias a caller's jax array — donation must
        # only ever consume our own streaming state
        dests = {d: jnp.array(env[d], copy=True) for d in node.carry}
        if state is not None:
            start, carry = state
            dests = {d: jnp.array(carry[d], copy=True) for d in node.carry}
        step = self._step_fn(node, env, tile)

        def tile_cols(c):
            lo = c * tile
            view = tuple(col[lo:lo + tile] for col in cols)
            if view[0].shape[0] < tile:          # zero-pad the last tile;
                pad = tile - view[0].shape[0]    # bag_limits masks the tail
                view = tuple(np.concatenate(
                    [v, np.zeros((pad,) + v.shape[1:], v.dtype)])
                    for v in view)
            return view

        def prefetch(c):
            def attempt():
                F.site("lower.chunk_prefetch", loop=li, chunk=c)
                return jax.device_put(tile_cols(c))
            return F.run_with_retries(attempt, policy=self.cp.policy,
                                      ledger=self.cp.faults,
                                      label=f"prefetch[{bag}]")

        nxt = prefetch(start) if start < nchunks else None
        for c in range(start, nchunks):
            cur, nxt = nxt, None

            def attempt(c=c, cur=cur):
                F.site("lower.chunk_step", loop=li, chunk=c)
                return step(dests, cur, jnp.int32(c * tile), jnp.int32(n))

            # dispatch is async: the step computes while the next tile
            # crosses host→device (the double buffer)
            new_dests = F.run_with_retries(attempt, policy=self.cp.policy,
                                           ledger=self.cp.faults,
                                           label=f"chunk[{bag}]")
            if c + 1 < nchunks:
                nxt = prefetch(c + 1)
            dests = new_dests
            self.chunks_run += 1
            if observer is not None and li is not None:
                observer(li, c + 1, dict(dests))
        env.update(dests)

    def _step_fn(self, node: ChunkLoop, env, tile: int):
        from .lower import ExecContext
        bag = node.chunk_bag
        statics = {k: v for k, v in env.items() if isinstance(v, int)}
        rest_names = sorted(
            r for r in node.reads
            if r in env and r != bag and r not in node.carry
            and not isinstance(env[r], int))
        rest = {r: env[r] for r in rest_names}

        def sig(v):
            return (tuple(jnp.shape(v)), str(jnp.asarray(v).dtype))

        key = (id(node), tile, tuple(sorted(statics.items())),
               tuple((d, sig(env[d])) for d in node.carry),
               tuple((r, sig(rest[r])) for r in rest_names),
               tuple((c.shape[1:], str(c.dtype)) for c in env[bag]))
        fn = self._step_cache.get(key)
        if fn is None:
            body, carry, executor = node.body, node.carry, self.cp.executor

            def traced(dests, tcols, off, lim, rest_args,
                       _statics=dict(statics)):
                e = dict(_statics)
                e.update(rest_args)
                e.update(dests)
                e[bag] = tcols
                ctx = ExecContext(bag_offsets={bag: off},
                                  bag_limits={bag: lim})
                executor.execute(body, e, ctx)
                return {d: e[d] for d in carry}

            fn = jax.jit(traced, donate_argnums=(0,))
            self._step_cache[key] = fn
        return lambda dests, tcols, off, lim: fn(dests, tcols, off, lim, rest)

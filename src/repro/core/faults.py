"""Fault-injection runtime + failure policy engine (DESIGN.md §11).

The paper targets Spark because the RDD substrate supplies fault
tolerance for free; this module is the JAX reproduction's equivalent
substrate, split into three pieces every layer shares:

* **Injection harness** — named sites (`SITES`) threaded through the
  executor (`lower.py`), the distributed backend (`distributed.py`) and
  the serving layer (`serve/plans.py`).  `site(name, **payload)` is a
  no-op unless a `FaultInjector` is active (one global read per call),
  in which case scripted `FaultSpec`s fire on the Nth hit: transient
  UNAVAILABLE-style errors, RESOURCE_EXHAUSTED capacity errors,
  deterministic user errors, NaN poisoning of a request lane, or a
  slow-round straggler that advances the injected clock.  Everything is
  deterministic — tests replay exact schedules.

* **Classifier + retry policy** — `classify(exc)` sorts any exception
  into transient / capacity / deterministic / shard_lost (a peer died
  holding data → the surgical-recovery lane, DESIGN.md §13);
  `run_with_retries` retries
  transients at the SAME ladder level with bounded exponential backoff,
  and re-raises everything else for the caller to descend the ladder.
  Deterministic errors get AT MOST one ladder descent before they
  surface (a user error reproduces at every level — retrying it forever
  would hide it); capacity errors descend immediately (the same
  allocation will fail again at this level).

* **Failure ledger** — one `FaultLedger` per compiled program (shared
  with its distributed wrapper) recording retries, ladder descents,
  recoveries and straggler events; `CompiledProgram.explain_faults()`
  renders it golden-testably next to explain()/explain_rounds().
"""
from __future__ import annotations

import re
import time
import zlib
from collections import Counter
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np


def checksum(x) -> int:
    """crc32 integrity stamp over an array's dtype, shape and raw bytes —
    the ONE checksum every robustness tier shares: checkpoint snapshots
    (checkpoint/manager.py), peer-replicated loop carries (runtime/ft.py)
    and shard-recovery verification (distributed._recover_shard) all stamp
    and verify with this, so a block recovered from any tier checks out
    against a stamp taken by any other."""
    a = np.asarray(x)
    h = zlib.crc32(str((a.dtype.str, a.shape)).encode())
    return zlib.crc32(np.ascontiguousarray(a).tobytes(), h) & 0xFFFFFFFF

# every named injection site threaded through the system; `site()`
# rejects names outside this registry so a renamed call-site cannot
# silently detach its scripted faults
SITES = frozenset({
    "lower.whole_trace",     # whole-program trace + call (lower._run_whole)
    "lower.node",            # per-node guard (PlanExecutor.run_node)
    "lower.loop_iter",       # host-driven SeqLoop iteration (run_stepwise)
    "dist.fused_compile",    # fused-region shard_map compile/exec
    "dist.round_exec",       # per-round jit+shard_map execution
    "dist.exchange",         # collective exchange (trace-time, in-body)
    "serve.stack",           # host-side batch stacking (poisonable)
    "serve.device_put",      # host→device transfer of a stacked batch
    "serve.batched_call",    # vmapped whole-program dispatch
    "lower.chunk_step",      # out-of-core chunk step dispatch (chunked.py)
    "lower.chunk_prefetch",  # out-of-core tile host→device prefetch
    "dist.shard_lost",       # post-round shard-partition loss (surgical
    #                          recovery, DESIGN.md §13) — fires AFTER a
    #                          round executed, modelling a worker dying
    #                          while holding its output partition
})

KINDS = ("transient", "capacity", "deterministic", "poison", "slow",
         "shard_lost")


class FaultError(Exception):
    """Base class of injected faults (classification is by subclass)."""


class TransientFault(FaultError):
    """Scripted UNAVAILABLE-style error: retryable at the same level."""


class CapacityFault(FaultError):
    """Scripted RESOURCE_EXHAUSTED-style error: descend, don't retry."""


class DeterministicFault(FaultError):
    """Scripted user error: reproduces at every level, surfaces after at
    most one ladder descent."""


class ShardLostFault(FaultError):
    """A shard's output partition was lost after a round executed (worker
    death).  `shard` is the lost partition index; the distributed executor
    recovers it surgically from lineage (DESIGN.md §13) instead of
    descending the ladder — unless the same shard was already lost within
    the policy TTL."""

    def __init__(self, msg: str, shard: int = 0):
        super().__init__(msg)
        self.shard = int(shard)


class PoisonedOutput(Exception):
    """A served lane carried non-finite values (serve nan_guard)."""


@dataclass
class FaultSpec:
    """One scripted fault: fire at `site` on hits `nth..nth+times-1`
    (1-based, counted per site).  `rid`-matched specs ignore the hit
    counter and instead fire whenever the request id appears in the
    site's payload (serving sites pass `rids`), up to `times` firings —
    that is how a single poisoned request deterministically fails every
    batch it rides in.  `delay_s` is the injected-clock advance of a
    `slow` spec; `message` overrides the raised text; `shard` is the
    partition index a `shard_lost` spec kills."""

    site: str
    kind: str = "transient"
    nth: int = 1
    times: int = 1
    rid: int | None = None
    delay_s: float = 0.0
    message: str = ""
    shard: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown injection site {self.site!r} "
                             f"(registry: {sorted(SITES)})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class RetryPolicy:
    """Bounded retry + backoff for transients, and the expiry of the
    per-signature whole-program disable memo (DESIGN.md §11 table)."""

    max_retries: int = 2       # same-level re-attempts for transients
    backoff_s: float = 0.02    # initial backoff, doubled per attempt
    max_backoff_s: float = 0.5
    disable_ttl: int = 8       # eager runs a failed whole signature sits
    #                            out before its trace is re-attempted
    shard_loss_ttl_s: float = 60.0   # a SECOND loss of the same shard
    #                            within this window escalates to the
    #                            ladder (the "worker" is flapping —
    #                            recomputing onto it again is throwaway)


class FaultInjector:
    """Deterministic scripted-fault dispenser; activate with inject()."""

    def __init__(self, *specs: FaultSpec, clock=None):
        self.specs = list(specs)
        self.clock = clock              # needs .advance(s) for slow specs
        self.hits: Counter = Counter()  # site → calls seen
        self.fired: list[dict] = []     # every firing, in order
        self._rid_left = {id(s): s.times for s in self.specs
                          if s.rid is not None}

    def fire(self, name: str, payload: dict) -> None:
        self.hits[name] += 1
        k = self.hits[name]
        for s in self.specs:
            if s.site != name:
                continue
            if s.rid is not None:
                rids = payload.get("rids") or ()
                if s.rid not in rids or self._rid_left[id(s)] <= 0:
                    continue
                self._rid_left[id(s)] -= 1
            elif not (s.nth <= k < s.nth + s.times):
                continue
            self.fired.append({"site": name, "kind": s.kind, "hit": k,
                               "rid": s.rid})
            self._act(s, name, k, payload)

    def _act(self, s: FaultSpec, name: str, k: int, payload: dict) -> None:
        if s.kind == "slow":
            if self.clock is not None and hasattr(self.clock, "advance"):
                self.clock.advance(s.delay_s)
            return
        if s.kind == "poison":
            # NaN-poison the matched request's lane in the stacked batch
            # (serve.stack passes mutable numpy arrays + the lane rids)
            arrays = payload.get("arrays")
            rids = payload.get("rids") or ()
            if arrays is None or s.rid not in rids:
                return
            lane = rids.index(s.rid)
            for v in arrays.values():
                for col in (v if isinstance(v, tuple) else (v,)):
                    if np.issubdtype(col.dtype, np.floating):
                        col[lane] = np.nan
            return
        msg = s.message or f"injected {s.kind} fault at {name} (hit {k})"
        if s.kind == "transient":
            raise TransientFault(f"UNAVAILABLE: {msg}")
        if s.kind == "capacity":
            raise CapacityFault(f"RESOURCE_EXHAUSTED: {msg}")
        if s.kind == "shard_lost":
            raise ShardLostFault(f"shard {s.shard} lost: {msg}", s.shard)
        raise DeterministicFault(msg)


_ACTIVE: FaultInjector | None = None


def active() -> FaultInjector | None:
    return _ACTIVE


@contextmanager
def inject(*specs: FaultSpec, clock=None):
    """Activate a scripted injector for the with-block (tests/benches).
    Yields the injector so callers can assert on hits/fired."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = inj = FaultInjector(*specs, clock=clock)
    try:
        yield inj
    finally:
        _ACTIVE = prev


def site(name: str, **payload) -> None:
    """The hook placed at every injection site.  Zero-cost when no
    injector is active; under jit/vmap it fires at TRACE time only
    (python-level), which is exactly where compile faults live."""
    inj = _ACTIVE
    if inj is not None:
        inj.fire(name, payload)


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------

_TRANSIENT_TOKENS = ("UNAVAILABLE", "DEADLINE_EXCEEDED", "ABORTED",
                     "connection reset", "socket closed", "NCCL")
# matched case-insensitively against str(exc) — real allocator messages
# disagree on casing across backends ("RESOURCE_EXHAUSTED: Out of
# memory", "Resource exhausted: ...", CUDA's "out of memory", TPU's
# "Ran out of memory in memory space hbm")
_CAPACITY_TOKENS = ("resource_exhausted", "resource exhausted",
                    "out of memory", "out_of_memory",
                    "ran out of memory", "failed to allocate",
                    "allocation failure", "hbm exhausted")
# "OOM" only as a standalone word — a bare substring would classify
# "bloom rebuild failed" as capacity
_OOM_WORD = re.compile(r"(?<![A-Za-z0-9])OOM(?![A-Za-z0-9])", re.IGNORECASE)
# real runtime errors that mean a peer/device DIED holding data — the
# surgical-recovery lane (DESIGN.md §13), distinct from transients (the
# data is gone, a same-level retry reads from a corpse) and from
# capacity (nothing is over budget)
_SHARD_LOST_TOKENS = ("device lost", "device unavailable",
                      "device_unavailable", "worker lost", "peer down",
                      "data transfer failed", "slice has been terminated")
# exception TYPES that mean capacity regardless of message wording:
# jaxlib's XlaRuntimeError subclasses (XlaRuntimeError itself carries the
# status token, but backends also raise dedicated OOM types), numpy's
# _ArrayMemoryError (a MemoryError subclass, caught above), torch-style
# OutOfMemoryError — matched by NAME up the MRO so classification never
# imports backend modules
_CAPACITY_TYPE_NAMES = frozenset({"OutOfMemoryError", "XlaOomError"})


def classify(exc: BaseException) -> str:
    """transient / capacity / deterministic.  Injected faults classify by
    type; real runtime errors by exception type name and the XLA status
    tokens their messages carry, case-insensitively (an honest
    ``XlaRuntimeError: RESOURCE_EXHAUSTED`` from a too-big allocation
    lands in the same capacity lane as the scripted one).  Anything
    unrecognized is deterministic — the safe default, because retrying an
    unknown error forever is the one behaviour the ladder must never
    exhibit."""
    if isinstance(exc, TransientFault):
        return "transient"
    if isinstance(exc, ShardLostFault):
        return "shard_lost"
    if isinstance(exc, CapacityFault) or isinstance(exc, MemoryError):
        return "capacity"
    if isinstance(exc, DeterministicFault):
        return "deterministic"
    if any(t.__name__ in _CAPACITY_TYPE_NAMES for t in type(exc).__mro__):
        return "capacity"
    s = str(exc)
    low = s.lower()
    if any(t in low for t in _CAPACITY_TOKENS) or _OOM_WORD.search(s):
        return "capacity"
    if any(t in low for t in _SHARD_LOST_TOKENS):
        return "shard_lost"
    if any(t in s for t in _TRANSIENT_TOKENS):
        return "transient"
    return "deterministic"


# ---------------------------------------------------------------------------
# failure ledger
# ---------------------------------------------------------------------------

@dataclass
class FaultLedger:
    """Per-program record of everything the failure policy did:
    retries, ladder descents, recoveries, straggler rounds.  `clock` and
    `sleep` are injectable (fake-clock tests never sleep for real); the
    straggler watchdog is the runtime/ft.py trailing-median idiom applied
    to round/batch wall times."""

    name: str = ""
    events: list = field(default_factory=list)
    counters: Counter = field(default_factory=Counter)
    straggler_factor: float = 3.0

    def __post_init__(self):
        self.clock = time.monotonic
        self.sleep = time.sleep
        self._times: list[float] = []
        self._last_med = 0.0           # trailing median at the last
        #                                straggler firing (speculation math)
        self.spec_saved_s = 0.0        # wall time the speculative copies
        #                                won back (bench accounting)
        self.level_reached = ""        # deepest ladder level this program
        #                                ever descended to

    def record(self, kind: str, label: str, detail: str = "") -> None:
        self.events.append((kind, label, detail))
        self.counters[kind] += 1

    def retry(self, label: str, exc, attempt: int, delay: float) -> None:
        self.record("retry", label,
                    f"{type(exc).__name__} attempt {attempt}, "
                    f"backoff {delay * 1e3:.0f}ms")

    def descend(self, frm: str, to: str, exc) -> None:
        self.level_reached = to
        self.record("descend", f"{frm}->{to}",
                    f"{classify(exc)}: {str(exc)[:96]}")

    def recover(self, label: str) -> None:
        self.record("recover", label)

    def recovered(self, label: str, detail: str = "") -> None:
        """Surgical shard recovery (lineage recompute / peer replica /
        speculative win) — distinct from `recover`, which marks a
        same-level RETRY succeeding."""
        self.record("recovered", label, detail)

    def note_time(self, label: str, dt: float) -> bool:
        """Straggler watchdog: a round exceeding straggler_factor × the
        trailing-median round time is an event (TrainRunner idiom).
        Returns True when the sample straggled.  A flagged sample is NOT
        folded into the trailing window — one genuine straggler must not
        drag the median up and mask the next one (two consecutive slow
        rounds both flag)."""
        window = self._times[-20:]
        straggled = False
        if len(window) >= 3:
            med = sorted(window)[len(window) // 2]
            if med > 0 and dt > self.straggler_factor * med:
                self._last_med = med
                self.record("straggler", label,
                            f"{dt * 1e3:.1f}ms vs median {med * 1e3:.1f}ms")
                straggled = True
        if not straggled:
            self._times.append(dt)
        return straggled

    def explain(self) -> str:
        """Golden-testable text form, the way explain()/explain_rounds()
        pin the plan: the counter summary line, then every event."""
        c = self.counters
        out = [f"== fault ledger: {self.name} ==",
               f"retries={c['retry']} descents={c['descend']} "
               f"recoveries={c['recover']} stragglers={c['straggler']}"
               + (f" shard-recovered={c['recovered']}"
                  if c["recovered"] else "")
               + (f" speculative={c['speculative']}"
                  if c["speculative"] else "")
               + (f"  ladder-level-reached={self.level_reached}"
                  if self.level_reached else "")]
        for kind, label, detail in self.events:
            out.append(f"  {kind:<9}[{label}]"
                       + (f" {detail}" if detail else ""))
        return "\n".join(out)


def run_with_retries(fn, *, policy: RetryPolicy, ledger: FaultLedger,
                     label: str, sleep=None):
    """Execute fn(), retrying TRANSIENT failures at the same ladder level
    with bounded exponential backoff.  Capacity and deterministic errors
    re-raise immediately — descending the ladder is the caller's move,
    and how far a deterministic error may descend (exactly one level) is
    enforced there.  Records retry + recover events in the ledger."""
    zzz = sleep if sleep is not None else ledger.sleep
    attempt = 0
    while True:
        try:
            out = fn()
            if attempt:
                ledger.recover(label)
            return out
        except Exception as ex:            # noqa: BLE001 — policy engine
            if classify(ex) != "transient" or attempt >= policy.max_retries:
                raise
            delay = min(policy.backoff_s * (2 ** attempt),
                        policy.max_backoff_s)
            attempt += 1
            ledger.retry(label, ex, attempt, delay)
            zzz(delay)

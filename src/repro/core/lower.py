"""Plan execution: physical-plan nodes → JAX.

The pipeline is  translate (Fig. 2) → passes.plan_program (operator
recognition, see passes.py) → PlanExecutor (this module).  The executor
performs NO recognition: every operator choice was made by the pass
pipeline; this module only materializes the chosen node, checking the
runtime guards (extents, packed-vs-dense inputs) that static planning
cannot see.  When a guard fails the executor walks the node's `fallback`
chain — results never change, only the operator used.

Node → JAX mapping:

  MapExpr         broadcasted value over the iteration space; full replace
                  or meshgrid .at[].set with drop semantics
  DenseMap        dense fast path: ONE vectorized jnp expression over whole
                  arrays / per-shard blocks — no index grids, gathers,
                  masks or scatters (guard: extents cover the destination
                  exactly) — else the general MapExpr path
  Scatter         .at[].set at computed keys, OOB rows dropped
  SegmentReduce   one of four backends, chosen at trace time by the
                  operator-selection subsystem (op_select.py, DESIGN.md
                  §8) from the node's candidate set: native scatter-⊕
                  with drop semantics (no identity segment array, no
                  index flattening), sort-based jax.ops.segment_⊕ over
                  sorted keys, one-hot dot_general on the MXU, or the
                  Pallas blocked one-hot kernel.  `backend="auto"`
                  resolves via the cost model / autotune cache against
                  the concrete (N, K, D, dtype, dest-sharding) shape
                  class; a concrete backend name pins the choice.  The
                  resolved decision is recorded (explain() prints it)
  AxisReduce      ⊕-reduce over contracted axes (Rule 17: no shuffle); a
                  `product` certificate contracts via jnp.einsum instead of
                  the dense grid (same operator, MXU materialization)
  EinsumContract  jnp.einsum over sliced operands (guard: offsets static
                  OR certified per-shard — aligned local blocks slice at
                  0, replicated operands via bounds-proven dynamic_slice;
                  pad limits only on the leading key axis) — else its
                  AxisReduce fallback
  TiledMatmul     block-sparse Pallas tile_matmul on the §5 packed lhs
                  (guard: lhs arrives as TiledMatrix) — else einsum
  ScalarReduce    total ⊕-reduce (+ any/all peephole for max/min of
                  float(bool)); `point` targets one destination cell
  SeqLoop         lax.while_loop over the mutated-variable carry
  Fused           parts executed against the shared iteration space

Distributed execution passes bag offsets/limits through ExecContext — plan
parameters, not executor state — so the same plan serves single-device,
shard_map and gspmd backends (see distributed.py).

The compiled program is a pure function dict->dict and is jit-compatible
(dims must be python ints: they define static shapes).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from . import faults as F
from . import plan as P
from .analysis import check as check_restrictions
from .comprehension import Get, pretty
from .loop_ast import (BinOp, Call, Const, Program, RejectionError, UnOp,
                       Var)
from .passes import PlanConfig, plan_program
from .translate import translate


# ---------------------------------------------------------------------------
# scalar op tables (public: distributed.py composes partials with these)
# ---------------------------------------------------------------------------

OPS = {
    "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply, "/": jnp.divide,
    "//": jnp.floor_divide, "%": jnp.mod, "**": jnp.power,
    "==": jnp.equal, "!=": jnp.not_equal, "<": jnp.less,
    "<=": jnp.less_equal, ">": jnp.greater, ">=": jnp.greater_equal,
    "and": jnp.logical_and, "or": jnp.logical_or,
}

FNS = {"sqrt": jnp.sqrt, "exp": jnp.exp, "log": jnp.log, "abs": jnp.abs,
       "sin": jnp.sin, "cos": jnp.cos, "tanh": jnp.tanh,
       "sigmoid": jax.nn.sigmoid, "float": lambda x: jnp.asarray(x, jnp.float32),
       "int": lambda x: jnp.asarray(x, jnp.int32),
       "min": jnp.minimum, "max": jnp.maximum,
       "where": lambda c, a, b: jnp.where(c, a, b)}

REDUCE = {"+": jnp.sum, "*": jnp.prod, "min": jnp.min, "max": jnp.max}
COMBINE = {"+": jnp.add, "*": jnp.multiply, "min": jnp.minimum,
           "max": jnp.maximum}


def identity(op: str, dtype) -> jnp.ndarray:
    """The ⊕ identity element for masked-out rows."""
    if op == "+":
        return jnp.zeros((), dtype)
    if op == "*":
        return jnp.ones((), dtype)
    big = jnp.asarray(np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating) \
        else jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return -big if op == "max" else big


def _scatter_op(ref, op: str):
    return {"+": ref.add, "*": ref.multiply, "min": ref.min, "max": ref.max}[op]


class Axes:
    """Materialized iteration space: ordered axes with concrete extents."""

    def __init__(self):
        self.order: list[str] = []
        self.extent: dict[str, int] = {}

    def add(self, name: str, n: int):
        self.order.append(name)
        self.extent[name] = n

    def pos(self, name: str) -> int:
        return self.order.index(name)

    def shape(self):
        return tuple(self.extent[a] for a in self.order)

    def expand(self, arr, axis_name: str):
        """1-D array along `axis_name` → broadcast rank."""
        shape = [1] * len(self.order)
        shape[self.pos(axis_name)] = -1
        return jnp.reshape(arr, shape)


@dataclass(frozen=True)
class ExecContext:
    """Per-call plan parameters for distributed execution: traced global
    index offsets for sharded bags, and logical bag lengths when columns
    were padded to a multiple of the shard count.

    The dense-array analogues (distribution analysis, DESIGN.md §6) reuse
    the same machinery for ONED_ROW arrays:

      row_offsets     array → traced global row index of the local block's
                      first row; the executor subtracts it so dim-0 reads
                      and writes of the array target the per-shard block
      array_limits    array → logical dim-0 length when rows were padded
                      to a multiple of the shard count; reads at global
                      row ≥ limit are masked and writes dropped, so pad
                      rows can never change a result (paper §3.4 empty-bag
                      semantics against the LOGICAL bound)
      axis_overrides  range-axis var → (offset, extent, limit, total): the
                      round localizes the axis to the shard's row block
                      exactly like a sharded bag axis (offset globalizes
                      the index var, rows beyond `limit` are masked out).
                      `total` is the STATIC padded global extent
                      (shards × extent): the bounds certificate for slicing
                      a replicated operand per shard — offset + extent ≤
                      total always, so when total ≤ the operand's physical
                      dim a lax.dynamic_slice can never clamp (DESIGN.md
                      §7).
      aligned         alignment certificates: names whose dim-0 LOCAL
                      block is exactly the round axis' override window
                      ([offset, offset+extent)).  distributed.py issues
                      one only when the distribution analysis proved every
                      read leading-indexed by the round axis AND the
                      physical rows tile exactly like the axis, so the
                      executor may treat the traced window start as a
                      static local 0.
      salts           group-by dest → salt factor S resolved by the
                      RUN-TIME hot-key probe (op_select.probe_hot_fraction
                      + choose_salt) for this call's concrete key data.
                      The executor spreads each key over S sub-
                      destinations (`key*S + salt`) and ⊕-folds the [K, S]
                      partial back, so skewed keys stop serializing the
                      scatter.  Static pins (`SegmentReduce.salt`, set by
                      the planner from `PlanConfig.skew_salting`) take
                      precedence; callers put the resolved dict in their
                      compile-cache key, since the decision changes the
                      traced computation.
    """
    bag_offsets: dict = field(default_factory=dict)
    bag_limits: dict = field(default_factory=dict)
    row_offsets: dict = field(default_factory=dict)
    array_limits: dict = field(default_factory=dict)
    axis_overrides: dict = field(default_factory=dict)
    aligned: frozenset = frozenset()
    salts: dict = field(default_factory=dict)


_EMPTY_CTX = ExecContext()


def salt_for_node(node, env, selector, skew_salting: str, *,
                  nshards: int = 1, bag_limits=None) -> int:
    """Run-time half of the hot-key salting decision for one group-by
    node: probe the CONCRETE key column host-side and ask the selector's
    cost model / cache for the salt factor (1 = do not salt).  Only fires
    in "auto" mode on nodes without a static pin, and only for the probe-
    able shape — a single key that IS a bag column (the word-count /
    group-by form), reduced into a 1-D destination.  Everything else keeps
    S=1: salting is an optimization, never a requirement."""
    if not isinstance(node, P.SegmentReduce) or node.salt is not None \
            or skew_salting != "auto":
        return 1
    if len(node.keys) != 1 or not isinstance(node.keys[0], Var):
        return 1
    dest = env.get(node.dest)
    if dest is None or len(jnp.shape(dest)) != 1:
        return 1
    kv = node.keys[0].name
    bag, col = None, 0
    for a in node.space.axes:
        if a.kind == "bag" and kv in a.vals:
            bag, col = a.bag, a.vals.index(kv)
            break
    if bag is None or bag not in env:
        return 1
    bv = env[bag]
    c = (bv if isinstance(bv, tuple) else (bv,))[col]
    if isinstance(c, jax.core.Tracer):
        return 1                  # under an outer trace: no concrete data
    n = int(c.shape[0])
    lim = (bag_limits or {}).get(bag)
    if lim is not None:
        n = min(n, int(lim))
    if n == 0:
        return 1
    from .op_select import probe_hot_fraction
    hot = probe_hot_fraction(np.asarray(c[:min(n, 4096)]))
    dec = selector.choose_salt(n=n, k=int(jnp.shape(dest)[0]), op=node.op,
                               nshards=nshards, hot_frac=hot)
    return int(dec.backend.split(":", 1)[1]) \
        if dec.backend.startswith("salt:") else 1


def collect_salts(nodes, env, selector, skew_salting: str, *,
                  nshards: int = 1, bag_limits=None) -> dict:
    """dest → salt factor for every probe-decided group-by in the plan
    (walks SeqLoop bodies and fused regions).  Callers thread the result
    through ExecContext.salts AND their compile-cache key — the factor is
    baked into the trace."""
    out: dict = {}
    def walk(ns):
        for n in ns:
            if isinstance(n, P.SeqLoop):
                walk(n.body)
            elif isinstance(n, (P.Fused, P.FusedRound)):
                walk(n.parts)
            else:
                s = salt_for_node(n, env, selector, skew_salting,
                                  nshards=nshards, bag_limits=bag_limits)
                if s > 1:
                    out[n.dest] = s
    walk(nodes)
    return out


# ---------------------------------------------------------------------------
# plan executor
# ---------------------------------------------------------------------------

class PlanExecutor:
    def __init__(self, prog: Program, selector=None):
        self.prog = prog
        # id(node) → the materialization the executor last chose for it
        # ("einsum", "mxu-einsum", "dense-store", "segment:scatter[cost]",
        # …).  Written at trace time; CompiledProgram.explain() and
        # DistributedProgram.explain_rounds() read it to report the ACTUAL
        # operator/backend of each compiled node or per-shard round.
        self.decisions: dict = {}
        self._selector = selector

    @property
    def selector(self):
        if self._selector is None:
            from .op_select import OpSelector
            self._selector = OpSelector()
        return self._selector

    def note(self, node, tag: str) -> None:
        self.decisions[id(node)] = tag

    # ---- static scalars (dims / range bounds) ----
    def static_int(self, e, env) -> int:
        if isinstance(e, Const):
            return int(e.value)
        if isinstance(e, Var):
            v = env[e.name]
            if isinstance(v, (int, np.integer)):
                return int(v)
            raise RejectionError(
                f"range bound '{e.name}' must be a static dim (python int)")
        if isinstance(e, BinOp):
            l = self.static_int(e.lhs, env)
            r = self.static_int(e.rhs, env)
            return int({"+": l + r, "-": l - r, "*": l * r,
                        "//": l // r, "/": l // r}[e.op])
        raise RejectionError(f"non-static range bound {e}")

    # ---- materialize an IterSpace against the env ----
    def build_space(self, space: P.IterSpace, env, ctx: ExecContext):
        ax = Axes()
        binding: dict[str, tuple] = {}  # var -> ("range", axis, lo)|("bagval", axis, col)
        for a in space.axes:
            if a.kind == "range":
                ov = ctx.axis_overrides.get(a.var)
                if ov is not None:      # localized to the shard's row block
                    off, ext, _lim, _tot = ov
                    ax.add(a.var, ext)
                    binding[a.var] = ("range", a.var, off)
                    continue
                lo = self.static_int(a.lo, env)
                hi = self.static_int(a.hi, env)
                ax.add(a.var, max(hi - lo, 0))
                binding[a.var] = ("range", a.var, lo)
            else:
                bagv = env[a.bag]
                cols = bagv if isinstance(bagv, tuple) else (bagv,)
                n = int(cols[0].shape[0])
                ax.add(a.var, n)
                binding[a.var] = ("range", a.var,
                                  ctx.bag_offsets.get(a.bag, 0))
        base_masks = []
        for a in space.axes:
            if a.kind == "range":
                ov = ctx.axis_overrides.get(a.var)
                if ov is not None and ov[2] is not None:
                    off, ext, lim, _tot = ov  # mask rows ≥ the logical extent
                    base_masks.append(ax.expand(
                        (off + jnp.arange(ext)) < lim, a.var))
                continue
            bagv = env[a.bag]
            cols = bagv if isinstance(bagv, tuple) else (bagv,)
            for j, v in enumerate(a.vals):
                binding[v] = ("bagval", a.var, cols[j])
            lim = ctx.bag_limits.get(a.bag)
            if lim is not None:
                off = binding[a.var][2]
                base_masks.append(ax.expand(
                    (off + jnp.arange(ax.extent[a.var])) < lim, a.var))
        return ax, binding, list(space.conds), base_masks

    # ---- expression evaluation over the iteration space ----
    def eval(self, e, env, ax: Axes, binding, masks: list,
             ctx: ExecContext = _EMPTY_CTX):
        if isinstance(e, Const):
            return jnp.asarray(e.value)
        if isinstance(e, Var):
            if e.name in binding:
                kind, axis, aux = binding[e.name]
                if kind == "range":
                    return ax.expand(aux + jnp.arange(ax.extent[axis]), axis)
                return ax.expand(aux, axis)
            return jnp.asarray(env[e.name])
        if isinstance(e, (P.Gather, Get)):
            arr = env[e.array]
            from .tiles import TiledMatrix, unpack
            if isinstance(arr, TiledMatrix):   # §5 fallback: unpack on read
                arr = unpack(arr)
            # identity-traversal broadcast: statically marked eligible, and
            # the runtime extents cover the array exactly (no gather).
            # Padded (array_limits) and localized (row_offsets) arrays never
            # qualify: their extents differ from the physical dim.
            bc_ok = e.broadcast_ok if isinstance(e, P.Gather) else True
            if bc_ok and len(e.idxs) == len(arr.shape) and \
                    e.array not in ctx.row_offsets and \
                    e.array not in ctx.array_limits and \
                    all(isinstance(ix, Var) and ix.name in binding
                        and binding[ix.name][0] == "range"
                        and isinstance(binding[ix.name][2], int)
                        and binding[ix.name][2] == 0
                        and ax.extent[ix.name] == d
                        for ix, d in zip(e.idxs, arr.shape)) and \
                    len({ix.name for ix in e.idxs}) == len(e.idxs):
                names = [ix.name for ix in e.idxs]
                shape = [1] * len(ax.order)
                perm_src = sorted(names, key=ax.pos)
                a2 = jnp.transpose(arr, [names.index(a) for a in perm_src])
                for a in perm_src:
                    shape[ax.pos(a)] = ax.extent[a]
                return jnp.reshape(a2, shape)
            idxs = [self.eval(i, env, ax, binding, masks, ctx)
                    for i in e.idxs]
            off = ctx.row_offsets.get(e.array)
            lim = ctx.array_limits.get(e.array)
            cooked = []
            for dim_i, (d, ix) in enumerate(zip(arr.shape, idxs)):
                ix = jnp.asarray(ix, jnp.int32)
                if dim_i == 0:
                    if lim is not None:     # logical bound, global coords
                        masks.append(ix < lim)
                    if off is not None:     # localize to the shard's block
                        ix = ix - off
                # uint32 reinterpretation: negatives wrap past any dim, so
                # ONE unsigned compare is the whole inRange check
                # ((ix >= 0) & (ix < d)) and the gather indexes unsigned
                iu = ix.astype(jnp.uint32)
                masks.append(iu < jnp.uint32(d))
                cooked.append(iu)
            # clip-mode gather on the unsigned indices: out-of-range rows
            # read a clamped row, and §3.4 empty-bag semantics live in the
            # recorded inRange MASK, which every consumer applies — the
            # gathered value at a dropped row is never observable.  Clamp
            # is one fusable op; a fill-mode gather would add a
            # compare+select pair per gather, measured ~20% slower on the
            # scatter-fed group-by path (pagerank's inner loop).
            if len(cooked) == 1:
                return jnp.take(arr, cooked[0], axis=0, mode="clip")
            return arr.at[tuple(jnp.broadcast_arrays(*cooked))].get(
                mode="clip")
        if isinstance(e, BinOp):
            return OPS[e.op](self.eval(e.lhs, env, ax, binding, masks, ctx),
                             self.eval(e.rhs, env, ax, binding, masks, ctx))
        if isinstance(e, UnOp):
            v = self.eval(e.e, env, ax, binding, masks, ctx)
            return -v if e.op == "neg" else jnp.logical_not(v)
        if isinstance(e, Call):
            return FNS[e.fn](*[self.eval(a, env, ax, binding, masks, ctx)
                               for a in e.args])
        raise RejectionError(f"cannot execute expression {e}")

    def _mask(self, conds, env, ax, binding, masks,
              ctx: ExecContext = _EMPTY_CTX):
        for c in conds:
            masks.append(self.eval(c, env, ax, binding, masks, ctx))
        uniq: list = []                  # repeated reads of one array CSE
        for x in masks:                  # to one traced mask: AND it once
            if not any(x is u for u in uniq):
                uniq.append(x)
        if not uniq:
            return None
        m = uniq[0]
        for x in uniq[1:]:
            m = jnp.logical_and(m, x)
        return jnp.broadcast_to(m, ax.shape()) if ax.order else m

    # ------------------------------------------------------------------
    # node execution.  run_node returns the NEW VALUE of each destination
    # (a tuple for Fused); execute() assigns them into the env.
    # ------------------------------------------------------------------

    def execute(self, nodes, env, ctx: ExecContext = _EMPTY_CTX):
        for node in nodes:
            if isinstance(node, P.SeqLoop):
                if node.cond is None and getattr(node, "chunk_bag", None):
                    # a ChunkLoop (core/chunked.py) reaching the plain
                    # executor: the whole bag is resident here, so the
                    # stream degrades to one all-resident "tile" — plain
                    # sequencing of the body, same results
                    self.execute(node.body, env, ctx)
                    continue
                self._exec_seq_loop(node, env, ctx)
            elif isinstance(node, P.FusedRound):
                # round-fusion region: plain sequencing on a single device
                # (the grouping only matters to the distributed executor)
                self.execute(node.parts, env, ctx)
            elif isinstance(node, P.Fused):
                for part, v in zip(node.parts, self.run_node(node, env, ctx)):
                    env[part.dest] = v
            else:
                env[node.dest] = self.run_node(node, env, ctx)

    def run_node(self, node, env, ctx: ExecContext = _EMPTY_CTX):
        # per-node guard site (DESIGN.md §11): under jit this fires at
        # trace time — a fault here fails the whole-program trace, whose
        # ladder then descends to the eager path where it fires again
        F.site("lower.node", node=type(node).__name__)
        if isinstance(node, P.Rebalance):
            # single device: one shard holds every row, blocks are balanced
            # by construction — the round is the identity (the distributed
            # executor runs the real size-exchange + all-to-all)
            self.note(node, "rebalance:noop[single-device]")
            return env[node.dest]
        if isinstance(node, P.DenseMap):
            res = self._exec_dense_map(node, env, ctx)
            if res is not None:
                return res
            self.note(node, "fallback:general-store")
            return self._exec_map(node, env, ctx)
        if isinstance(node, P.MapExpr):
            return self._exec_map(node, env, ctx)
        if isinstance(node, P.Scatter):
            return self._exec_scatter(node, env, ctx)
        if isinstance(node, P.SegmentReduce):
            return self._exec_segment(node, env, ctx)
        if isinstance(node, P.AxisReduce):
            return self._exec_axis_reduce(node, env, ctx)
        if isinstance(node, P.EinsumContract):
            return self._exec_einsum(node, env, ctx)
        if isinstance(node, P.TiledMatmul):
            return self._exec_tiled(node, env, ctx)
        if isinstance(node, P.ScalarReduce):
            return self._exec_scalar_reduce(node, env, ctx)
        if isinstance(node, P.Fused):
            return tuple(self.run_node(p, env, ctx) for p in node.parts)
        raise RejectionError(f"cannot execute plan node {node}")

    # ---- stores ----
    def _eval_dense(self, e, key_axes, ax, binding, env, ctx):
        """Whole-array evaluation of a dense-fastpath value: identity
        gathers resolve to the operand (sliced per shard under the bounds
        certificates of `_sliced_operand`), scalars broadcast.  None when a
        guard fails (caller takes the general grid path)."""
        if isinstance(e, Const):
            return jnp.asarray(e.value)
        if isinstance(e, Var):
            return jnp.asarray(env[e.name])
        if isinstance(e, (P.Gather, Get)):
            arr = env[e.array]
            from .tiles import TiledMatrix, unpack
            if isinstance(arr, TiledMatrix):
                arr = unpack(arr)
            if len(arr.shape) != len(key_axes):
                return None
            # pad_ok=False: a store must DROP out-of-range writes (keep the
            # old destination), which zero-padding cannot emulate
            return self._sliced_operand(arr, e.array, key_axes, ax,
                                        binding, ctx, pad_ok=False)
        if isinstance(e, BinOp):
            lhs = self._eval_dense(e.lhs, key_axes, ax, binding, env, ctx)
            rhs = self._eval_dense(e.rhs, key_axes, ax, binding, env, ctx)
            if lhs is None or rhs is None:
                return None
            return OPS[e.op](lhs, rhs)
        if isinstance(e, UnOp):
            v = self._eval_dense(e.e, key_axes, ax, binding, env, ctx)
            if v is None:
                return None
            return -v if e.op == "neg" else jnp.logical_not(v)
        if isinstance(e, Call):
            args = [self._eval_dense(a, key_axes, ax, binding, env, ctx)
                    for a in e.args]
            if any(a is None for a in args):
                return None
            return FNS[e.fn](*args)
        return None

    def _exec_dense_map(self, node: P.DenseMap, env, ctx):
        """DenseMap fast path: the pass proved identity indexing (keys =
        axes, identity gathers only, no conditions); verify at runtime
        that the extents cover the destination exactly, then emit ONE
        vectorized jnp expression — no index grids, no gather/scatter, no
        masks.  Per shard, aligned operands are their local blocks and
        replicated ones a bounds-certified dynamic slice; rows beyond the
        logical limit keep the destination's (zero) pad values.  Returns
        None when a guard fails (caller: general MapExpr path)."""
        from .tiles import TiledMatrix
        dest = env[node.dest]
        if isinstance(dest, TiledMatrix):
            return None
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        lim = None
        for pos, a in enumerate(node.space.axes):
            ov = ctx.axis_overrides.get(a.var)
            if ov is not None:
                if pos != 0:     # only the round axis may be localized
                    return None
                lim = ov[2]
        if tuple(ax.shape()) != tuple(dest.shape):
            return None          # space must cover the dest exactly
        if ctx.array_limits.get(node.dest) is not None \
                and node.dest not in ctx.aligned:
            return None          # padded global dest needs the drop path
        val = self._eval_dense(node.value, node.key_axes, ax, binding, env,
                               ctx)
        if val is None:
            return None
        val = jnp.broadcast_to(jnp.asarray(val), ax.shape())
        val = val.astype(dest.dtype)
        if lim is not None:      # keep (zero) pad rows beyond the limit
            ov = ctx.axis_overrides[node.space.axes[0].var]
            keep = (ov[0] + jnp.arange(ov[1])) < lim
            keep = keep.reshape((-1,) + (1,) * (val.ndim - 1))
            val = jnp.where(keep, val, dest)
        self.note(node, "dense-store")
        return val

    def _exec_map(self, node: P.MapExpr, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        if node.key_axes is None:          # guarded scalar assignment
            masks = list(base)
            val = self.eval(node.value, env, ax, binding, masks, ctx)
            m = self._mask(conds, env, ax, binding, masks, ctx)
            if m is not None:
                old = env.get(node.dest, jnp.zeros_like(val))
                return jnp.where(m, val, old)
            return val

        dest = env[node.dest]
        masks = list(base)
        val = self.eval(node.value, env, ax, binding, masks, ctx)
        m = self._mask(conds, env, ax, binding, masks, ctx)
        key_axes = node.key_axes
        val = jnp.broadcast_to(val, ax.shape())
        perm = [ax.order.index(a) for a in key_axes]
        val = jnp.transpose(val, perm)
        if m is not None:
            m = jnp.transpose(jnp.broadcast_to(m, ax.shape()), perm)
        los = [binding[a][2] for a in key_axes]
        exts = [ax.extent[a] for a in key_axes]
        dest_off = ctx.row_offsets.get(node.dest)
        dest_lim = ctx.array_limits.get(node.dest)
        static0 = all(isinstance(l, int) and l == 0 for l in los)
        if tuple(exts) == dest.shape and static0 and m is None \
                and dest_lim is None:
            return val.astype(dest.dtype)                 # full replace
        grids = list(jnp.meshgrid(
            *[los[i] + jnp.arange(exts[i]) for i in range(len(exts))],
            indexing="ij"))
        keep = m
        if dest_lim is not None:          # pad rows: drop (logical bound)
            ok = grids[0] < dest_lim
            keep = ok if keep is None else (keep & ok)
        if dest_off is not None:          # localize rows to the shard block
            grids[0] = grids[0] - dest_off
        if keep is not None:
            grids[0] = jnp.where(keep, grids[0], dest.shape[0])  # drop
        return dest.at[tuple(grids)].set(val.astype(dest.dtype), mode="drop")

    def _exec_scatter(self, node: P.Scatter, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        dest = env[node.dest]
        masks = list(base)
        val = self.eval(node.value, env, ax, binding, masks, ctx)
        m = self._mask(conds, env, ax, binding, masks, ctx)
        shape = ax.shape()
        val = jnp.broadcast_to(val, shape)
        kk = [jnp.broadcast_to(jnp.asarray(
            self.eval(k, env, ax, binding, masks, ctx), jnp.int32), shape)
            for k in node.keys]
        dest_off = ctx.row_offsets.get(node.dest)
        dest_lim = ctx.array_limits.get(node.dest)
        ok = None if m is None else m
        if dest_lim is not None:          # logical bound, global coords
            lim_ok = kk[0] < dest_lim
            ok = lim_ok if ok is None else ok & lim_ok
        if dest_off is not None:          # localize to the shard block
            kk[0] = kk[0] - dest_off
        if ok is not None:                # condition/pad drops: sentinel
            kk[0] = jnp.where(ok, kk[0], dest.shape[0])
        # uint32 reinterpretation: negative/OOB keys wrap past the dims
        # and drop natively — no per-dim bounds selects, no signed-index
        # normalization (see _exec_segment)
        kk = [k.astype(jnp.uint32) for k in kk]
        return dest.at[tuple(kk)].set(val.astype(dest.dtype), mode="drop")

    # ---- reductions ----
    def _segment_backend(self, node: P.SegmentReduce, n_rows, dest):
        """Resolve the group-by backend for this node at trace time: a
        pinned backend is honored verbatim; "auto" asks the selector with
        the concrete shape class (rows reduced, flattened segment count,
        dtype, and the destination's analyzed sharding)."""
        if node.backend != "auto":
            self.note(node, f"segment:{node.backend}[pinned]")
            return node.backend
        kflat = 1
        for d_ in dest.shape:
            kflat *= int(d_)
        sh = (node.shardings or {}).get(node.dest)
        dec = self.selector.choose_segment(
            n=int(n_rows), k=kflat, d=1, op=node.op, dtype=str(dest.dtype),
            dest_dist=sh.dist.name if sh is not None else "REP",
            candidates=node.candidates)
        self.note(node, f"segment:{dec.backend}[{dec.source}]")
        return dec.backend

    def _exec_segment(self, node: P.SegmentReduce, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        dest = env[node.dest]
        masks = list(base)
        keys = [self.eval(k, env, ax, binding, masks, ctx)
                for k in node.keys]
        val = self.eval(node.value, env, ax, binding, masks, ctx)
        m = self._mask(conds, env, ax, binding, masks, ctx)
        shape = ax.shape()
        val = jnp.broadcast_to(val, shape)
        kk = [jnp.broadcast_to(jnp.asarray(k, jnp.int32), shape)
              for k in keys]
        lim0 = ctx.array_limits.get(node.dest)
        n_rows = 1
        for d_ in shape:
            n_rows *= d_
        backend = self._segment_backend(node, n_rows, dest)
        salt_s, salt_src = self._segment_salt(node, ctx, dest)
        if salt_s > 1:
            # hot-key salting: spread every key over S sub-destinations —
            # `key*S + salt` with salt = global row index mod S — reduce a
            # [K·S] partial, then ⊕-fold the [K, S] view back to [K].  The
            # fold over ALL S slots makes any salt assignment correct (⊕ is
            # associative-commutative); the global row index keeps the
            # assignment identical on one device and across shards.  Every
            # backend takes the flattened-partial route here, including
            # scatter — salting exists to break its duplicate-update
            # serialization, and scattering into the [K·S] identity-filled
            # partial is exactly how the chain length divides by S.
            flat, num = self._ravel_keys([k.reshape(-1) for k in kk],
                                         dest.shape, limit0=lim0)
            if m is not None:
                flat = jnp.where(m.reshape(-1), flat, num)
            off = 0
            lead = node.space.axes[0] if node.space.axes else None
            if lead is not None and lead.kind == "bag":
                off = ctx.bag_offsets.get(lead.bag, 0)
            salt = (off + jnp.arange(flat.shape[0], dtype=jnp.int32)) % salt_s
            salted = jnp.where(flat < num, flat * salt_s + salt,
                               num * salt_s)
            vflat = val.reshape(-1).astype(dest.dtype)
            part = self._segment_flat(backend, salted, vflat,
                                      num * salt_s, node.op)
            part = REDUCE[node.op](part.reshape(num, salt_s), axis=1)
            self.note(node, self.decisions.get(id(node), "")
                      + f" salt={salt_s}x[{salt_src}]")
            return COMBINE[node.op](
                dest, part.reshape(dest.shape).astype(dest.dtype))
        if backend != "scatter":
            # flattened-segment backends (sort / onehot / pallas): ravel
            # the key tuple against the physical dims, route every dropped
            # row (OOB key, negative key, padded row, failed condition) to
            # the sentinel segment `num`, reduce into a [num] partial and
            # ⊕-combine with the destination.  Empty segments carry the ⊕
            # identity in the partial, so the combine leaves them alone.
            flat, num = self._ravel_keys([k.reshape(-1) for k in kk],
                                         dest.shape, limit0=lim0)
            if m is not None:
                flat = jnp.where(m.reshape(-1), flat, num)  # dropped
            vflat = val.reshape(-1).astype(dest.dtype)
            seg = self._segment_flat(backend, flat, vflat, num, node.op)
            return COMBINE[node.op](
                dest, seg.reshape(dest.shape).astype(dest.dtype))
        # native scatter-⊕ straight into the destination with drop
        # semantics — no identity-filled segment array, no index
        # flattening.  Keys are reinterpreted as uint32: a negative key
        # wraps to ≥ 2^31, far beyond any dimension, so the scatter's own
        # mode="drop" bounds check drops it natively — the paper's §3.4
        # OOB-write-drops semantics with NO sentinel select, and XLA
        # skips the signed-index normalization chain entirely (2 selects
        # + 2 compares per scatter on the hot group-by path).  Rows
        # dropped for other reasons — a failed condition, an out-of-range
        # value gather, a padded row — scatter the ⊕ IDENTITY instead:
        # contributing the identity is contributing nothing (and it also
        # scrubs the non-finite values a dropped row may carry).
        val = val.astype(dest.dtype)
        if m is not None:
            val = jnp.where(m, val, identity(node.op, dest.dtype))
        if lim0 is not None:      # logical dim-0 bound (padded rows)
            kk[0] = jnp.where(kk[0] >= lim0, dest.shape[0], kk[0])
        kk = [k.astype(jnp.uint32) for k in kk]
        return _scatter_op(dest.at[tuple(kk)], node.op)(val, mode="drop")

    def _segment_salt(self, node: P.SegmentReduce, ctx, dest):
        """Resolve the hot-key salt factor for this node: the static hint
        (`node.salt` — user-set or planner-stamped from
        `PlanConfig.skew_salting`) wins; otherwise the caller's run-time
        probe result (`ctx.salts`).  Restricted to single-key 1-D
        destinations — multi-key ravels already interleave destinations,
        and the fold is defined on the flat [K·S] partial."""
        if len(node.keys) != 1 or len(dest.shape) != 1:
            return 1, None
        if node.salt is not None:
            return (int(node.salt), "hint") if node.salt > 1 else (1, None)
        s = ctx.salts.get(node.dest)
        if s is not None and int(s) > 1:
            return int(s), "probe"
        return 1, None

    def _segment_flat(self, backend: str, ids, vals, num: int, op: str):
        """[N]-flat segment-⊕ partial via the chosen backend.  `ids` ==
        `num` marks dropped rows; the partial's row i is the ⊕ of all
        vals whose id == i, with the ⊕ identity for empty segments."""
        if backend == "scatter":
            # scatter-⊕ into an identity-filled [num+1] partial (salted
            # path only: unsalted scatter goes straight into the dest).
            # Sentinel rows land in the discard row and are sliced off —
            # dropped rows may carry non-finite values, but they only ever
            # touch buf[num].
            buf = jnp.full((num + 1,), identity(op, vals.dtype), vals.dtype)
            return _scatter_op(buf.at[ids], op)(vals)[:num]
        if backend == "sort":
            # sort-based: jax.ops.segment_⊕ over sorted ids (the classic
            # GPU/TPU shape).  num+1 segments so the sentinel rows land in
            # a discard row — deterministic drop without scatter modes.
            order = jnp.argsort(ids)
            seg = {"+": jax.ops.segment_sum, "min": jax.ops.segment_min,
                   "max": jax.ops.segment_max,
                   "*": jax.ops.segment_prod}[op]
            return seg(vals[order], ids[order], num_segments=num + 1,
                       indices_are_sorted=True)[:num]
        if backend == "onehot":
            # group-by as matmul: [N, num] one-hot × [N] values on the
            # MXU.  Integer values take the exact-int path (int32
            # accumulation); floats accumulate in f32.  Sentinel rows'
            # VALUES must be zeroed too: their one-hot row is all zeros,
            # but 0 × inf/NaN would still contaminate the dot — dropped
            # rows may carry non-finite values (e.g. a condition guarding
            # a division), and drop semantics say they contribute nothing
            acc = vals.dtype if jnp.issubdtype(vals.dtype, jnp.integer) \
                else jnp.float32
            vals = jnp.where(ids == num, jnp.zeros((), vals.dtype), vals)
            oh = (ids[:, None] == jnp.arange(num)[None, :]).astype(acc)
            return jax.lax.dot_general(
                vals.astype(acc)[None, :], oh, (((1,), (0,)), ((), ())),
                preferred_element_type=acc)[0]
        if backend == "pallas":
            from ..kernels import ops as kops
            return kops.segment_reduce(ids, vals, num, op=op)
        raise RejectionError(f"unknown segment backend {backend!r}")

    def _ravel_keys(self, kk, dshape, limit0=None):
        """Flatten index tuples against the PHYSICAL dims (strides must
        match the later reshape); `limit0` bounds dim-0 keys by the logical
        row count when the destination rows were padded."""
        num = 1
        for d in dshape:
            num *= d
        flat = jnp.zeros_like(kk[0])
        ok = jnp.ones_like(kk[0], dtype=bool)
        for dim_i, (k, d) in enumerate(zip(kk, dshape)):
            hi = limit0 if dim_i == 0 and limit0 is not None else d
            ok &= (k >= 0) & (k < hi)
            flat = flat * d + jnp.clip(k, 0, d - 1)
        flat = jnp.where(ok, flat, num)
        return flat, num

    def _keyed_combine(self, dest, partial, key_axes, ax, binding, op,
                       in_key_order, dest_off=None, dest_lim=None,
                       dest_name=None, ctx: ExecContext = _EMPTY_CTX):
        """Scatter-⊕ a partial (indexed by the key axes) into dest.
        `dest_off` localizes dim-0 rows to the shard's block; `dest_lim`
        drops rows at or beyond the logical row count (padding)."""
        if not in_key_order:
            cur = [a for a in ax.order if a in key_axes]
            partial = jnp.transpose(partial,
                                    [cur.index(a) for a in key_axes])
        los = [binding[a][2] for a in key_axes]
        exts = [ax.extent[a] for a in key_axes]
        # alignment certificate: the destination's local block IS the round
        # axis' window, so the traced window start is local row 0.  Rows
        # beyond the logical limit carry the ⊕ identity in the partial
        # (masked upstream), so the full-block combine leaves pad rows
        # untouched — no dynamic scatter inside the shard.
        if dest_name is not None and dest_name in ctx.aligned and key_axes \
                and key_axes[0] in ctx.axis_overrides \
                and not isinstance(los[0], int) \
                and exts[0] == dest.shape[0]:
            los[0] = 0
            dest_off = None
            dest_lim = None
        static0 = all(isinstance(l, int) and l == 0 for l in los)
        if tuple(exts) == dest.shape and static0 and dest_lim is None:
            return COMBINE[op](dest, partial.astype(dest.dtype))
        rows = los[0] + jnp.arange(exts[0])
        if dest_lim is not None:
            ok = rows < dest_lim
            local = rows if dest_off is None else rows - dest_off
            rows = jnp.where(ok, local, dest.shape[0])
        elif dest_off is not None:
            rows = rows - dest_off
        grids = tuple(
            (rows if i == 0 else los[i] + jnp.arange(exts[i])).reshape(
                [-1 if j == i else 1 for j in range(len(exts))])
            for i in range(len(exts)))
        return _scatter_op(dest.at[grids], op)(
            partial.astype(dest.dtype), mode="drop")

    def _exec_axis_reduce(self, node: P.AxisReduce, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        dest = env[node.dest]
        contracted = node.contracted
        # dense fast path (pass: dense-fastpath): the value is a certified
        # +-product of gathers — contract on the MXU via jnp.einsum instead
        # of materializing the dense iteration grid.  The plan-level
        # operator stays AxisReduce; only the materialization changes.
        if node.product is not None and not conds \
                and self._mxu_masks_ok(node.space, node.key_axes, ctx):
            partial = self._product_partial(node.product, node.key_axes, ax,
                                            binding, env, ctx)
            if partial is not None:
                partial = self._limit_mask_partial(partial, node.key_axes,
                                                   ctx)
                self.note(node, "mxu-einsum")
                return self._keyed_combine(
                    dest, partial, node.key_axes, ax, binding, "+",
                    in_key_order=True,
                    dest_off=ctx.row_offsets.get(node.dest),
                    dest_lim=ctx.array_limits.get(node.dest),
                    dest_name=node.dest, ctx=ctx)
        self.note(node, "dense-grid")
        masks = list(base)
        val = self.eval(node.value, env, ax, binding, masks, ctx)
        m = self._mask(conds, env, ax, binding, masks, ctx)
        val = jnp.broadcast_to(val, ax.shape())
        if m is not None:
            val = jnp.where(m, val, identity(node.op, val.dtype))
        if contracted:
            partial = REDUCE[node.op](
                val, axis=tuple(ax.pos(a) for a in contracted))
        else:
            partial = val
        return self._keyed_combine(dest, partial, node.key_axes, ax, binding,
                                   node.op, in_key_order=False,
                                   dest_off=ctx.row_offsets.get(node.dest),
                                   dest_lim=ctx.array_limits.get(node.dest),
                                   dest_name=node.dest, ctx=ctx)

    # ---- contractions (runtime guards; fall back on failure) ----
    def _mxu_masks_ok(self, space: P.IterSpace, key_axes, ctx) -> bool:
        """Masks admissible on an MXU contraction: only the LEADING KEY
        axis may carry a pad limit (its out-of-limit partial rows are
        zeroed by `_limit_mask_partial` before combining).  A limit on a
        contracted axis or a padded bag axis would let pad rows contribute
        to kept outputs, so those take the masked dense-grid path."""
        for a in space.axes:
            if a.kind == "bag":
                if ctx.bag_limits.get(a.bag) is not None:
                    return False
            else:
                ov = ctx.axis_overrides.get(a.var)
                if ov is not None and ov[2] is not None and \
                        (not key_axes or a.var != key_axes[0]):
                    return False
        return True

    def _limit_mask_partial(self, partial, key_axes, ctx):
        """Zero the partial's out-of-limit leading rows (round-axis
        padding).  Zero is the + identity, so the combine can never
        perturb the destination's pad rows — preserving the system
        invariant that pad rows stay zero."""
        ov = ctx.axis_overrides.get(key_axes[0]) if key_axes else None
        if ov is None or ov[2] is None:
            return partial
        off, ext, lim, _tot = ov
        keep = (off + jnp.arange(ext)) < lim
        keep = keep.reshape((-1,) + (1,) * (jnp.ndim(partial) - 1))
        return jnp.where(keep, partial, jnp.zeros((), partial.dtype))

    def _sliced_operand(self, arr, name, faxes, ax, binding, ctx,
                        pad_ok=True):
        """Slice a contraction operand to the iteration extents along each
        factor axis; None when an offset/extent guard fails.

        Traced offsets (per-shard rounds) are admitted only under a static
        certificate — lax.dynamic_slice clamps out-of-range starts
        silently, so no slice is emitted that cannot be PROVEN in bounds:

        * `name in ctx.aligned` (dim 0): the operand's local block IS the
          round axis' window; no slice at all, local rows 0..extent.
        * global operand (never localized): the axis' padded global extent
          `total` is static; when total ≤ the physical dim, every window
          [offset, offset+extent) ⊆ [0, total) ⊆ [0, dim) — dynamic_slice
          cannot clamp (the bounds certificate, DESIGN.md §7).
        """
        for dim_i, (d, axn) in enumerate(zip(arr.shape, faxes)):
            lo = binding[axn][2]
            ext = ax.extent[axn]
            if isinstance(lo, int):
                if lo != 0 or ext != d:
                    if lo + ext > d:
                        return None
                    arr = jax.lax.slice_in_dim(arr, lo, lo + ext,
                                               axis=dim_i)
                continue
            if dim_i == 0 and name in ctx.aligned:
                if ext != d:
                    return None      # certificate requires block == window
                continue
            ov = ctx.axis_overrides.get(axn)
            if ov is not None and name not in ctx.row_offsets \
                    and ov[3] is not None and (ov[3] <= d or pad_ok):
                if ov[3] > d:
                    # physical dim shorter than the padded axis (an unpadded
                    # replicated operand on a non-divisible axis): zero-pad
                    # it to `total` first, making the window provably in
                    # bounds.  Only +-contraction callers may opt in
                    # (pad_ok): a zero row reproduces the empty-bag
                    # semantics of an out-of-range read under +, and rows
                    # at or beyond the logical limit are masked out of
                    # every kept output anyway.
                    pad = [(0, 0)] * arr.ndim
                    pad[dim_i] = (0, ov[3] - d)
                    arr = jnp.pad(arr, pad)
                arr = jax.lax.dynamic_slice_in_dim(arr, lo, ext, axis=dim_i)
                continue
            return None
        return arr

    def _product_partial(self, ef: P.EinsumFactors, key_axes, ax, binding,
                         env, ctx: ExecContext = _EMPTY_CTX):
        """jnp.einsum over the factor gathers; None when an offset/extent
        guard fails (caller falls back).  Padded operands are safe here:
        every slice is statically proven in bounds, pad rows are zero by
        system invariant, and the contraction monoid is +, whose identity
        matches the zero pad rows.  Factors covering only a subset of the
        key axes (contraction-free terms) come back expanded with size-1
        dims, ready to broadcast against full-key partials."""
        from .tiles import TiledMatrix, unpack
        letters = {a: chr(ord('a') + i) for i, a in enumerate(ax.order)}
        specs = []
        operands = []
        used: set = set()
        for f, faxes in zip(ef.factors, ef.factor_axes):
            arr = env[f.array]
            if isinstance(arr, TiledMatrix):
                arr = unpack(arr)
            spec = "".join(letters[axn]
                           for _, axn in zip(arr.shape, faxes))
            arr = self._sliced_operand(arr, f.array, faxes, ax, binding,
                                       ctx)
            if arr is None:
                return None
            specs.append(spec)
            operands.append(arr)
            used.update(faxes)
        out_axes = [a for a in key_axes if a in used]
        out_spec = "".join(letters[a] for a in out_axes)
        res = jnp.einsum(",".join(specs) + "->" + out_spec, *operands)
        if tuple(out_axes) != tuple(key_axes):
            res = jnp.reshape(
                res, [ax.extent[a] if a in used else 1 for a in key_axes])
        for o in ef.others:
            res = res * self.eval(o, env, ax, binding, [], ctx)
        return res

    def _terms_partial(self, node: P.EinsumContract, ax, binding, env,
                       ctx: ExecContext = _EMPTY_CTX):
        key_axes = node.key_axes
        contracted = node.contracted
        key_exts = tuple(ax.extent[a] for a in ax.order if a in key_axes)
        cur = [a for a in ax.order if a in key_axes]
        perm = [cur.index(a) for a in key_axes]
        mult = 1
        for a in contracted:
            mult *= ax.extent[a]
        total = None
        for sign, term, ef, free in node.terms:
            if ef is not None:
                part = self._product_partial(ef, key_axes, ax, binding, env,
                                             ctx)
                if part is None:
                    return None
                if free:        # Σ over the contracted axes of a term free
                    part = part * mult      # of them = extent-product × term
            else:               # unrecognized contraction-free term:
                masks: list = []            # grid-evaluate (Σ_j c = |j|·c)
                v = self.eval(term, env, ax, binding, masks, ctx)
                if masks:
                    return None
                if jnp.ndim(v) == 0:
                    part = jnp.broadcast_to(v, key_exts)
                else:  # full-rank with size-1 contracted dims: drop them
                    part = jnp.squeeze(
                        v, axis=tuple(ax.pos(a) for a in contracted))
                    part = jnp.broadcast_to(part, key_exts)
                part = jnp.transpose(part, perm) * mult
            total = part * sign if total is None else total + part * sign
        for sc in node.scalars:
            total = total * self.eval(sc, env, ax, binding, [], ctx)
        return jnp.broadcast_to(total,
                                tuple(ax.extent[a] for a in key_axes))

    def _exec_einsum(self, node: P.EinsumContract, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        partial = None
        # the candidate set IS the guard chain; op_select="force:dense-grid"
        # narrows it to the fallback, skipping the einsum attempt entirely
        if "einsum" in node.candidates and \
                self._mxu_masks_ok(node.space, node.key_axes, ctx):
            if node.product is not None:
                partial = self._product_partial(node.product, node.key_axes,
                                                ax, binding, env, ctx)
            else:
                partial = self._terms_partial(node, ax, binding, env, ctx)
        if partial is None:
            self.note(node, "fallback:dense-grid")
            return self.run_node(node.fallback, env, ctx)
        partial = self._limit_mask_partial(partial, node.key_axes, ctx)
        self.note(node, "einsum")
        dest = env[node.dest]
        return self._keyed_combine(dest, partial, node.key_axes, ax, binding,
                                   "+", in_key_order=True,
                                   dest_off=ctx.row_offsets.get(node.dest),
                                   dest_lim=ctx.array_limits.get(node.dest),
                                   dest_name=node.dest, ctx=ctx)

    def _exec_tiled(self, node: P.TiledMatmul, env, ctx):
        from .tiles import TiledMatrix, matmul_tiled, unpack
        ein = node.contract
        lhs = env[node.lhs]
        if not isinstance(lhs, TiledMatrix):
            return self.run_node(ein, env, ctx)
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        if base:
            return self.run_node(ein, env, ctx)
        # packed lhs must be used at full extent (no slicing on tiles)
        for d, axn in zip(lhs.shape, ein.product.factor_axes[0]):
            lo = binding[axn][2]
            if not isinstance(lo, int) or lo != 0 or ax.extent[axn] != d:
                return self.run_node(ein, env, ctx)
        rhs = env[node.rhs]
        if isinstance(rhs, TiledMatrix):
            rhs = unpack(rhs)
        rhs = self._sliced_operand(rhs, node.rhs, ein.product.factor_axes[1],
                                   ax, binding, ctx)
        if rhs is None:
            return self.run_node(ein, env, ctx)
        # packed lhs, guards passed: op_select decides whether the
        # block-sparse Pallas kernel or unpack+einsum contracts — the
        # former wins on the target MXU, the latter everywhere Pallas
        # would run in (python-level) interpret mode.  Both consume the
        # packed representation; only the materialization differs.  A
        # single-element candidate set (op_select="force:<b>") is honored
        # verbatim.
        if len(node.candidates) == 1:
            choice, src = node.candidates[0], "pinned"
        else:
            dec = self.selector.choose_contract(
                m=int(lhs.shape[0]), k=int(lhs.shape[1]),
                n=int(rhs.shape[1]), candidates=node.candidates)
            choice, src = dec.backend, dec.source
        if choice == "unpack-einsum":
            self.note(node, f"tiled:unpack-einsum[{src}]")
            return self.run_node(ein, env, ctx)
        self.note(node, f"tiled:pallas-tiled[{src}]")
        res = matmul_tiled(lhs, rhs)
        for o in ein.product.others:
            res = res * self.eval(o, env, ax, binding, [], ctx)
        dest = env[node.dest]
        return self._keyed_combine(dest, res, ein.key_axes, ax, binding,
                                   "+", in_key_order=True,
                                   dest_off=ctx.row_offsets.get(node.dest),
                                   dest_lim=ctx.array_limits.get(node.dest),
                                   dest_name=node.dest, ctx=ctx)

    # ---- scalar reductions ----
    def _total_reduce(self, node: P.ScalarReduce, env, ax, binding, conds,
                      base, ctx: ExecContext = _EMPTY_CTX):
        masks: list = []
        if node.bool_any is not None and not base:
            # peephole: max/min over float(bool) → any/all (XLA-CPU f32
            # max-reduce is ~20x slower than a bool reduce; same result)
            b = self.eval(node.bool_any, env, ax, binding, masks, ctx)
            if not masks and ax.order:
                red = jnp.any if node.op == "max" else jnp.all
                return red(jnp.asarray(b, bool)).astype(jnp.float32)
        masks = list(base)
        val = self.eval(node.value, env, ax, binding, masks, ctx)
        m = self._mask(conds, env, ax, binding, masks, ctx)
        val = jnp.broadcast_to(val, ax.shape()) if ax.order else val
        if m is not None:
            val = jnp.where(m, val, identity(node.op,
                                             jnp.asarray(val).dtype))
        return REDUCE[node.op](val) if ax.order else val

    def _exec_scalar_reduce(self, node: P.ScalarReduce, env, ctx):
        ax, binding, conds, base = self.build_space(node.space, env, ctx)
        total = self._total_reduce(node, env, ax, binding, conds, base, ctx)
        dest = env[node.dest]
        if node.point is not None:      # Rule 16: one-cell ⊕ update
            return _scatter_op(dest.at[node.point], node.op)(
                total.astype(dest.dtype))
        dest = jnp.asarray(dest)
        return COMBINE[node.op](dest, total.astype(dest.dtype))

    # ---- sequential loop ----
    def _exec_seq_loop(self, node: P.SeqLoop, env, ctx):
        carry0 = tuple(jnp.asarray(env[n]) for n in node.carry)

        def cond_fn(c, _names=node.carry, _n=node):
            e2 = dict(env)
            e2.update(dict(zip(_names, c)))
            return jnp.asarray(
                self.eval(_n.cond, e2, Axes(), {}, [], ctx), bool)

        def body_fn(c, _names=node.carry, _n=node):
            e2 = dict(env)
            e2.update(dict(zip(_names, c)))
            self.execute(_n.body, e2, ctx)
            return tuple(jnp.asarray(e2[n]) for n in _names)

        out = jax.lax.while_loop(cond_fn, body_fn, carry0)
        env.update(dict(zip(node.carry, out)))

    def eval_scalar(self, e, env):
        """Evaluate an expression outside any iteration space."""
        return self.eval(e, env, Axes(), {}, [])


# ---------------------------------------------------------------------------
# program compilation
# ---------------------------------------------------------------------------

class CompiledProgram:
    def __init__(self, prog: Program, target, optimize_contractions=True,
                 use_kernels=False, infer_distributions=True,
                 dense_fastpath=True, op_select="cost",
                 autotune_cache=None, compile_mode="whole",
                 donate=False, round_fusion=True,
                 skew_rebalance=True, skew_salting="auto",
                 out_of_core="auto", memory_budget=None, chunk_rows=None,
                 lineage=True, speculative=True):
        self.program = prog
        self.target = target
        from .op_select import CACHE_FILE, OpSelector
        if autotune_cache is None:
            autotune_cache = CACHE_FILE
        self.config = PlanConfig(optimize_contractions=optimize_contractions,
                                 use_kernels=use_kernels,
                                 infer_distributions=infer_distributions,
                                 dense_fastpath=dense_fastpath,
                                 op_select=op_select,
                                 autotune_cache=autotune_cache,
                                 round_fusion=round_fusion,
                                 skew_rebalance=skew_rebalance,
                                 skew_salting=skew_salting,
                                 out_of_core=out_of_core,
                                 memory_budget=memory_budget,
                                 chunk_rows=chunk_rows,
                                 lineage=lineage,
                                 speculative=speculative)
        self.plan = plan_program(target, prog, self.config)
        from .dist_analysis import collect
        self.dists = collect(self.plan)   # array → Dist (pass-8 annotations)
        self.selector = OpSelector(op_select, cache_path=autotune_cache)
        self.executor = PlanExecutor(prog, self.selector)
        # ---- whole-program compilation (DESIGN.md §9) ----
        # run() traces the ENTIRE plan into one cached jax.jit computation
        # per (static dims, shapes, dtypes) signature — one XLA dispatch
        # per call instead of one per node.  compile_mode="eager" keeps the
        # per-node path (the guaranteed fallback, also taken automatically
        # when a trace fails or an input arrives §5-packed).  `donate`
        # additionally donates the buffers of mutated destinations and
        # SeqLoop carries to the computation — callers passing jax arrays
        # must not reuse them after the call (numpy inputs are copied to
        # device per call, so donation is always safe for them).
        self.compile_mode = compile_mode
        self.donate = donate
        self._whole_cache: dict = {}   # signature → (fn, decisions snapshot)
        # per-SIGNATURE compile-failure memoization (DESIGN.md §11): a
        # failed whole-program trace disables only ITS signature, for
        # policy.disable_ttl runs — other shapes keep the whole path, and
        # the expired signature gets re-attempted (bounded retry budget)
        self._whole_bad: dict = {}     # signature key → remaining ttl
        self.trace_count = 0           # whole-program traces (test probe)
        self.cache_hits = 0
        self.trace_failures = 0        # failed whole traces (probe)
        self.whole_retries = 0         # expired disables re-attempted
        self.faults = F.FaultLedger(prog.name)   # failure ledger (§11);
        self.policy = F.RetryPolicy()  # shared with DistributedProgram
        self._last_whole_exc = None    # why the LAST _run_whole descended
        # ---- out-of-core capacity tier (DESIGN.md §12) ----
        # out_of_core: "auto" = admit against memory_budget when set, and
        # descend to chunked streaming on classified capacity errors;
        # "force" = every run() streams; "off" = pre-§12 ladder (capacity
        # bottoms out at interp/single-device).  chunk_rows pins the tile;
        # None derives it from the budget via memest/choose_chunk_rows.
        self.out_of_core = out_of_core
        self.memory_budget = memory_budget
        self.chunk_rows = chunk_rows
        self._chunker = None           # lazy chunked.ChunkRunner
        self._mem_last = None          # last memest.MemEstimate (explain)
        self._mem_cache: dict = {}     # shape key → MemEstimate
        self._donate_names = frozenset(
            d for n in self.plan for d in P.dests_of(n)
            if prog.params.get(d) is not None
            and prog.params[d].kind != "dim")

    @property
    def _whole_disabled(self) -> bool:
        """Back-compat probe: True while ANY signature is sitting out its
        disable ttl (the old flag was global AND permanent — §11 made it
        per-signature with a bounded retry budget)."""
        return bool(self._whole_bad)

    def pretty_target(self) -> str:
        return "\n".join(pretty(s) for s in self.target)

    def explain(self, tiled=()) -> str:
        """Spark-EXPLAIN-style dump of the chosen physical operator per
        statement.  `tiled` names params assumed to arrive §5-packed.
        After a run(), nodes whose backend the operator-selection
        subsystem resolved at trace time carry a `selected:` line (e.g.
        ``selected: segment:scatter[cost]``).  The trailing
        `whole-program:` line reports the compile-cache state — how many
        signatures were traced and how many run() calls hit the cache."""
        text = P.explain(self.plan, self.program.name, tiled,
                         decisions=self.executor.decisions)
        mode = "eager" if self.compile_mode != "whole" or \
            self._whole_disabled else "whole"
        text += (f"\nwhole-program: mode={mode}, {self.trace_count} traced, "
                 f"{self.cache_hits} cache hits"
                 + (", donate=on" if self.donate else "")
                 + (f", {self.trace_failures} trace failures "
                    f"({len(self._whole_bad)} signatures sitting out ttl, "
                    f"{self.whole_retries} re-attempted)"
                    if self.trace_failures or self.whole_retries else ""))
        if self._mem_last is not None:
            text += "\n" + self._mem_last.summary(self.memory_budget)
        return text

    # ---- out-of-core capacity tier (DESIGN.md §12) ----
    @property
    def chunker(self):
        if self._chunker is None:
            from .chunked import ChunkRunner
            self._chunker = ChunkRunner(self)
        return self._chunker

    def estimate_memory(self, inputs: dict):
        """Peak-device-bytes estimate for this call's shapes
        (core/memest.py) — the admission-check input.  Cached per shape
        class; also surfaced through explain()/explain_memory()."""
        from . import memest
        senv = memest.shape_env(self.program, inputs)
        key = tuple(sorted((n, repr(e)) for n, e in senv.items()))
        est = self._mem_cache.get(key)
        if est is None:
            est = memest.estimate(self.plan, self.program, senv,
                                  donate=self.donate)
            self._mem_cache[key] = est
        self._mem_last = est
        return est

    def explain_memory(self, inputs: dict) -> str:
        return self.estimate_memory(inputs).explain(self.memory_budget)

    def explain_chunked(self) -> str:
        """The chunked (out-of-core) form of the plan, ChunkLoops shown."""
        return self.chunker.explain()

    def explain_lineage(self) -> str:
        """The per-round recovery recipes (core/lineage.py, DESIGN.md §13):
        one `lineage:` line per round naming the shard axis, the write
        taxonomy class, each read's surviving source (rep / aligned /
        gathered) and the producer-chain depth a restart would replay."""
        from .lineage import explain_lineage
        return explain_lineage(self.plan, self.program.name)

    def _ooc_admits(self, inputs: dict) -> bool:
        """True when this call must take the chunked tier up front: forced,
        or its estimated peak exceeds the memory budget (the hard
        admission check — chunk instead of letting XLA OOM)."""
        if self.out_of_core == "force":
            return True
        if self.out_of_core == "off" or self.memory_budget is None:
            return False
        est = self.estimate_memory(inputs)
        if est.peak_bytes > self.memory_budget:
            from .memest import fmt_bytes
            self.faults.record(
                "admission", "chunked",
                f"estimated peak {fmt_bytes(est.peak_bytes)} > budget "
                f"{fmt_bytes(self.memory_budget)}: streaming chunked")
            return True
        return False

    def _initial_chunk_rows(self, inputs: dict) -> int:
        if self.chunk_rows:
            return int(self.chunk_rows)
        from .chunked import DEFAULT_CHUNK_ROWS, choose_chunk_rows
        if self.memory_budget is not None:
            return choose_chunk_rows(self.estimate_memory(inputs),
                                     self.memory_budget)
        return DEFAULT_CHUNK_ROWS

    def _run_chunked(self, inputs: dict, *, observer=None, loop_state=None,
                     recovering=False):
        """The chunked rung: stream bag tiles through resident
        accumulators (core/chunked.py).  A capacity error INSIDE the
        stream halves the tile and retries — descending the memory curve,
        never ascending it — until a 1-row tile fails too."""
        rows = self._initial_chunk_rows(inputs)
        while True:
            try:
                out = self.chunker.run(inputs, chunk_rows=rows,
                                       observer=observer,
                                       loop_state=loop_state)
                if recovering:
                    self.faults.recover("chunked")
                return out
            except Exception as ex:           # noqa: BLE001 — ladder
                if F.classify(ex) != "capacity" or rows <= 1:
                    raise
                self.faults.descend(f"chunked[{rows}]",
                                    f"chunked[{rows // 2}]", ex)
                rows //= 2
                recovering = True

    # -- public execution interface (distributed.py consumes this) --
    def execute(self, env: dict, *, bag_offsets=None, bag_limits=None,
                array_limits=None, nodes=None, salts=None) -> None:
        ctx = ExecContext(bag_offsets or {}, bag_limits or {},
                          array_limits=array_limits or {},
                          salts=salts or {})
        self.executor.execute(self.plan if nodes is None else nodes, env, ctx)

    def prepare_env(self, inputs: dict) -> dict:
        env = {}
        for name, t in self.program.params.items():
            v = inputs[name]
            if t.kind == "dim":
                env[name] = int(v)
            elif t.kind == "bag":
                env[name] = tuple(jnp.asarray(c) for c in v) \
                    if isinstance(v, tuple) else (jnp.asarray(v),)
            elif t.kind in ("vector", "matrix", "map"):
                from .tiles import TiledMatrix
                if isinstance(v, TiledMatrix):
                    env[name] = v      # §5 packed input, fused where possible
                else:
                    env[name] = jnp.asarray(
                        v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = jnp.asarray(v)
        return env

    # ---- whole-program path ----
    def _signature(self, env):
        """Compile-cache key: static dims by VALUE (they define shapes),
        arrays by shape+dtype.  None = this env cannot take the whole-
        program path (§5 packed inputs execute eagerly)."""
        from .tiles import TiledMatrix
        sig = []
        for name, t in self.program.params.items():
            v = env[name]
            if t.kind == "dim":
                sig.append((name, "dim", v))
            elif t.kind == "bag":
                sig.append((name, "bag", tuple(
                    (tuple(c.shape), str(c.dtype)) for c in v)))
            elif isinstance(v, TiledMatrix):
                return None
            else:
                sig.append((name, t.kind, tuple(jnp.shape(v)),
                            str(jnp.asarray(v).dtype)))
        return tuple(sig)

    def _run_whole(self, inputs: dict):
        env = self.prepare_env(inputs)
        sig = self._signature(env)
        if sig is None:
            return None                       # packed inputs: eager path
        static = {n: v for n, v in env.items() if isinstance(v, int)}
        # donation only applies at a real jit boundary: under an outer
        # trace (callers wrapping run() in their own jit) the donated
        # buffers are tracers and jax would warn and ignore them
        donate = self.donate and not any(
            isinstance(x, jax.core.Tracer)
            for v in env.values()
            for x in (v if isinstance(v, tuple) else (v,)))
        donated = {n: v for n, v in env.items()
                   if donate and n in self._donate_names
                   and not isinstance(v, int)}
        kept = {n: v for n, v in env.items()
                if n not in static and n not in donated}
        # run-time hot-key probe (skew salting): the resolved factors are
        # part of the cache key — a skewed and a uniform key stream of the
        # same shapes trace DIFFERENT programs
        salts = collect_salts(self.plan, env, self.selector,
                              self.config.skew_salting)
        key = (sig, donate, tuple(sorted(salts.items())))
        left = self._whole_bad.get(key)
        if left is not None:
            # this signature's trace failed recently: sit out the rest of
            # its disable ttl at the eager level, then re-attempt (§11 —
            # the old behaviour disabled the whole PROGRAM forever)
            if left > 1:
                self._whole_bad[key] = left - 1
                return None
            del self._whole_bad[key]
            self.whole_retries += 1
            self.faults.record("retry", "whole",
                               "signature disable ttl expired: "
                               "re-attempting whole-program trace")
        ent = self._whole_cache.get(key)
        if ent is None:
            def traced(dnt, kpt, _static=dict(static)):
                e = dict(_static)
                e.update(dnt)
                e.update(kpt)
                self.executor.execute(self.plan, e,
                                      ExecContext(salts=salts))
                return {n: e[n] for n in self.program.outputs}

            fn = jax.jit(traced, donate_argnums=(0,) if donated else ())

            def attempt():
                F.site("lower.whole_trace", program=self.program.name)
                return fn(donated, kept)      # traces the whole plan once
            try:
                out = F.run_with_retries(attempt, policy=self.policy,
                                         ledger=self.faults, label="whole")
            except Exception as ex:           # noqa: BLE001 — ladder
                self.trace_failures += 1
                self._whole_bad[key] = self.policy.disable_ttl
                self._last_whole_exc = ex
                # capacity never ascends the memory curve (§12): the
                # chunked tier is the correct rung, not the eager path
                # (same buffers, same OOM) — run() routes on the class
                to = "chunked" if (F.classify(ex) == "capacity"
                                   and self.out_of_core != "off") else "eager"
                self.faults.descend("whole", to, ex)
                return None                   # run() picks the rung
            self.trace_count += 1
            self._whole_cache[key] = (fn, dict(self.executor.decisions))
            return out
        fn, notes = ent
        self.cache_hits += 1
        # cached signatures skip the trace: restore the decision snapshot
        # taken when this signature was traced, so explain() stays accurate
        self.executor.decisions.update(notes)
        return fn(donated, kept)

    def run(self, inputs: dict) -> dict:
        # hard admission check (DESIGN.md §12): calls whose estimated
        # peak exceeds the memory budget stream chunked from the start
        if self._ooc_admits(inputs):
            return self._run_chunked(inputs)
        whole_failed = False
        if self.compile_mode == "whole":
            self._last_whole_exc = None
            out = self._run_whole(inputs)
            if out is not None:
                return out
            ex = self._last_whole_exc
            whole_failed = ex is not None
            if whole_failed and F.classify(ex) == "capacity" \
                    and self.out_of_core != "off":
                # whole → chunked: the capacity rung (never eager, which
                # re-materializes the same all-resident buffers)
                return self._run_chunked(inputs, recovering=True)

        def eager():
            env = self.prepare_env(inputs)
            self.execute(env, salts=collect_salts(
                self.plan, env, self.selector, self.config.skew_salting))
            return {n: env[n] for n in self.program.outputs}

        # degradation ladder (DESIGN.md §11/§12): whole → eager per-node
        # (the executor's own node fallback chains live inside) → chunked
        # streaming for capacity / interpreter oracle for the rest.
        # Transients retry at each level with bounded backoff;
        # deterministic errors get AT MOST one descent before surfacing.
        try:
            out = F.run_with_retries(eager, policy=self.policy,
                                     ledger=self.faults, label="eager")
            if whole_failed:
                self.faults.recover("eager")
            return out
        except Exception as ex:               # noqa: BLE001 — ladder
            if F.classify(ex) == "deterministic":
                # a user error reproduces at every level: it already got
                # its one descent (whole→eager) or none was available —
                # surface it, never fall through to the oracle (which
                # would silently mask it)
                raise
            if F.classify(ex) == "capacity" and self.out_of_core != "off":
                # eager → chunked: stream tiles instead of the oracle
                # (the oracle holds everything host-resident in float64 —
                # fine for correctness, wrong rung for capacity)
                self.faults.descend("eager", "chunked", ex)
                try:
                    return self._run_chunked(inputs, recovering=True)
                except Exception as ex2:      # noqa: BLE001 — ladder
                    if F.classify(ex2) == "deterministic":
                        raise
                    return self._run_interp(inputs, "chunked", ex2)
            # transient persisting past the eager retries: the reference
            # interpreter is the bottom rung — correct numpy float64
            # results (not bit-identical; the ledger says so)
            return self._run_interp(inputs, "eager", ex)

    def _run_interp(self, inputs: dict, from_level: str, ex) -> dict:
        self.faults.descend(from_level, "interp", ex)
        from .interp import run as _oracle
        out = _oracle(self.program, dict(inputs))
        self.faults.recover("interp")
        return {n: out[n] for n in self.program.outputs}

    def explain_faults(self) -> str:
        """Render the failure ledger (DESIGN.md §11) next to explain():
        retry/descent/recovery/straggler events plus the per-signature
        whole-program disable state."""
        text = self.faults.explain()
        text += (f"\nwhole-program: {self.trace_failures} trace failures, "
                 f"{len(self._whole_bad)} signatures sitting out ttl "
                 f"(budget {self.policy.disable_ttl} runs), "
                 f"{self.whole_retries} re-attempted")
        return text

    # ---- checkpointable execution (DESIGN.md §11) ----
    def run_stepwise(self, inputs: dict, *, loop_state=None, observer=None):
        """Eager execution with HOST-DRIVEN top-level sequential loops —
        the checkpoint/resume entry.  run() executes a SeqLoop as one
        on-device lax.while_loop, so no mid-loop state ever reaches the
        host; this path instead evaluates the condition and executes the
        body once per iteration, calling
        ``observer(loop_idx, iteration, carry_dict)`` after every
        iteration with the loop carry as live arrays — the hook
        runtime/ft.LoopRunner snapshots through CheckpointManager.

        ``loop_state`` maps loop_idx → (iteration, {carry: array}) and
        fast-forwards the matching loop: nodes before it re-execute
        (pure and deterministic from the same inputs), the carry is
        restored, and iteration continues from there.  A resumed run is
        bit-identical to an uninterrupted stepwise run because both
        execute the exact same per-iteration body computations on the
        same carry values.  Loop indices follow plan.seq_loops().

        Out-of-core runs route to the chunked plan, whose top-level
        ChunkLoops are SeqLoops in this numbering — the observer fires
        once per CHUNK with the accumulator carry, so LoopRunner
        checkpoints give chunk-granular resume of a killed streaming run
        with no extra machinery (DESIGN.md §12)."""
        if self._ooc_admits(inputs):
            return self._run_chunked(inputs, observer=observer,
                                     loop_state=loop_state)
        env = self.prepare_env(inputs)
        salts = collect_salts(self.plan, env, self.selector,
                              self.config.skew_salting)
        ctx = ExecContext(salts=salts)
        loop_state = dict(loop_state or {})
        li = 0
        for node in P.flatten(self.plan):
            if not isinstance(node, P.SeqLoop):
                self.executor.execute([node], env, ctx)
                continue
            it = 0
            st = loop_state.get(li)
            if st is not None:
                it, carry = st
                for c in node.carry:
                    env[c] = jnp.asarray(carry[c])
            while bool(self.executor.eval_scalar(node.cond, env)):
                F.site("lower.loop_iter", loop=li, iteration=it)
                self.executor.execute(node.body, env, ctx)
                it += 1
                if observer is not None:
                    observer(li, it, {c: env[c] for c in node.carry})
            li += 1
        return {n: env[n] for n in self.program.outputs}

    # ---- batchable entry (serving layer, DESIGN.md §10) ----
    # The PlanServer (serve/plans.py) coalesces concurrent invocations of
    # one program into a single vmapped whole-program XLA call.  These
    # three hooks are its contract: a HOST-SIDE mirror of prepare_env (so
    # requests canonicalize without touching the device), the signature
    # key that doubles as the shape-bucketing function, and the batched
    # call itself — the same traced plan, vmapped over a leading request
    # axis and cached in the SAME whole-program cache.

    def canonical_inputs(self, inputs: dict) -> dict:
        """Numpy mirror of prepare_env: same dtype coercions, no device
        transfer.  The serving layer stacks many of these host-side and
        ships ONE buffer per bucket.  §5 packed inputs are rejected —
        they execute eagerly and cannot batch."""
        from .tiles import TiledMatrix
        out = {}
        for name, t in self.program.params.items():
            v = inputs[name]
            if isinstance(v, TiledMatrix):
                raise ValueError(
                    f"param '{name}': packed (TiledMatrix) inputs cannot "
                    "take the batched serving path")
            if t.kind == "dim":
                out[name] = int(v)
            elif t.kind == "bag":
                cols = v if isinstance(v, tuple) else (v,)
                out[name] = tuple(
                    np.asarray(c, jax.dtypes.canonicalize_dtype(
                        np.asarray(c).dtype)) for c in cols)
            elif t.kind in ("vector", "matrix", "map"):
                out[name] = np.asarray(
                    v, np.float32 if t.dtype == "float" else np.int32)
            else:
                a = np.asarray(v)
                out[name] = np.asarray(
                    a, jax.dtypes.canonicalize_dtype(a.dtype))
        return out

    def entry_signature(self, cinputs: dict) -> tuple:
        """The whole-program compile-cache key of one canonicalized
        request: static dims BY VALUE, arrays by shape+dtype — exactly
        `_signature`, computed host-side.  This IS the serving layer's
        bucketing function: requests whose signatures agree after bag/row
        padding share one batched computation."""
        sig = []
        for name, t in self.program.params.items():
            v = cinputs[name]
            if t.kind == "dim":
                sig.append((name, "dim", int(v)))
            elif t.kind == "bag":
                sig.append((name, "bag", tuple(
                    (tuple(c.shape), str(c.dtype)) for c in v)))
            else:
                sig.append((name, t.kind, tuple(np.shape(v)),
                            str(np.asarray(v).dtype)))
        return tuple(sig)

    @property
    def bag_row_aligned(self) -> dict:
        """array → bag for dense params whose dim-0 rides a bag's row
        count (plan.bag_row_arrays): the arrays a shape bucket must pad in
        lockstep with that bag, under a matching `array_limits` mask."""
        if not hasattr(self, "_bag_row_aligned"):
            self._bag_row_aligned = P.bag_row_arrays(self.plan)
        return self._bag_row_aligned

    def batched_call(self, key, static: dict, arrays: dict, lengths: dict,
                     limit_bags=(), limit_arrays=()):
        """Run the whole-program trace vmapped over a leading request
        axis: `arrays` maps every non-dim param to a [B, ...]-stacked
        value (bags as tuples of [B, N] columns), `lengths` maps each
        padded bag/bag-aligned array to its [B] logical row counts —
        threaded per lane through ExecContext.{bag,array}_limits so pad
        rows can never change a result (the same §3.4 machinery the
        distributed pad+mask path uses).  `key` is the caller's padded
        bucket signature (it must determine shapes, B, and the limit
        sets); entries live in the SAME `_whole_cache` as single-request
        signatures and count toward trace_count/cache_hits.  Mutated
        destinations are donated — callers pass freshly device_put
        buffers and must not reuse them.  Hot-key salting stays off on
        this path (keys are tracers under vmap; the probe needs concrete
        data).  Raises on trace failure — the serving layer falls back to
        sequential run() per request."""
        ck = ("batched", key)
        donated = {n: v for n, v in arrays.items()
                   if n in self._donate_names}
        kept = {n: v for n, v in arrays.items() if n not in donated}
        ent = self._whole_cache.get(ck)
        if ent is None:
            outs = tuple(self.program.outputs)
            lb, la = tuple(limit_bags), tuple(limit_arrays)

            def traced(dnt, kpt, lens, _static=dict(static)):
                def one(d, k_, l):
                    e = dict(_static)
                    e.update(d)
                    e.update(k_)
                    ctx = ExecContext(
                        bag_limits={n: l[n] for n in lb},
                        array_limits={n: l[n] for n in la})
                    self.executor.execute(self.plan, e, ctx)
                    return {n: e[n] for n in outs}
                return jax.vmap(one)(dnt, kpt, lens)

            fn = jax.jit(traced, donate_argnums=(0,) if donated else ())
            out = fn(donated, kept, lengths)   # traces the batch once
            self.trace_count += 1
            self._whole_cache[ck] = (fn, dict(self.executor.decisions))
            return out
        fn, notes = ent
        self.cache_hits += 1
        self.executor.decisions.update(notes)
        return fn(donated, kept, lengths)

    def __call__(self, **inputs):
        return self.run(inputs)


def compile_program(fn_or_prog, *, restrictions=True,
                    optimize_contractions=True,
                    use_kernels=False,
                    infer_distributions=True,
                    dense_fastpath=True,
                    op_select="cost",
                    autotune_cache=None,
                    compile_mode="whole",
                    donate=False,
                    round_fusion=True,
                    skew_rebalance=True,
                    skew_salting="auto",
                    out_of_core="auto",
                    memory_budget=None,
                    chunk_rows=None,
                    lineage=True,
                    speculative=True) -> CompiledProgram:
    """Front door: loop program → restrictions check (Def. 3.1) →
    comprehension translation (Fig. 2) → pass pipeline (passes.py) →
    executable physical plan.

    op_select picks the group-by-⊕ backend policy (DESIGN.md §8):
    "cost" (default) resolves each SegmentReduce's backend from the
    analytical shape-class cost model at trace time; "autotune" measures
    every candidate once per shape class and persists the winner to
    `autotune_cache` (default `.repro_autotune.json`, reloaded by later
    sessions and CI); "force:<backend>" pins one backend everywhere its
    candidate set allows (A/B tests).  use_kernels=True is the legacy
    flag form of "force:pallas" (the one-hot-MXU segment kernel;
    interpret-mode off-TPU).  infer_distributions=False pins every array
    to REP (replicated — the pre-analysis distributed behaviour);
    dense_fastpath=False disables the executor specialization pass
    (DenseMap / MXU AxisReduce / columnar certificates) — operators then
    always materialize the general way.

    compile_mode picks the execution strategy of run() (DESIGN.md §9):
    "whole" (default) traces the entire plan into ONE cached XLA
    computation per (dims, shapes, dtypes) signature; "eager" keeps the
    per-node dispatch path (also the automatic fallback when a whole-
    program trace fails or inputs arrive §5-packed).  donate=True
    additionally donates mutated destinations and SeqLoop carries at the
    jit boundary — callers must then treat jax-array inputs as consumed.
    round_fusion=False disables pass 11 (FusedRound regions / on-device
    distributed loops).

    skew_rebalance=False disables the explicit ONED_VAR→ONED_ROW rebalance
    insertion (skewed arrays then stay variable-block, the pad+mask
    fallback).  skew_salting picks the hot-key salting policy for
    group-bys: "auto" (default) resolves per node from the run-time skew
    probe + cost model, "off" pins S=1 everywhere, "force:<S>" salts every
    eligible group-by with factor S (A/B tests and goldens).

    Out-of-core (DESIGN.md §12): memory_budget (bytes) turns on the hard
    admission check — calls whose memest peak estimate exceeds it stream
    bag tiles through resident accumulators (core/chunked.py) instead of
    running all-resident; classified capacity errors (real XlaRuntimeError
    OOMs or injected ones) descend to the same chunked rung.
    out_of_core: "auto" (default) = admit + descend as above; "force" =
    every run streams (A/B tests); "off" = pre-§12 ladder.  chunk_rows
    pins the streaming tile; None derives it from the budget.

    Surgical recovery (DESIGN.md §13): lineage=True (default) annotates
    every round with its RoundLineage recovery recipe, so a shard lost
    mid-run is recomputed in place instead of descending the ladder;
    lineage=False restores the pre-§13 ladder-only behaviour.
    speculative=True (default) lets the straggler watchdog launch ≤1
    backup execution of a flagged round (first finisher wins);
    speculative=False keeps the watchdog log-only."""
    prog = fn_or_prog if isinstance(fn_or_prog, Program) \
        else fn_or_prog.program
    if restrictions:
        check_restrictions(prog)
    target = translate(prog)
    return CompiledProgram(prog, target, optimize_contractions, use_kernels,
                           infer_distributions, dense_fastpath, op_select,
                           autotune_cache, compile_mode, donate,
                           round_fusion, skew_rebalance, skew_salting,
                           out_of_core, memory_budget, chunk_rows,
                           lineage, speculative)

"""Physical lowering: target comprehensions → JAX.

Each bulk statement is compiled against its *iteration space* (one axis per
generator; extents from range bounds / bag lengths, static under jit):

  value/key/cond expressions  →  broadcasted jnp arrays over the axes
  Get (array access)          →  gather with clipped indices + inRange mask
  group-by on computed keys   →  segment-reduce (scatter-⊕) into the
                                 destination index space  [paper's shuffle]
  group-by on pure axis keys  →  axis reduction (Rule 17 generalized): sum/
                                 min/max over the contracted axes — no
                                 shuffle at all
  …and when the reduction is a +-product of gathers over axis vars:
                                 **einsum** — the join+group-by+sum pattern
                                 becomes an MXU contraction (beyond-paper;
                                 toggle with optimize_contractions=False for
                                 the paper-faithful baseline)
  ◁ merge                     →  scatter (.at[]) with drop semantics for
                                 out-of-range / masked rows
  while                       →  lax.while_loop over the mutated-var carry

The compiled program is a pure function dict->dict and is jit-compatible
(dims must be python ints: they define static shapes).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .analysis import check as check_restrictions
from .comprehension import (BagGen, BulkStore, BulkUpdate, Cond, Get,
                            RangeGen, ScalarAgg, ScalarAssign, SeqWhile,
                            pretty)
from .loop_ast import (BinOp, Call, Const, Program, RejectionError, UnOp,
                       Var)
from .translate import translate


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

_OPS = {
    "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply, "/": jnp.divide,
    "//": jnp.floor_divide, "%": jnp.mod, "**": jnp.power,
    "==": jnp.equal, "!=": jnp.not_equal, "<": jnp.less,
    "<=": jnp.less_equal, ">": jnp.greater, ">=": jnp.greater_equal,
    "and": jnp.logical_and, "or": jnp.logical_or,
}

_FNS = {"sqrt": jnp.sqrt, "exp": jnp.exp, "log": jnp.log, "abs": jnp.abs,
        "sin": jnp.sin, "cos": jnp.cos, "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid, "float": lambda x: jnp.asarray(x, jnp.float32),
        "int": lambda x: jnp.asarray(x, jnp.int32),
        "min": jnp.minimum, "max": jnp.maximum,
        "where": lambda c, a, b: jnp.where(c, a, b)}

_REDUCE = {"+": jnp.sum, "*": jnp.prod, "min": jnp.min, "max": jnp.max}
_COMBINE = {"+": jnp.add, "*": jnp.multiply, "min": jnp.minimum,
            "max": jnp.maximum}


def _identity(op: str, dtype) -> jnp.ndarray:
    if op == "+":
        return jnp.zeros((), dtype)
    if op == "*":
        return jnp.ones((), dtype)
    big = jnp.asarray(np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating) \
        else jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return -big if op == "max" else big


def _scatter_op(ref, op: str):
    return {"+": ref.add, "*": ref.multiply, "min": ref.min, "max": ref.max}[op]


class Axes:
    """Iteration space: ordered axes with extents; values broadcast over it."""

    def __init__(self):
        self.order: list[str] = []
        self.extent: dict[str, int] = {}

    def add(self, name: str, n: int):
        self.order.append(name)
        self.extent[name] = n

    def pos(self, name: str) -> int:
        return self.order.index(name)

    def shape(self):
        return tuple(self.extent[a] for a in self.order)

    def expand(self, arr, axis_name: str):
        """1-D array along `axis_name` → broadcast rank."""
        shape = [1] * len(self.order)
        shape[self.pos(axis_name)] = -1
        return jnp.reshape(arr, shape)


# ---------------------------------------------------------------------------
# statement compilation (closures over env dict)
# ---------------------------------------------------------------------------

class _StmtLowerer:
    def __init__(self, prog: Program, optimize_contractions: bool):
        self.prog = prog
        self.opt_contract = optimize_contractions
        # distributed mode: traced global-index offsets for sharded bags
        self.bag_offset: dict = {}
        # route +-group-bys through the Pallas one-hot-MXU kernel
        self.use_kernels: bool = False

    # ---- static scalars (dims / range bounds) ----
    def static_int(self, e, env) -> int:
        if isinstance(e, Const):
            return int(e.value)
        if isinstance(e, Var):
            v = env[e.name]
            if isinstance(v, (int, np.integer)):
                return int(v)
            raise RejectionError(
                f"range bound '{e.name}' must be a static dim (python int)")
        if isinstance(e, BinOp):
            l = self.static_int(e.lhs, env)
            r = self.static_int(e.rhs, env)
            return int({"+": l + r, "-": l - r, "*": l * r,
                        "//": l // r, "/": l // r}[e.op])
        raise RejectionError(f"non-static range bound {e}")

    # ---- build iteration space ----
    def axes_of(self, quals, env) -> tuple[Axes, dict, list]:
        ax = Axes()
        binding: dict[str, tuple] = {}   # var -> ("range", axis, lo) | ("bagval", axis, col)
        conds = []
        for q in quals:
            if isinstance(q, RangeGen):
                lo = self.static_int(q.lo, env)
                hi = self.static_int(q.hi, env)
                ax.add(q.var, max(hi - lo, 0))
                binding[q.var] = ("range", q.var, lo)
            elif isinstance(q, BagGen):
                bagv = env[q.bag]
                cols = bagv if isinstance(bagv, tuple) else (bagv,)
                n = int(cols[0].shape[0])
                ax.add(q.idx, n)
                binding[q.idx] = ("range", q.idx,
                                  self.bag_offset.get(q.bag, 0))
                for j, v in enumerate(q.vals):
                    binding[v] = ("bagval", q.idx, cols[j])
            else:
                conds.append(q.e)
        return ax, binding, conds

    # ---- expression evaluation over the iteration space ----
    def eval(self, e, env, ax: Axes, binding, masks: list):
        if isinstance(e, Const):
            return jnp.asarray(e.value)
        if isinstance(e, Var):
            if e.name in binding:
                kind, axis, aux = binding[e.name]
                if kind == "range":
                    return ax.expand(aux + jnp.arange(ax.extent[axis]), axis)
                return ax.expand(aux, axis)
            return jnp.asarray(env[e.name])
        if isinstance(e, Get):
            arr = env[e.array]
            from .tiles import TiledMatrix, unpack
            if isinstance(arr, TiledMatrix):   # §5 fallback: unpack on read
                arr = unpack(arr)
            # identity-traversal fast path: V[i] / M[i,j] over full ranges is
            # the array itself, broadcast into the iteration space (no gather)
            if all(isinstance(ix, Var) and ix.name in binding
                   and binding[ix.name][0] == "range"
                   and isinstance(binding[ix.name][2], int)
                   and binding[ix.name][2] == 0
                   and ax.extent[ix.name] == d
                   for ix, d in zip(e.idxs, arr.shape)) and \
                    len({ix.name for ix in e.idxs}) == len(e.idxs):
                names = [ix.name for ix in e.idxs]
                shape = [1] * len(ax.order)
                perm_src = sorted(names, key=ax.pos)
                a2 = jnp.transpose(arr, [names.index(a) for a in perm_src])
                for a in perm_src:
                    shape[ax.pos(a)] = ax.extent[a]
                return jnp.reshape(a2, shape)
            idxs = [self.eval(i, env, ax, binding, masks) for i in e.idxs]
            clipped = []
            for d, ix in zip(arr.shape, idxs):
                ix = jnp.asarray(ix, jnp.int32)
                masks.append((ix >= 0) & (ix < d))
                clipped.append(jnp.clip(ix, 0, d - 1))
            if len(clipped) == 1:
                return jnp.take(arr, clipped[0], axis=0)
            return arr[tuple(jnp.broadcast_arrays(*clipped))]
        if isinstance(e, BinOp):
            return _OPS[e.op](self.eval(e.lhs, env, ax, binding, masks),
                              self.eval(e.rhs, env, ax, binding, masks))
        if isinstance(e, UnOp):
            v = self.eval(e.e, env, ax, binding, masks)
            return -v if e.op == "neg" else jnp.logical_not(v)
        if isinstance(e, Call):
            return _FNS[e.fn](*[self.eval(a, env, ax, binding, masks)
                                for a in e.args])
        raise RejectionError(f"cannot lower expression {e}")

    def _mask(self, conds, env, ax, binding, masks):
        for c in conds:
            masks.append(self.eval(c, env, ax, binding, masks))
        if not masks:
            return None
        m = masks[0]
        for x in masks[1:]:
            m = jnp.logical_and(m, x)
        return jnp.broadcast_to(m, ax.shape()) if ax.order else m

    # ---- key classification ----
    def _axis_keys(self, keys, binding):
        """keys that are distinct pure generator-axis vars, else None."""
        names = []
        for k in keys:
            if isinstance(k, Var) and k.name in binding \
                    and binding[k.name][0] == "range":
                names.append(k.name)
            else:
                return None
        return names if len(set(names)) == len(names) else None

    # ---- einsum contraction recognition (beyond-paper) ----
    def _try_einsum(self, st: BulkUpdate, key_axes, ax: Axes, env, binding,
                    contracted):
        if not self.opt_contract or st.op != "+" or not contracted:
            return None
        factors = []
        others = []

        def flatten(e):
            if isinstance(e, BinOp) and e.op == "*":
                flatten(e.lhs)
                flatten(e.rhs)
            elif isinstance(e, Get):
                factors.append(e)
            else:
                others.append(e)
        flatten(st.value)
        if len(factors) < 1:
            return None
        # every factor index must be a pure range-axis var with full extent
        letters = {a: chr(ord('a') + i) for i, a in enumerate(ax.order)}
        from .tiles import TiledMatrix, matmul_tiled, unpack
        specs = []
        operands = []
        tiled_first = len(factors) == 2 and \
            isinstance(env[factors[0].array], TiledMatrix)
        for f in factors:
            arr = env[f.array]
            if isinstance(arr, TiledMatrix):
                if not tiled_first or f is not factors[0]:
                    arr = unpack(arr)   # §5 fusion only on the lhs of matmul
            spec = ""
            for d, ix in zip(arr.shape, f.idxs):
                if not (isinstance(ix, Var) and ix.name in binding
                        and binding[ix.name][0] == "range"):
                    return None
                axn = ix.name
                lo = binding[axn][2]
                if not isinstance(lo, int):
                    return None
                if lo != 0 or ax.extent[axn] != d:
                    if lo + ax.extent[axn] > d:
                        return None
                    arr = jax.lax.slice_in_dim(arr, lo, lo + ax.extent[axn],
                                               axis=len(spec))
                spec += letters[axn]
            specs.append(spec)
            operands.append(arr)
        for o in others:  # residual scalar factors only
            if isinstance(o, Const):
                continue
            if isinstance(o, Var) and o.name not in binding:
                continue
            return None
        out_spec = "".join(letters[a] for a in key_axes)
        used = set("".join(specs))
        if not set(out_spec) <= used or not \
                all(letters[a] in used for a in contracted):
            return None
        # §5 packed-array fusion: matmul-shaped contraction on a tiled lhs
        # runs the block-sparse Pallas kernel directly on the tiles
        if tiled_first and specs[0][1] == specs[1][0] and \
                out_spec == specs[0][0] + specs[1][1] and \
                len(specs[0]) == 2 and len(specs[1]) == 2:
            res = matmul_tiled(env[factors[0].array], operands[1])
        else:
            if tiled_first:
                operands = [unpack(env[factors[0].array])] + operands[1:]
            res = jnp.einsum(",".join(specs) + "->" + out_spec, *operands)
        for o in others:
            res = res * self.eval(o, env, ax, binding, [])
        return res

    def _axes_used(self, e, binding, ax):
        used = set()

        def go(x):
            if isinstance(x, Var) and x.name in binding:
                k, axis, _ = binding[x.name]
                used.add(axis)
            elif isinstance(x, Get):
                for i in x.idxs:
                    go(i)
            elif isinstance(x, BinOp):
                go(x.lhs)
                go(x.rhs)
            elif isinstance(x, UnOp):
                go(x.e)
            elif isinstance(x, Call):
                for a in x.args:
                    go(a)
        go(e)
        return used

    def _try_term_split(self, st, key_axes, ax, env, binding, contracted):
        """value = s1*s2*(Σ terms): strip axis-free scalar factors, einsum
        each product term; a term free of the contracted axes reduces to
        extent-product x term (Σ_j c = |j|·c) instead of a grid."""
        scalars: list = []
        value = st.value
        while isinstance(value, BinOp) and value.op == "*":
            if not self._axes_used(value.lhs, binding, ax):
                scalars.append(value.lhs)
                value = value.rhs
            elif not self._axes_used(value.rhs, binding, ax):
                scalars.append(value.rhs)
                value = value.lhs
            else:
                break
        terms: list = []

        def split(e, sign):
            if isinstance(e, BinOp) and e.op in ("+", "-"):
                split(e.lhs, sign)
                split(e.rhs, sign if e.op == "+" else -sign)
            elif isinstance(e, UnOp) and e.op == "neg":
                split(e.e, -sign)
            else:
                terms.append((sign, e))
        split(value, 1)
        if len(terms) < 2:
            return None

        key_exts = tuple(ax.extent[a] for a in ax.order if a in key_axes)
        cur = [a for a in ax.order if a in key_axes]
        perm = [cur.index(a) for a in key_axes]
        total = None
        for sign, term in terms:
            used = self._axes_used(term, binding, ax)
            if not (used & set(contracted)):
                masks: list = []
                v = self.eval(term, env, ax, binding, masks)
                if masks:
                    return None
                mult = 1
                for a in contracted:
                    mult *= ax.extent[a]
                if jnp.ndim(v) == 0:
                    part = jnp.broadcast_to(v, key_exts)
                else:  # full-rank with size-1 contracted dims: drop them
                    part = jnp.squeeze(
                        v, axis=tuple(ax.pos(a) for a in contracted))
                    part = jnp.broadcast_to(part, key_exts)
                part = jnp.transpose(part, perm) * mult
            else:
                sub = BulkUpdate(st.dest, st.keys, "+", term, st.quals)
                part = self._try_einsum(sub, key_axes, ax, env, binding,
                                        contracted)
                if part is None:
                    return None
            total = part * sign if total is None else total + part * sign
        for sc in scalars:
            total = total * self.eval(sc, env, ax, binding, [])
        return total

    # ---- bulk statements ----
    def lower_update(self, st: BulkUpdate, env):
        ax, binding, conds = self.axes_of(st.quals, env)
        dest = env[st.dest]

        # Rule (16): constant group-by keys -> one total aggregation and a
        # single-element ⊕ update (no segment scatter)
        if st.keys and all(isinstance(k, Const) for k in st.keys):
            total = self._total_reduce(st.op, st.value, conds, env, ax,
                                       binding)
            ii = tuple(int(k.value) for k in st.keys)
            return _scatter_op(dest.at[ii], st.op)(total.astype(dest.dtype))

        key_axes = self._axis_keys(st.keys, binding)

        if key_axes is not None:
            contracted = [a for a in ax.order if a not in key_axes]
            ein = self._try_einsum(st, key_axes, ax, env, binding, contracted)
            if ein is None and not conds and st.op == "+" and contracted \
                    and self.opt_contract:
                ein = self._try_term_split(st, key_axes, ax, env, binding,
                                           contracted)
            if ein is not None and not conds:
                partial = ein
                in_key_order = True
            else:
                in_key_order = False
                masks: list = []
                val = self.eval(st.value, env, ax, binding, masks)
                m = self._mask(conds, env, ax, binding, masks)
                val = jnp.broadcast_to(val, ax.shape())
                if m is not None:
                    val = jnp.where(m, val, _identity(st.op, val.dtype))
                if contracted:
                    partial = _REDUCE[st.op](
                        val, axis=tuple(ax.pos(a) for a in contracted))
                else:
                    partial = val
            # reorder to key order + scatter-⊕ at the (affine) offsets
            if not in_key_order:
                cur = [a for a in ax.order if a in key_axes]
                partial = jnp.transpose(partial,
                                        [cur.index(a) for a in key_axes])
            los = [binding[a][2] for a in key_axes]
            exts = [ax.extent[a] for a in key_axes]
            static0 = all(isinstance(l, int) and l == 0 for l in los)
            if tuple(exts) == dest.shape and static0:
                return _COMBINE[st.op](dest, partial.astype(dest.dtype))
            grids = tuple(
                (los[i] + jnp.arange(exts[i])).reshape(
                    [-1 if j == i else 1 for j in range(len(exts))])
                for i in range(len(exts)))
            return _scatter_op(dest.at[grids], st.op)(
                partial.astype(dest.dtype), mode="drop")

        # computed keys → flatten + segment-⊕ (the paper's group-by)
        masks = []
        keys = [self.eval(k, env, ax, binding, masks) for k in st.keys]
        val = self.eval(st.value, env, ax, binding, masks)
        m = self._mask(conds, env, ax, binding, masks)
        shape = ax.shape()
        val = jnp.broadcast_to(val, shape).reshape(-1)
        kk = [jnp.broadcast_to(jnp.asarray(k, jnp.int32), shape).reshape(-1)
              for k in keys]
        flat, num = self._ravel_keys(kk, dest.shape)
        if m is not None:
            flat = jnp.where(m.reshape(-1), flat, num)  # dropped
        if getattr(self, "use_kernels", False) and st.op == "+":
            # Pallas one-hot-MXU segment kernel as the group-by backend
            from ..kernels import ops as kops
            seg = kops.segment_sum(flat, val[:, None].astype(jnp.float32),
                                   num)[:, 0]
        else:
            seg = jnp.full((num,), _identity(st.op, val.dtype), val.dtype)
            seg = _scatter_op(seg.at[flat], st.op)(val, mode="drop")
        return _COMBINE[st.op](dest, seg.reshape(dest.shape).astype(dest.dtype))

    def _ravel_keys(self, kk, dshape):
        num = 1
        for d in dshape:
            num *= d
        flat = jnp.zeros_like(kk[0])
        ok = jnp.ones_like(kk[0], dtype=bool)
        for k, d in zip(kk, dshape):
            ok &= (k >= 0) & (k < d)
            flat = flat * d + jnp.clip(k, 0, d - 1)
        flat = jnp.where(ok, flat, num)
        return flat, num

    def lower_store(self, st: BulkStore, env):
        ax, binding, conds = self.axes_of(st.quals, env)
        dest = env[st.dest]
        masks: list = []
        val = self.eval(st.value, env, ax, binding, masks)
        m = self._mask(conds, env, ax, binding, masks)
        key_axes = self._axis_keys(st.keys, binding)

        if key_axes is not None and set(key_axes) == set(ax.order):
            val = jnp.broadcast_to(val, ax.shape())
            perm = [ax.order.index(a) for a in key_axes]
            val = jnp.transpose(val, perm)
            if m is not None:
                m = jnp.transpose(jnp.broadcast_to(m, ax.shape()), perm)
            los = [binding[a][2] for a in key_axes]
            exts = [ax.extent[a] for a in key_axes]
            static0 = all(isinstance(l, int) and l == 0 for l in los)
            if tuple(exts) == dest.shape and static0 and m is None:
                return val.astype(dest.dtype)                 # full replace
            grids = list(jnp.meshgrid(
                *[los[i] + jnp.arange(exts[i]) for i in range(len(exts))],
                indexing="ij"))
            if m is not None:
                grids[0] = jnp.where(m, grids[0], dest.shape[0])  # drop
            return dest.at[tuple(grids)].set(val.astype(dest.dtype),
                                             mode="drop")

        # affine computed keys → scatter (restrictions ⇒ no duplicates)
        shape = ax.shape()
        val = jnp.broadcast_to(val, shape)
        kk = [jnp.broadcast_to(jnp.asarray(
            self.eval(k, env, ax, binding, masks), jnp.int32), shape)
            for k in st.keys]
        ok = jnp.ones(shape, bool) if m is None else m
        for k, d in zip(kk, dest.shape):
            ok &= (k >= 0) & (k < d)
        kk = [jnp.where(ok, k, d) for k, d in zip(kk, dest.shape)]
        return dest.at[tuple(kk)].set(val.astype(dest.dtype), mode="drop")

    def _total_reduce(self, op, value, conds, env, ax, binding):
        """⊕-reduce `value` over the whole iteration space.  Peephole:
        max/min over float(bool) lowers to any/all (XLA-CPU f32 max-reduce
        is ~20x slower than a bool reduce; same result)."""
        from .loop_ast import Call as _Call
        masks: list = []
        if op in ("max", "min") and isinstance(value, _Call) and \
                value.fn == "float" and not conds:
            b = self.eval(value.args[0], env, ax, binding, masks)
            if not masks and ax.order:
                red = jnp.any if op == "max" else jnp.all
                return red(jnp.asarray(b, bool)).astype(jnp.float32)
            masks = []
        val = self.eval(value, env, ax, binding, masks)
        m = self._mask(conds, env, ax, binding, masks)
        val = jnp.broadcast_to(val, ax.shape()) if ax.order else val
        if m is not None:
            val = jnp.where(m, val, _identity(op, jnp.asarray(val).dtype))
        return _REDUCE[op](val) if ax.order else val

    def lower_scalar_agg(self, st: ScalarAgg, env):
        ax, binding, conds = self.axes_of(st.quals, env)
        dest = jnp.asarray(env[st.dest])
        total = self._total_reduce(st.op, st.value, conds, env, ax, binding)
        return _COMBINE[st.op](dest, total.astype(dest.dtype))

    def lower_scalar_assign(self, st: ScalarAssign, env):
        ax, binding, conds = self.axes_of(st.quals, env)
        masks: list = []
        val = self.eval(st.value, env, ax, binding, masks)
        m = self._mask(conds, env, ax, binding, masks)
        if m is not None:
            old = env.get(st.dest, jnp.zeros_like(val))
            return jnp.where(m, val, old)
        return val


# ---------------------------------------------------------------------------
# program compilation
# ---------------------------------------------------------------------------

class CompiledProgram:
    def __init__(self, prog: Program, target, optimize_contractions=True,
                 use_kernels=False):
        self.program = prog
        self.target = target
        self._low = _StmtLowerer(prog, optimize_contractions)
        self._low.use_kernels = use_kernels

    def pretty_target(self) -> str:
        return "\n".join(pretty(s) for s in self.target)

    def _mutated(self, stmts) -> list[str]:
        names = []
        for s in stmts:
            if isinstance(s, SeqWhile):
                names += self._mutated(s.body)
            else:
                if s.dest not in names:
                    names.append(s.dest)
        return names

    def _exec(self, stmts, env):
        low = self._low
        for st in stmts:
            if isinstance(st, BulkUpdate):
                env[st.dest] = low.lower_update(st, env)
            elif isinstance(st, BulkStore):
                env[st.dest] = low.lower_store(st, env)
            elif isinstance(st, ScalarAgg):
                env[st.dest] = low.lower_scalar_agg(st, env)
            elif isinstance(st, ScalarAssign):
                env[st.dest] = low.lower_scalar_assign(st, env)
            elif isinstance(st, SeqWhile):
                carry_names = self._mutated(st.body)
                carry0 = tuple(jnp.asarray(env[n]) for n in carry_names)

                def cond_fn(c, _names=carry_names, _st=st):
                    e2 = dict(env)
                    e2.update(dict(zip(_names, c)))
                    return jnp.asarray(
                        low.eval(_st.cond, e2, Axes(), {}, []), bool)

                def body_fn(c, _names=carry_names, _st=st):
                    e2 = dict(env)
                    e2.update(dict(zip(_names, c)))
                    self._exec(_st.body, e2)
                    return tuple(jnp.asarray(e2[n]) for n in _names)

                out = jax.lax.while_loop(cond_fn, body_fn, carry0)
                env.update(dict(zip(carry_names, out)))
            else:
                raise RejectionError(f"cannot execute {st}")

    def run(self, inputs: dict) -> dict:
        env = {}
        for name, t in self.program.params.items():
            v = inputs[name]
            if t.kind == "dim":
                env[name] = int(v)
            elif t.kind == "bag":
                env[name] = tuple(jnp.asarray(c) for c in v) \
                    if isinstance(v, tuple) else (jnp.asarray(v),)
            elif t.kind in ("vector", "matrix", "map"):
                from .tiles import TiledMatrix
                if isinstance(v, TiledMatrix):
                    env[name] = v      # §5 packed input, fused where possible
                else:
                    env[name] = jnp.asarray(
                        v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = jnp.asarray(v)
        self._exec(self.target, env)
        return {n: env[n] for n in self.program.outputs}

    def __call__(self, **inputs):
        return self.run(inputs)


def compile_program(fn_or_prog, *, restrictions=True,
                    optimize_contractions=True,
                    use_kernels=False) -> CompiledProgram:
    """Front door: loop program → restrictions check (Def. 3.1) →
    comprehension translation (Fig. 2) → compiled JAX executable.
    use_kernels=True routes +-group-bys through the Pallas one-hot-MXU
    segment kernel (interpret-mode off-TPU)."""
    prog = fn_or_prog if isinstance(fn_or_prog, Program) \
        else fn_or_prog.program
    if restrictions:
        check_restrictions(prog)
    target = translate(prog)
    return CompiledProgram(prog, target, optimize_contractions, use_kernels)

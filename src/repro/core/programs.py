"""The paper's benchmark programs (§6 / Appendix B) in the loop DSL.

Same program set as Table 1 / Figure 3: Average, Count, Conditional Count/
Sum, Equal, String Match, Word Count, Histogram, Linear Regression,
Group-By, Matrix Addition/Multiplication, PageRank, KMeans, Matrix
Factorization.  Strings are dictionary-encoded to int codes (columnar
standard; DESIGN.md §2).
"""
from __future__ import annotations

from .frontend import bag, dim, loop_program, map_, matrix, scalar, vector


@loop_program
def average(V: bag[1], s: scalar, cnt: scalar, avg: scalar):
    for v in V:
        s += v
        cnt += 1.0
    avg = s / cnt


@loop_program
def count(V: bag[1], cnt: scalar):
    for v in V:
        cnt += 1.0


@loop_program
def conditional_count(V: bag[1], cnt: scalar, limit: scalar):
    for v in V:
        if v < limit:
            cnt += 1.0


@loop_program
def conditional_sum(V: bag[1], s: scalar, limit: scalar):
    for v in V:
        if v < limit:
            s += v


@loop_program
def equal(W: bag[1], first: scalar, diffs: scalar):
    # all strings equal <=> no element differs from the first (codes)
    for w in W:
        if w != first:
            diffs += 1.0


@loop_program
def string_match(W: bag[1], k1: scalar, k2: scalar, k3: scalar,
                 found: vector):
    for w in W:
        found[0] = max(found[0], float(w == k1))
        found[1] = max(found[1], float(w == k2))
        found[2] = max(found[2], float(w == k3))


@loop_program
def word_count(W: bag[1], C: map_):
    for i, w in items(W):
        C[w] += 1.0


@loop_program
def histogram(P: bag[3], R: map_, G: map_, B: map_):
    for r, g, b in P:
        R[r] += 1.0
        G[g] += 1.0
        B[b] += 1.0


@loop_program
def group_by(S: bag[2], C: map_):
    for k, v in S:
        C[k] += v


@loop_program
def linear_regression(P: bag[2], n: dim, sum_x: scalar, sum_y: scalar,
                      x_bar: scalar, y_bar: scalar, xx_bar: scalar,
                      xy_bar: scalar, slope: scalar, intercept: scalar):
    for x, y in P:
        sum_x += x
        sum_y += y
    x_bar = sum_x / n
    y_bar = sum_y / n
    for x, y in P:
        xx_bar += (x - x_bar) * (x - x_bar)
        xy_bar += (x - x_bar) * (y - y_bar)
    slope = xy_bar / xx_bar
    intercept = y_bar - slope * x_bar


@loop_program
def matrix_addition(M: matrix, N: matrix, R: matrix, n: dim, m: dim):
    for i in range(0, n):
        for j in range(0, m):
            R[i, j] = M[i, j] + N[i, j]


@loop_program
def matrix_multiplication(M: matrix, N: matrix, R: matrix,
                          n: dim, m: dim, l: dim):
    for i in range(0, n):
        for j in range(0, m):
            R[i, j] = 0.0
            for k in range(0, l):
                R[i, j] += M[i, k] * N[k, j]


@loop_program
def pagerank(E: bag[2], P: vector, NP: vector, C: vector, N: dim,
             num_steps: scalar, steps: scalar, b: scalar):
    for s, d in E:
        C[s] += 1.0
    while steps < num_steps:
        steps += 1.0
        for i in range(0, N):
            NP[i] = 0.0
        for s, d in E:
            NP[d] += P[s] / C[s]
        for i in range(0, N):
            P[i] = (1.0 - b) / N + b * NP[i]


@loop_program
def kmeans_step(P: bag[2], CX: vector, CY: vector, K: dim,
                D: matrix, MinD: vector, Cl: vector,
                SX: vector, SY: vector, CN: vector,
                NX: vector, NY: vector):
    for i, x, y in items(P):
        for j in range(0, K):
            D[i, j] = (x - CX[j]) * (x - CX[j]) + (y - CY[j]) * (y - CY[j])
    for i, x, y in items(P):
        for j in range(0, K):
            MinD[i] = min(MinD[i], D[i, j])
    for i, x, y in items(P):
        for j in range(0, K):
            Cl[i] = max(Cl[i], float(j) * float(D[i, j] == MinD[i])
                        - 1e9 * float(D[i, j] != MinD[i]))
    for i, x, y in items(P):
        SX[int(Cl[i])] += x
        SY[int(Cl[i])] += y
        CN[int(Cl[i])] += 1.0
    for j in range(0, K):
        NX[j] = SX[j] / max(CN[j], 1.0)
        NY[j] = SY[j] / max(CN[j], 1.0)


@loop_program
def matrix_factorization_step(R: matrix, P: matrix, Q: matrix,
                              Pp: matrix, Qp: matrix,
                              pq: matrix, err: matrix,
                              n: dim, m: dim, l: dim,
                              a: scalar, lam: scalar):
    # paper §3.2 (fixed version: pq / err are matrices, not scalars)
    for i in range(0, n):
        for j in range(0, m):
            pq[i, j] = 0.0
            for k in range(0, l):
                pq[i, j] += Pp[i, k] * Qp[k, j]
            err[i, j] = R[i, j] - pq[i, j]
            for k in range(0, l):
                P[i, k] += a * (2.0 * err[i, j] * Qp[k, j] - lam * Pp[i, k])
                Q[k, j] += a * (2.0 * err[i, j] * Pp[i, k] - lam * Qp[k, j])


# ---- rejected programs (paper §3.2 counterexamples) ----

def rejected_programs():
    """Programs the paper rejects; returned as (name, builder) so tests can
    assert RejectionError at parse/check time."""
    from .frontend import parse_program

    def smoothing():
        def p(V: vector, n: dim):
            for i in range(1, n - 1):
                V[i] = (V[i - 1] + V[i + 1]) / 2.0
        return parse_program(p)

    def scalar_temp():
        def p(V: vector, W: vector, n: dim, t: scalar):
            for i in range(0, n):
                t = V[i]
                W[i] = t * 2.0
        return parse_program(p)

    def mf_scalar_pq():
        def p(R: matrix, P: matrix, Q: matrix, n: dim, m: dim, l: dim,
              pq: scalar, err: scalar):
            for i in range(0, n):
                for j in range(0, m):
                    pq = 0.0
                    for k in range(0, l):
                        pq += P[i, k] * Q[k, j]
                    err = R[i, j] - pq
        return parse_program(p)

    return [("smoothing", smoothing), ("scalar_temp", scalar_temp),
            ("mf_scalar_pq", mf_scalar_pq)]


ALL = {
    "average": average, "count": count,
    "conditional_count": conditional_count,
    "conditional_sum": conditional_sum, "equal": equal,
    "string_match": string_match, "word_count": word_count,
    "histogram": histogram, "group_by": group_by,
    "linear_regression": linear_regression,
    "matrix_addition": matrix_addition,
    "matrix_multiplication": matrix_multiplication,
    "pagerank": pagerank, "kmeans_step": kmeans_step,
    "matrix_factorization_step": matrix_factorization_step,
}

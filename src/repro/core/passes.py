"""Optimizer pass pipeline: target comprehensions → physical plan.

Every *recognition* decision the compiler makes lives here, as an ordered
sequence of passes over plan nodes (lower.py only materializes the chosen
operators, with runtime extent/representation guards).  The ordering is a
contract (see DESIGN.md):

  1. iteration-spaces           comprehension qualifiers → IterSpace; every
                                statement gets its naive physical operator
                                (the paper-faithful plan)
  2. identity-traversal         Get reads whose indices are distinct
                                generator-axis vars → broadcast-eligible
                                Gather (no gather when extents cover)
  3. axis-key-classification    group-by keys that are pure axis vars →
                                AxisReduce (Rule 17 generalized); constant
                                keys → ScalarReduce at a point (Rule 16)
  4. dense-fastpath             operator *specialization*, never an operator
                                change: identity-space MapExpr → DenseMap
                                (vectorized store, no index grids/gathers);
                                +-AxisReduce of a product of gathers gets an
                                MXU `product` certificate (executed via
                                jnp.einsum even in the paper-faithful
                                configuration); gather-free ScalarReduce
                                marked `dense` (pure columnar fold)
  5. einsum-recognition         +-AxisReduce of a product of gathers (or a
                                ±-sum of products) → EinsumContract
                                (beyond-paper MXU contraction)
  6. tiled-fusion               matmul-shaped EinsumContract → TiledMatmul
                                (§5: block-sparse Pallas kernel on packed
                                lhs, no unpack)
  7. dead-store-elimination     a store fully overwritten by a later
                                equal-coverage unconditional store, with no
                                intervening reader, is dropped
  8. update-fusion              consecutive reductions sharing an iteration
                                space and touching disjoint state → Fused
                                (one distributed collective round)
  9. distribution-analysis      fixed-point inference of a per-array
                                sharding (REP ≤ ONED_ROW ≤ TWOD_BLOCK) over
                                the finished plan; annotation-only
                                (dist_analysis.py, DESIGN.md §6)
 10. operator-selection         backend CANDIDATE sets on SegmentReduce
                                (scatter / sort / onehot / pallas) and the
                                contraction nodes; the concrete choice is
                                resolved at trace time by the cost-model /
                                autotune selector (op_select.py, DESIGN.md
                                §8); annotation-only
 11. round-fusion               adjacent shard-mappable nodes → FusedRound
                                regions (one shard_map program per region,
                                collectives inside; a fully-fusable SeqLoop
                                body becomes the on-device-loop candidate);
                                sequencing-only — the single-device
                                executor runs members unchanged (DESIGN.md
                                §9)

Passes 2-6 must run in this order: classification consumes rewritten reads,
dense-fastpath recognizes products on AxisReduce nodes from 3, einsum
promotes that recognition to EinsumContract nodes, tiled-fusion consumes
EinsumContract nodes.  Passes 7-8 are cleanups over the final operator
choice and must run last among the transforms (fusion would otherwise hide
stores from the deadness scan).  Passes 9-11 transform nothing — they must
see the FINAL operator choices (a Fused round places all its parts, an
eliminated store constrains nothing), so they run after everything else;
10 follows 9 because a backend's shape class includes the destination's
inferred sharding, and 11 follows both because a region groups nodes whose
round classification (placements included) is already final.
"""
from __future__ import annotations

from dataclasses import dataclass

from . import plan as P
from .comprehension import (BagGen, BulkStore, BulkUpdate, Cond, Get,
                            RangeGen, ScalarAgg, ScalarAssign, SeqWhile)
from .loop_ast import (BinOp, Call, Const, Index, Program, RejectionError,
                       UnOp, Var)


@dataclass(frozen=True)
class PlanConfig:
    optimize_contractions: bool = True   # False = paper-faithful plans
    use_kernels: bool = False            # legacy: force the Pallas segment
    #                                      kernel (= op_select "force:pallas")
    infer_distributions: bool = True     # False = REP-everything annotations
    dense_fastpath: bool = True          # False = no executor specialization
    op_select: str = "cost"              # "cost" | "autotune" | "force:<b>"
    autotune_cache: str = ".repro_autotune.json"   # on-disk decision cache
    round_fusion: bool = True            # False = one shard_map per node
    skew_rebalance: bool = True          # False = never pin ONED_VAR up /
    #                                      insert Rebalance rounds (fallback:
    #                                      arrays keep variable blocks)
    skew_salting: str = "auto"           # hot-key salting for group-bys:
    #                                      "auto" (cost model + runtime
    #                                      probe) | "off" | "force:<S>"
    #                                      (static hint: S sub-keys per key)
    out_of_core: str = "auto"            # chunked capacity tier (§12):
    #                                      "auto" (admit vs budget + descend
    #                                      on capacity) | "force" | "off"
    memory_budget: int | None = None     # device bytes the admission check
    #                                      holds a call's memest peak under
    chunk_rows: int | None = None        # pinned streaming tile; None =
    #                                      derive from budget (memest)
    lineage: bool = True                 # False = no RoundLineage recipes:
    #                                      shard loss descends the ladder
    #                                      instead of recovering surgically
    speculative: bool = True             # False = straggler watchdog stays
    #                                      log-only (no backup executions)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _space_of(quals) -> P.IterSpace:
    axes, conds = [], []
    for q in quals:
        if isinstance(q, RangeGen):
            axes.append(P.AxisSpec("range", q.var, lo=q.lo, hi=q.hi))
        elif isinstance(q, BagGen):
            axes.append(P.AxisSpec("bag", q.idx, bag=q.bag, vals=q.vals))
        else:
            conds.append(q.e)
    return P.IterSpace(tuple(axes), tuple(conds))


def _expr_names(e, acc: set):
    if isinstance(e, (Get, P.Gather, Index)):
        acc.add(e.array)
        for i in e.idxs:
            _expr_names(i, acc)
    elif isinstance(e, Var):
        acc.add(e.name)
    elif isinstance(e, BinOp):
        _expr_names(e.lhs, acc)
        _expr_names(e.rhs, acc)
    elif isinstance(e, UnOp):
        _expr_names(e.e, acc)
    elif isinstance(e, Call):
        for a in e.args:
            _expr_names(a, acc)


def _refs_of(st, prog: Program) -> frozenset:
    """Env names a statement reads (params/outputs only; loop vars shadow)."""
    names: set = set()
    bound: set = set()
    for q in getattr(st, "quals", []):
        if isinstance(q, BagGen):
            names.add(q.bag)
            bound |= set(q.vals) | {q.idx}
        elif isinstance(q, RangeGen):
            _expr_names(q.lo, names)
            _expr_names(q.hi, names)
            bound.add(q.var)
        else:
            _expr_names(q.e, names)
    if hasattr(st, "value"):
        _expr_names(st.value, names)
    for k in getattr(st, "keys", ()):
        _expr_names(k, names)
    names -= bound
    return frozenset(n for n in names
                     if n in prog.params or n in prog.outputs)


def _axis_keys(keys, space: P.IterSpace):
    """Keys that are distinct pure generator-axis vars, else None."""
    axis_vars = set(space.axis_vars)
    names = []
    for k in keys:
        if isinstance(k, Var) and k.name in axis_vars:
            names.append(k.name)
        else:
            return None
    return names if len(set(names)) == len(names) else None


def _var_axis_map(space: P.IterSpace) -> dict:
    """var → axis name, for axis vars and bag value-column vars."""
    m = {}
    for a in space.axes:
        m[a.var] = a.var
        for v in a.vals:
            m[v] = a.var
    return m


def _axes_used(e, space: P.IterSpace) -> set:
    va = _var_axis_map(space)
    used: set = set()

    def go(x):
        if isinstance(x, Var):
            if x.name in va:
                used.add(va[x.name])
        elif isinstance(x, (Get, P.Gather)):
            for i in x.idxs:
                go(i)
        elif isinstance(x, BinOp):
            go(x.lhs)
            go(x.rhs)
        elif isinstance(x, UnOp):
            go(x.e)
        elif isinstance(x, Call):
            for a in x.args:
                go(a)
    go(e)
    return used


def _transform_blocks(nodes: list, block_fn) -> list:
    """Apply a statement-block transform to the top level and every SeqLoop
    body (fusion/deadness never cross a sequential-loop boundary)."""
    out = block_fn(nodes)
    for n in out:
        if isinstance(n, P.SeqLoop):
            n.body = _transform_blocks(n.body, block_fn)
    return out


def _map_nodes(nodes: list, node_fn) -> list:
    out = []
    for n in nodes:
        if isinstance(n, P.SeqLoop):
            n.body = _map_nodes(n.body, node_fn)
            out.append(node_fn(n))
        else:
            out.append(node_fn(n))
    return out


# ---------------------------------------------------------------------------
# pass 1: iteration-space construction (naive / paper-faithful operators)
# ---------------------------------------------------------------------------

def build_spaces(target: list, prog: Program) -> list:
    nodes = []
    for st in target:
        if isinstance(st, BulkUpdate):
            nodes.append(P.SegmentReduce(
                st, _space_of(st.quals), _refs_of(st, prog),
                st.dest, tuple(st.keys), st.op, st.value))
        elif isinstance(st, BulkStore):
            nodes.append(P.Scatter(
                st, _space_of(st.quals), _refs_of(st, prog),
                st.dest, tuple(st.keys), st.value))
        elif isinstance(st, ScalarAgg):
            nodes.append(P.ScalarReduce(
                st, _space_of(st.quals), _refs_of(st, prog),
                st.dest, st.op, st.value))
        elif isinstance(st, ScalarAssign):
            nodes.append(P.MapExpr(
                st, _space_of(st.quals), _refs_of(st, prog),
                st.dest, st.value, key_axes=None))
        elif isinstance(st, SeqWhile):
            body = build_spaces(st.body, prog)
            carry: list = []
            for b in body:
                for d in P.dests_of(b):
                    if d not in carry:
                        carry.append(d)
            reads: set = set()
            _expr_names(st.cond, reads)
            reads = {n for n in reads if n in prog.params or n in prog.outputs}
            for b in body:
                reads |= b.reads
            nodes.append(P.SeqLoop(st, P.IterSpace(()), frozenset(reads),
                                   st.cond, body, tuple(carry)))
        else:
            raise RejectionError(f"cannot plan statement {st}")
    return nodes


# ---------------------------------------------------------------------------
# pass 2: identity-traversal elimination (physical reads)
# ---------------------------------------------------------------------------

def _rewrite_reads(e, axis_vars: frozenset):
    if isinstance(e, (Get, P.Gather)):
        idxs = tuple(_rewrite_reads(i, axis_vars) for i in e.idxs)
        names = [i.name for i in idxs if isinstance(i, Var)]
        ok = (len(names) == len(idxs) and len(set(names)) == len(names)
              and all(n in axis_vars for n in names))
        return P.Gather(e.array, idxs, broadcast_ok=ok)
    if isinstance(e, BinOp):
        return BinOp(e.op, _rewrite_reads(e.lhs, axis_vars),
                     _rewrite_reads(e.rhs, axis_vars))
    if isinstance(e, UnOp):
        return UnOp(e.op, _rewrite_reads(e.e, axis_vars))
    if isinstance(e, Call):
        return Call(e.fn, tuple(_rewrite_reads(a, axis_vars) for a in e.args))
    return e


def pass_identity_traversal(nodes: list, prog, config) -> list:
    def fix(n):
        av = frozenset(n.space.axis_vars)
        n.space = P.IterSpace(
            n.space.axes, tuple(_rewrite_reads(c, av) for c in n.space.conds))
        if hasattr(n, "value"):
            n.value = _rewrite_reads(n.value, av)
        if hasattr(n, "keys"):
            n.keys = tuple(_rewrite_reads(k, av) for k in n.keys)
        if isinstance(n, P.SeqLoop):
            n.cond = _rewrite_reads(n.cond, frozenset())
        return n
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 3: axis-key classification (Rules 16/17)
# ---------------------------------------------------------------------------

def _bool_any(node: P.ScalarReduce):
    """max/min over float(bool) lowers to any/all — record the peephole."""
    v = node.value
    if node.op in ("max", "min") and isinstance(v, Call) and \
            v.fn == "float" and not node.space.conds:
        return v.args[0]
    return None


def pass_classify_keys(nodes: list, prog, config) -> list:
    def fix(n):
        if isinstance(n, P.SegmentReduce):
            if n.keys and all(isinstance(k, Const) for k in n.keys):
                sr = P.ScalarReduce(n.stmt, n.space, n.reads, n.dest, n.op,
                                    n.value,
                                    point=tuple(int(k.value) for k in n.keys))
                sr.bool_any = _bool_any(sr)
                return sr
            ka = _axis_keys(n.keys, n.space)
            if ka is not None:
                return P.AxisReduce(n.stmt, n.space, n.reads, n.dest,
                                    tuple(ka), n.op, n.value)
            return n      # backend chosen by pass 10 (operator-selection)
        if isinstance(n, P.Scatter):
            ka = _axis_keys(n.keys, n.space)
            if ka is not None and set(ka) == set(n.space.axis_vars):
                return P.MapExpr(n.stmt, n.space, n.reads, n.dest, n.value,
                                 key_axes=tuple(ka))
            return n
        if isinstance(n, P.ScalarReduce):
            n.bool_any = _bool_any(n)
        return n
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 4: dense fast-path operator specialization
# ---------------------------------------------------------------------------

def _static_zero_lo(e) -> bool:
    return isinstance(e, Const) and e.value == 0


def _identity_gather(g, key_axes) -> bool:
    return (len(g.idxs) == len(key_axes)
            and all(isinstance(ix, Var) and ix.name == a
                    for ix, a in zip(g.idxs, key_axes)))


def _dense_value_ok(e, key_axes, axis_vars: set) -> bool:
    """Evaluating `e` over the identity space needs no index grids: every
    array read is an identity gather (indexed by exactly the key axes, in
    order) and no bare axis var appears outside gather indices."""
    if isinstance(e, (Get, P.Gather)):
        return _identity_gather(e, key_axes)
    if isinstance(e, Var):
        return e.name not in axis_vars
    if isinstance(e, Const):
        return True
    if isinstance(e, BinOp):
        return (_dense_value_ok(e.lhs, key_axes, axis_vars)
                and _dense_value_ok(e.rhs, key_axes, axis_vars))
    if isinstance(e, UnOp):
        return _dense_value_ok(e.e, key_axes, axis_vars)
    if isinstance(e, Call):
        return all(_dense_value_ok(a, key_axes, axis_vars) for a in e.args)
    return False


def pass_dense_fastpath(nodes: list, prog, config) -> list:
    """Operator *specialization* (not a plan-level operator change):

    * MapExpr whose iteration space provably equals its write space — all
      0-based range axes, key order = axis order, no conditions, identity
      gathers only — becomes `DenseMap`: the executor emits one vectorized
      jnp expression with no index grids, masks or scatters (runtime
      extent mismatch falls back to the general MapExpr path).
    * +-AxisReduce whose value is a product of axis-indexed gathers gets a
      `product` MXU certificate: the executor contracts via jnp.einsum
      instead of materializing the dense iteration grid.  The node itself
      is unchanged — this is how the paper-faithful configuration
      (optimize_contractions=False) keeps native-BLAS inner loops without
      changing its operator choices.
    * ScalarReduce whose value/conditions contain no array reads is marked
      `dense` (pure columnar fold — certifies that no gather or index grid
      is materialized for it).
    """
    if not config.dense_fastpath:
        return nodes

    def fix(n):
        if isinstance(n, P.AxisReduce) and n.op == "+" \
                and not n.space.conds and n.contracted:
            n.product = _product_factors(n.value, n.space, n.key_axes,
                                         n.contracted)
            return n
        if isinstance(n, P.ScalarReduce):
            n.dense = not (_has_gather(n.value)
                           or any(_has_gather(c) for c in n.space.conds))
            return n
        if type(n) is not P.MapExpr or n.key_axes is None:
            return n
        sp = n.space
        if sp.conds or not sp.axes:
            return n
        if any(a.kind != "range" or not _static_zero_lo(a.lo)
               for a in sp.axes):
            return n
        if n.key_axes != sp.axis_vars:
            return n
        if not _dense_value_ok(n.value, n.key_axes, set(sp.axis_vars)):
            return n
        return P.DenseMap(n.stmt, sp, n.reads, n.dest, n.value,
                          key_axes=n.key_axes)
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 5: einsum recognition (beyond-paper contraction)
# ---------------------------------------------------------------------------

def _product_factors(value, space: P.IterSpace, key_axes, contracted,
                     require_all_keys: bool = True):
    """Static half of the contraction recognizer: value must be a product of
    axis-indexed gathers times axis-free scalars covering all axes."""
    axis_vars = set(space.axis_vars)
    bagvals = set(space.bagval_vars)
    factors: list = []
    others: list = []

    def flatten(e):
        if isinstance(e, BinOp) and e.op == "*":
            flatten(e.lhs)
            flatten(e.rhs)
        elif isinstance(e, P.Gather):
            factors.append(e)
        else:
            others.append(e)
    flatten(value)
    if not factors:
        return None
    factor_axes = []
    for f in factors:
        axs = []
        for ix in f.idxs:
            if not (isinstance(ix, Var) and ix.name in axis_vars):
                return None
            axs.append(ix.name)
        factor_axes.append(tuple(axs))
    for o in others:
        if isinstance(o, Const):
            continue
        if isinstance(o, Var) and o.name not in axis_vars \
                and o.name not in bagvals:
            continue
        return None
    used = {a for axs in factor_axes for a in axs}
    need = set(contracted) | (set(key_axes) if require_all_keys else set())
    if not need <= used:
        return None
    return P.EinsumFactors(tuple(factors), tuple(factor_axes), tuple(others))


def _term_split(node: P.AxisReduce, contracted):
    """value = s1*s2*(±Σ terms): axis-free scalar factors stripped, each
    product term einsum-recognized (terms free of the contracted axes reduce
    to extent-product × term at runtime)."""
    scalars: list = []
    value = node.value
    while isinstance(value, BinOp) and value.op == "*":
        if not _axes_used(value.lhs, node.space):
            scalars.append(value.lhs)
            value = value.rhs
        elif not _axes_used(value.rhs, node.space):
            scalars.append(value.rhs)
            value = value.lhs
        else:
            break
    terms: list = []

    def split(e, sign):
        if isinstance(e, BinOp) and e.op in ("+", "-"):
            split(e.lhs, sign)
            split(e.rhs, sign if e.op == "+" else -sign)
        elif isinstance(e, UnOp) and e.op == "neg":
            split(e.e, -sign)
        else:
            terms.append((sign, e))
    split(value, 1)
    if len(terms) < 2:
        return None
    entries = []
    for sign, term in terms:
        if not (_axes_used(term, node.space) & set(contracted)):
            # contraction-free term (Σ_j c = |j|·c): recognize its product
            # structure too when possible, so the per-shard executor can
            # slice operands instead of materializing a gather grid
            ef = _product_factors(term, node.space, node.key_axes, (),
                                  require_all_keys=False)
            entries.append((sign, term, ef, True))
        else:
            ef = _product_factors(term, node.space, node.key_axes, contracted)
            if ef is None:
                return None
            entries.append((sign, term, ef, False))
    return tuple(scalars), tuple(entries)


def pass_einsum(nodes: list, prog, config) -> list:
    if not config.optimize_contractions:
        return nodes

    def fix(n):
        if not isinstance(n, P.AxisReduce) or n.op != "+" or n.space.conds:
            return n
        contracted = n.contracted
        if not contracted:
            return n
        # dense-fastpath already recognized the product; promote it to a
        # plan-level EinsumContract (recognition happens once).  The
        # fallback grid drops its MXU certificate: the contract's own
        # einsum path subsumes it, and a failed guard must not re-fail.
        ef = n.product if n.product is not None else \
            _product_factors(n.value, n.space, n.key_axes, contracted)
        n.product = None
        if ef is not None:
            return P.EinsumContract(n.stmt, n.space, n.reads, n.dest,
                                    n.key_axes, product=ef, fallback=n)
        ts = _term_split(n, contracted)
        if ts is not None:
            scalars, entries = ts
            return P.EinsumContract(n.stmt, n.space, n.reads, n.dest,
                                    n.key_axes, scalars=scalars,
                                    terms=entries, fallback=n)
        return n
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 6: §5 tiled-matmul fusion
# ---------------------------------------------------------------------------

def pass_tiled_fusion(nodes: list, prog, config) -> list:
    if not config.optimize_contractions:
        return nodes

    def fix(n):
        if not (isinstance(n, P.EinsumContract) and n.product is not None):
            return n
        fa = n.product.factor_axes
        if len(fa) == 2 and len(fa[0]) == 2 and len(fa[1]) == 2 \
                and fa[0][1] == fa[1][0] \
                and tuple(n.key_axes) == (fa[0][0], fa[1][1]):
            return P.TiledMatmul(n.stmt, n.space, n.reads, n.dest, contract=n)
        return n
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 7: dead-store elimination
# ---------------------------------------------------------------------------

def _reads_name(node, name: str) -> bool:
    if name in node.reads:
        return True
    # reduce-type nodes implicitly read-modify-write their destinations
    if P.is_reduce(node) and name in P.dests_of(node):
        return True
    return isinstance(node, P.SeqLoop) and name in P.dests_of(node)


def _pure_store(n) -> bool:
    return isinstance(n, (P.MapExpr, P.Scatter))


def _has_gather(e) -> bool:
    if isinstance(e, (Get, P.Gather)):
        return True
    if isinstance(e, BinOp):
        return _has_gather(e.lhs) or _has_gather(e.rhs)
    if isinstance(e, UnOp):
        return _has_gather(e.e)
    if isinstance(e, Call):
        return any(_has_gather(a) for a in e.args)
    return False


def _same_coverage(killer, victim) -> bool:
    """killer unconditionally writes at least every key victim writes.
    The killer's VALUE must be gather-free: a gather whose index lands out
    of range drops that row at runtime (empty-bag semantics), so a store
    with gathers in its value may write fewer cells than the victim did."""
    # compare at the MapExpr/Scatter family level: DenseMap is a MapExpr
    # specialization with identical write coverage
    both_map = isinstance(killer, P.MapExpr) and isinstance(victim, P.MapExpr)
    both_scatter = isinstance(killer, P.Scatter) and \
        isinstance(victim, P.Scatter)
    if not (both_map or both_scatter) or killer.dest != victim.dest:
        return False
    if killer.space.axes != victim.space.axes or killer.space.conds:
        return False
    if _has_gather(killer.value):
        return False
    if both_map:
        return killer.key_axes == victim.key_axes
    return killer.keys == victim.keys


def pass_dead_stores(nodes: list, prog, config) -> list:
    def block(b):
        keep = []
        for i, n in enumerate(b):
            dead = False
            if _pure_store(n):
                for k in b[i + 1:]:
                    if _reads_name(k, n.dest):
                        break
                    if isinstance(k, P.SeqLoop):
                        break               # conservative: opaque region
                    if _pure_store(k) and _same_coverage(k, n):
                        dead = True
                        break
            if not dead:
                keep.append(n)
        return keep
    return _transform_blocks(nodes, block)


# ---------------------------------------------------------------------------
# pass 8: cross-statement update fusion
# ---------------------------------------------------------------------------

_FUSABLE = (P.SegmentReduce, P.AxisReduce, P.ScalarReduce)


def pass_fuse_updates(nodes: list, prog, config) -> list:
    def block(b):
        out: list = []
        i = 0
        while i < len(b):
            n = b[i]
            if not isinstance(n, _FUSABLE):
                out.append(n)
                i += 1
                continue
            group = [n]
            dests = {n.dest}
            reads = set(n.reads)
            j = i + 1
            while j < len(b):
                m = b[j]
                if not isinstance(m, _FUSABLE) or m.space != n.space:
                    break
                if m.dest in dests or m.dest in reads or (set(m.reads) & dests):
                    break
                group.append(m)
                dests.add(m.dest)
                reads |= m.reads
                j += 1
            if len(group) >= 2:
                out.append(P.Fused(None, n.space,
                                   frozenset(reads - dests), parts=group))
                i = j
            else:
                out.append(n)
                i += 1
        return out
    return _transform_blocks(nodes, block)


# ---------------------------------------------------------------------------
# pass 9: distribution analysis (annotation-only; see dist_analysis.py)
# ---------------------------------------------------------------------------

def pass_distribution(nodes: list, prog, config) -> list:
    from .dist_analysis import analyze
    rb: dict = {}
    analyze(nodes, prog, config, rebalance_out=rb)
    inserted = sorted(a for a, d in rb.items() if d == "inserted")
    if inserted and getattr(config, "skew_rebalance", True):
        # materialize the analysis' "insert an explicit rebalance"
        # decisions as plan nodes (one per pinned array, placed right
        # after its last producer), then re-annotate so the new nodes
        # carry shardings like every other leaf
        _insert_rebalances(nodes, set(inserted))
        analyze(nodes, prog, config)
    return nodes


def _insert_rebalances(nodes: list, arrays: set) -> None:
    """Insert a `P.Rebalance` after the LAST node writing each pinned array
    (in the block — top level or SeqLoop body — where that write lives), so
    every later reader sees balanced ONED_ROW blocks."""

    def last_writer(block):
        found = {}
        for i, n in enumerate(block):
            if isinstance(n, P.SeqLoop):
                last_writer(n.body)
                continue
            for d in P.dests_of(n):
                if d in arrays:
                    found[d] = (i, n)
        # insert in reverse index order so earlier positions stay valid
        for name, (i, n) in sorted(found.items(),
                                   key=lambda kv: -kv[1][0]):
            space = getattr(n, "space", P.IterSpace(()))
            block.insert(i + 1, P.Rebalance(None, space,
                                            frozenset({name}), name))
            arrays.discard(name)

    last_writer(nodes)


# ---------------------------------------------------------------------------
# pass 10: operator selection (annotation-only; see op_select.py)
# ---------------------------------------------------------------------------

def pass_select_backend(nodes: list, prog, config) -> list:
    """Attach the backend CANDIDATE SET to every node that has more than
    one correct materialization (SegmentReduce today; EinsumContract /
    TiledMatmul carry their guard chains as declared candidates).  The
    concrete choice is deferred to trace time (`backend="auto"`), when the
    selector (op_select.OpSelector — cost model or autotune cache) sees
    the concrete (N, K, D, dtype, dest-sharding) shape class.  Runs after
    distribution analysis because the shape class includes the
    destination's inferred sharding.  The legacy `use_kernels=True` flag
    (the pre-subsystem static choice) maps to pinning `pallas`; an
    `op_select="force:<backend>"` config pins that backend on every node
    whose candidate set contains it (tests / A-B benchmarks)."""
    forced = None
    if config.use_kernels:
        forced = "pallas"
    elif config.op_select.startswith("force:"):
        forced = config.op_select.split(":", 1)[1]
    # hot-key salting policy → static pin.  "auto" leaves salt=None: the
    # run-time probe (lower.collect_salts) decides per call from the
    # concrete key data.  "off" pins S=1 (disables probe and salting);
    # "force:<S>" pins S on every eligible node (the executor still
    # ignores the pin where salting is undefined: multi-key / non-1-D).
    salting = getattr(config, "skew_salting", "auto")
    salt_pin = None
    if salting == "off":
        salt_pin = 1
    elif salting.startswith("force:"):
        salt_pin = int(salting.split(":", 1)[1])

    def fix(n):
        if isinstance(n, P.Fused):
            # this pass runs AFTER update-fusion (it needs pass 9's
            # shardings), so it must reach the reduces inside Fused rounds
            n.parts = [fix(p) for p in n.parts]
            return n
        if isinstance(n, P.SegmentReduce):
            from .op_select import SEGMENT_CANDIDATES
            n.candidates = SEGMENT_CANDIDATES.get(n.op, ("scatter",))
            if forced is not None and forced in n.candidates:
                n.backend = forced
            else:
                n.backend = "auto"
            if salt_pin is not None:
                n.salt = salt_pin
            return n
        if isinstance(n, P.TiledMatmul):
            fix(n.contract)      # the dense-lhs resolution shares the pin
        if isinstance(n, (P.EinsumContract, P.TiledMatmul)) \
                and forced is not None and forced in n.candidates:
            n.candidates = (forced,)
        return n
    return _map_nodes(nodes, fix)


# ---------------------------------------------------------------------------
# pass 11: round fusion (distributed dispatch; see plan.FusedRound)
# ---------------------------------------------------------------------------

def _scalar_member(n) -> bool:
    """Nodes the distributed executor can run replicated inside a fused
    shard_map region: scalar assignments and scalar ⊕-aggregations (their
    reads are scalars / replicated values; bag-driven ScalarReduce instead
    classifies as an unaligned reduce with a psum exchange)."""
    if isinstance(n, P.ScalarReduce):
        return True
    return type(n) in (P.MapExpr, P.DenseMap) and n.key_axes is None


def _fusable_member(n) -> bool:
    """Static half of the fused-round compatibility check: can this node in
    principle run as one sub-round of a single shard_map program?  The
    runtime half (row counts, placements, TiledMatrix representations) is
    re-checked at round-build time in distributed.py; a failure there falls
    back to per-member rounds, never to an error."""
    from .dist_analysis import leading_key_var, round_axis
    if isinstance(n, P.SeqLoop):
        return False                     # loops fuse their own bodies
    if isinstance(n, P.Rebalance):
        return True                      # one collective sub-round
    if _scalar_member(n):
        return True
    if isinstance(n, P.Fused):
        return all(_fusable_member(p) for p in n.parts)
    if isinstance(n, (P.MapExpr, P.Scatter)):
        ax = round_axis(n)
        return ax is not None and leading_key_var(n) == ax
    if isinstance(n, P.SegmentReduce):
        return n.space.has_bag           # range-driven: no psum source
    if isinstance(n, (P.AxisReduce, P.EinsumContract, P.TiledMatmul)):
        return n.space.has_bag or round_axis(n) is not None
    return False


def pass_fuse_rounds(nodes: list, prog, config) -> list:
    """Group adjacent shard-mappable nodes into `FusedRound` regions so the
    distributed executor dispatches ONE shard_map program per region, with
    the collectives inside it.  A SeqLoop whose entire body is fusable gets
    its body wrapped in a single region — the precondition for running the
    loop as an on-device lax.while_loop (no per-iteration host sync).
    Annotation-level sequencing only: every member keeps its own operator,
    classification and candidate set, and the single-device executor runs
    the members exactly as if they were never grouped."""
    if not config.round_fusion:
        return nodes

    def region(group):
        reads: set = set()
        dests: set = set()
        for g in group:
            reads |= set(g.reads)
            dests.update(P.dests_of(g))
        return P.FusedRound(None, P.IterSpace(()),
                            frozenset(reads - dests), parts=group)

    def block(b):
        out: list = []
        group: list = []

        def flush():
            if len(group) >= 2:
                out.append(region(list(group)))
            else:
                out.extend(group)
            group.clear()

        for n in b:
            if _fusable_member(n):
                group.append(n)
            else:
                flush()
                out.append(n)
        flush()
        return out

    def fix_loops(ns):
        for n in ns:
            if isinstance(n, P.SeqLoop):
                fix_loops(n.body)
                if n.body and all(_fusable_member(x) for x in n.body):
                    # whole body in ONE region (even a single member): the
                    # on-device loop needs one shard_map program per body
                    n.body = [region(list(n.body))]
                else:
                    n.body = block(n.body)
        return ns

    return block(fix_loops(nodes))


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

def _pass_lineage(nodes, prog, config):
    """Pass 12 (round-lineage): annotate every round with its RoundLineage
    recovery recipe (core/lineage.py, DESIGN.md §13).  Runs last — a
    recipe names the FINAL round classification and placements.  Imported
    lazily to keep passes.py's module graph acyclic."""
    from .lineage import pass_lineage
    return pass_lineage(nodes, prog, config)


PIPELINE = (
    ("identity-traversal", pass_identity_traversal),
    ("axis-key-classification", pass_classify_keys),
    ("dense-fastpath", pass_dense_fastpath),
    ("einsum-recognition", pass_einsum),
    ("tiled-fusion", pass_tiled_fusion),
    ("dead-store-elimination", pass_dead_stores),
    ("update-fusion", pass_fuse_updates),
    ("distribution-analysis", pass_distribution),
    ("operator-selection", pass_select_backend),
    ("round-fusion", pass_fuse_rounds),
    ("round-lineage", _pass_lineage),
)


def plan_program(target: list, prog: Program,
                 config: PlanConfig = PlanConfig()) -> list:
    """Run the full pipeline: target comprehensions → physical plan."""
    nodes = build_spaces(target, prog)
    for _name, fn in PIPELINE:
        nodes = fn(nodes, prog, config)
    return nodes

"""Monoid comprehension IR (paper §3.3) and target code (§3.8).

A target statement is either sequential glue (scalar assign, while, block)
or one of the three bulk comprehension forms produced by the Fig. 2 rules:

  BulkUpdate:  d := d ◁ {(k, w ⊕ (⊕/v)) | q̄, group by k}      (rule 15a)
  BulkStore:   d := d ◁ {(k, v) | q̄}                           (rule 15b)
  ScalarAgg:   v := v ⊕ (⊕/{e | q̄})                            (rule 16 applied)

Qualifier sources are already §3.6-optimized: dense-array accesses inside
expressions appear as `Get` (gather + implicit inRange guard), i.e. the
paper's `(i,v) ← V, i = e` join with a range generator is fused into an
indexed read — the limit case of loop-iteration elimination for dense
arrays (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

from .loop_ast import Expr


# ---------------------------------------------------------------------------
# comprehension-level expressions: loop_ast.Expr plus Get (guarded gather)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Get(Expr):
    """{ v | (i̅, v) ← array, i̅ = idxs } for a dense array: a gather with an
    implicit inRange condition."""
    array: str
    idxs: tuple[Expr, ...]


# ---------------------------------------------------------------------------
# qualifiers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RangeGen:
    var: str
    lo: Expr
    hi: Expr            # exclusive


@dataclass(frozen=True)
class BagGen:
    """(idx, *vals) ← bag (struct-of-arrays source)."""
    idx: str
    vals: tuple[str, ...]
    bag: str


@dataclass(frozen=True)
class Cond:
    e: Expr


Qual = Any  # RangeGen | BagGen | Cond


# ---------------------------------------------------------------------------
# target statements
# ---------------------------------------------------------------------------

@dataclass
class BulkUpdate:
    """dest := dest ◁⊕ {(keys, ⊕/value) | quals, group by keys}."""
    dest: str
    keys: tuple[Expr, ...]
    op: str
    value: Expr
    quals: list = field(default_factory=list)


@dataclass
class BulkStore:
    """dest := dest ◁ {(keys, value) | quals} (affine keys: no duplicates)."""
    dest: str
    keys: tuple[Expr, ...]
    value: Expr
    quals: list = field(default_factory=list)


@dataclass
class ScalarAgg:
    """var := var ⊕ (⊕/{value | quals}) — rule 16 total aggregation."""
    dest: str
    op: str
    value: Expr
    quals: list = field(default_factory=list)


@dataclass
class ScalarAssign:
    dest: str
    value: Expr          # scalar expression over env (may contain Get)
    quals: list = field(default_factory=list)  # conds only (top-level if)


@dataclass
class SeqWhile:
    cond: Expr
    body: list = field(default_factory=list)


TargetStmt = Any


# ---------------------------------------------------------------------------
# pretty printer (paper-style comprehensions, for docs/tests)
# ---------------------------------------------------------------------------

def _pe(e: Expr) -> str:
    from .loop_ast import BinOp, Call, Const, Index, UnOp, Var
    if isinstance(e, Var):
        return e.name
    if isinstance(e, Const):
        return repr(e.value)
    if isinstance(e, Get):
        return f"{e.array}[{', '.join(_pe(i) for i in e.idxs)}]"
    if isinstance(e, Index):
        return f"{e.array}[{', '.join(_pe(i) for i in e.idxs)}]"
    if isinstance(e, BinOp):
        return f"({_pe(e.lhs)} {e.op} {_pe(e.rhs)})"
    if isinstance(e, UnOp):
        return f"({e.op} {_pe(e.e)})"
    if isinstance(e, Call):
        return f"{e.fn}({', '.join(_pe(a) for a in e.args)})"
    return str(e)


def _pq(q) -> str:
    if isinstance(q, RangeGen):
        return f"{q.var} ← range({_pe(q.lo)}, {_pe(q.hi)})"
    if isinstance(q, BagGen):
        pats = ", ".join((q.idx,) + q.vals)
        return f"({pats}) ← {q.bag}"
    return _pe(q.e)


def pretty(stmt: TargetStmt) -> str:
    if isinstance(stmt, BulkUpdate):
        k = ", ".join(_pe(e) for e in stmt.keys)
        qs = ", ".join(_pq(q) for q in stmt.quals)
        return (f"{stmt.dest} := {stmt.dest} ◁ {{ (({k}), {stmt.op}/v) | {qs}, "
                f"let v = {_pe(stmt.value)}, group by ({k}) }}")
    if isinstance(stmt, BulkStore):
        k = ", ".join(_pe(e) for e in stmt.keys)
        qs = ", ".join(_pq(q) for q in stmt.quals)
        return f"{stmt.dest} := {stmt.dest} ◁ {{ (({k}), {_pe(stmt.value)}) | {qs} }}"
    if isinstance(stmt, ScalarAgg):
        qs = ", ".join(_pq(q) for q in stmt.quals)
        return (f"{stmt.dest} := {stmt.dest} {stmt.op} "
                f"({stmt.op}/{{ {_pe(stmt.value)} | {qs} }})")
    if isinstance(stmt, ScalarAssign):
        return f"{stmt.dest} := {_pe(stmt.value)}"
    if isinstance(stmt, SeqWhile):
        inner = "; ".join(pretty(b) for b in stmt.body)
        return f"while ({_pe(stmt.cond)}) {{ {inner} }}"
    return str(stmt)

"""Distribution analysis: infer a sharding for every dense array.

The paper's scalability argument (§6, Fig. 4–5) assumes *all* large
operands are partitioned.  Sharding only bags (the pre-pass behaviour)
replicates every dense array — PageRank ranks, k-means centroids and
matrix-factorization factors — so range-driven programs could not grow
past one device's memory.  This pass closes that gap the HPAT way
(Totoni et al., `distributed_analysis.py`): a fixed-point inference over
the physical plan assigning each array a distribution from the lattice

    REP  ≤  ONED_VAR  ≤  ONED_ROW  ≤  TWOD_BLOCK

    REP         replicated on every device (always-correct fallback, ⊥)
    ONED_VAR    row-partitioned along dim 0 with VARIABLE per-shard live
                lengths (HPAT's OneD_Var): bag-derived and filtered
                arrays, whose live extent is data-dependent — each shard
                holds an equal physical block but a different logical row
                count (the pad+mask limit)
    ONED_ROW    block-partitioned along dim 0 over the dp mesh axes,
                equal (balanced) live blocks
    TWOD_BLOCK  2-D block-partition candidate (matmul operands); the
                current executors place it as ONED_ROW — the lattice
                point records that a 2-D placement would be legal

Inference is optimistic-then-meet: every dense array starts at the top
(`TWOD_BLOCK`) and constraints only move it *down* (`meet` = min), so the
fixed point exists and is reached monotonically.  Two HPAT-style sweeps:

  sweep 1 (writes)  each plan node caps its destination at the best
                    distribution the distributed executor can *produce*
                    for that node shape (see `_dest_cap`); arrays read in
                    a SeqLoop condition meet to REP (the condition is
                    evaluated replicated every iteration).
  sweep 2 (reads)   "rebalance": any appearance outside a matmul-shaped
                    contraction caps an array at ONED_ROW, so TWOD_BLOCK
                    survives only for pure matmul operands.

The sweeps repeat until no distribution changes (the lattice has height
3, so at most a few iterations).  Loop-carried arrays need no extra
constraint: a distribution is a property of the *array*, not of a program
point, so a SeqLoop body sees one stable sharding across iterations by
construction — the meet over all its writers.

After the base fixed point, a `_rebalance` pass (HPAT's `_rebalance_arrs`
re-run idiom) revisits every array left at ONED_VAR and decides whether
variable blocks are acceptable where it is consumed.  Readers that only
walk the producing axis element-wise tolerate skewed blocks, so the array
KEEPS ONED_VAR and the rebalance is *elided*; a reader that slices by
global offsets (a contraction certificate) or re-reads the array across
SeqLoop iterations needs balanced blocks, so the array is pinned up to
ONED_ROW — recording that an explicit rebalance round must be *inserted*
after its producer — and the whole analysis re-runs with the pin until no
new pin appears.  `analyze(..., rebalance_out=...)` reports the final
{array: "inserted" | "elided"} decisions; pass_distribution materializes
the inserted ones as `plan.Rebalance` nodes.

Guarantee: a changed distribution never changes a result, only its
placement.  Every node keeps a replicated execution path (distributed.py
falls back to it whenever a runtime shape guard fails), and REP-everything
remains the global fallback (`PlanConfig.infer_distributions=False` or
`DistributedProgram(shard_dense=False)`).

Annotations: each leaf plan node gets a `shardings` dict — destination
first, then read operands — mapping array name to a `Sharding` whose str()
is e.g. ``ONED_ROW(i)`` (sharded on dim 0, aligned with axis var `i` in
this node), ``ONED_ROW`` (sharded, unaligned access here), ``TWOD_BLOCK``
or ``REP``.  `CompiledProgram.explain()` prints them per operand.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum
from typing import Optional

from . import plan as P
from .comprehension import Get
from .loop_ast import BinOp, Call, Const, Program, UnOp, Var


class Dist(IntEnum):
    """The distribution lattice; smaller = more replicated (meet = min)."""
    REP = 0
    ONED_VAR = 1      # row-partitioned, variable per-shard live lengths
    ONED_ROW = 2
    TWOD_BLOCK = 3


def meet(a: Dist, b: Dist) -> Dist:
    return Dist(min(a, b))


@dataclass(frozen=True)
class Sharding:
    """One operand's inferred placement within one plan node."""
    dist: Dist
    axis: Optional[str] = None    # aligned iteration-axis var, when known

    def __str__(self) -> str:
        if self.dist >= Dist.ONED_VAR and self.axis:
            return f"{self.dist.name}({self.axis})"
        return self.dist.name


# ---------------------------------------------------------------------------
# plan walking helpers
# ---------------------------------------------------------------------------

def dense_arrays(prog: Program) -> frozenset:
    return frozenset(n for n, t in prog.params.items()
                     if t.kind in ("vector", "matrix", "map"))


def leaf_nodes(nodes):
    """Yield every leaf plan node (Fused parts, FusedRound regions and
    SeqLoop bodies opened)."""
    for n in nodes:
        if isinstance(n, (P.SeqLoop, P.FusedRound)):
            yield from leaf_nodes(n.body if isinstance(n, P.SeqLoop)
                                  else n.parts)
        elif isinstance(n, P.Fused):
            yield from n.parts
        else:
            yield n


def _walk_gathers(e, acc: dict):
    if isinstance(e, (P.Gather, Get)):
        acc.setdefault(e.array, []).append(tuple(e.idxs))
        for i in e.idxs:
            _walk_gathers(i, acc)
    elif isinstance(e, BinOp):
        _walk_gathers(e.lhs, acc)
        _walk_gathers(e.rhs, acc)
    elif isinstance(e, UnOp):
        _walk_gathers(e.e, acc)
    elif isinstance(e, Call):
        for a in e.args:
            _walk_gathers(a, acc)


def gathers_of(node) -> dict:
    """Array name → list of index tuples for every read in the node (for
    Fused, the union over all parts: alignment must hold everywhere)."""
    acc: dict = {}
    if isinstance(node, P.TiledMatmul):
        return gathers_of(node.contract)
    if isinstance(node, P.Fused):
        for p in node.parts:
            for name, idx_lists in gathers_of(p).items():
                acc.setdefault(name, []).extend(idx_lists)
        for e in node.space.conds:
            _walk_gathers(e, acc)
        return acc
    exprs = list(node.space.conds)
    if hasattr(node, "value"):
        exprs.append(node.value)
    exprs.extend(getattr(node, "keys", ()))
    if isinstance(node, P.EinsumContract) and node.fallback is not None:
        exprs.append(node.fallback.value)   # original value pre-recognition
    for e in exprs:
        _walk_gathers(e, acc)
    return acc


def aligned_reads(node, axis_var: str) -> frozenset:
    """Arrays whose EVERY read in `node` is leading-indexed by `axis_var`
    (dim 0 of the array walks in lockstep with the sharded axis, so a
    per-shard row block serves all of the node's reads of it)."""
    out = set()
    for name, idx_lists in gathers_of(node).items():
        if all(idxs and isinstance(idxs[0], Var) and idxs[0].name == axis_var
               for idxs in idx_lists):
            out.add(name)
    return frozenset(out)


def leading_key_var(node) -> Optional[str]:
    """The axis var indexing dim 0 of the destination, when it is one."""
    if isinstance(node, P.TiledMatmul):
        node = node.contract
    keys = getattr(node, "key_axes", None)
    if keys:
        return keys[0]
    keys = getattr(node, "keys", None)
    if keys and isinstance(keys[0], Var) and \
            keys[0].name in node.space.axis_vars:
        return keys[0].name
    return None


def _static_zero(e) -> bool:
    return isinstance(e, Const) and e.value == 0


# ---------------------------------------------------------------------------
# bounds certificates for per-shard slices (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _contract_groups(node):
    """The node's recognized contraction factor groups ((factors,
    factor_axes) pairs), or None when it carries no product certificate."""
    if isinstance(node, P.TiledMatmul):
        node = node.contract
    if isinstance(node, P.EinsumContract):
        if node.product is not None:
            return [(node.product.factors, node.product.factor_axes)]
        if node.terms:
            out = [(ef.factors, ef.factor_axes)
                   for _s, _t, ef, _f in node.terms if ef is not None]
            return out or None
        return None
    if isinstance(node, P.AxisReduce) and node.product is not None:
        return [(node.product.factors, node.product.factor_axes)]
    return None


def shard_slice_certificates(node, axis: str, local: frozenset):
    """Structural bounds certificates for running `node`'s contraction as a
    per-shard jnp.einsum inside a shard_map round over `axis`.  For every
    factor, each occurrence of the round axis must be provably sliceable
    without relying on lax.dynamic_slice's silent clamping:

      "local"   the factor is an axis-aligned local block (every read
                leading-indexed by the round axis, rows tiling like the
                axis): its dim-0 block IS the shard's window, slice at 0.
      "window"  the factor stays global on the shard: a dynamic_slice
                window [offset, offset+extent) whose bound offset+extent ≤
                padded-global-extent is checked against the physical dim
                at trace time (zero-padding the + identity when shorter).
      "static"  the round axis does not index this factor; plain static
                slicing applies.

    Returns {array: certificate}; None when some factor admits no
    certificate (or an unrecognized term still needs a gather grid) — the
    executor will then fall back to the masked dense-grid path.  The
    numeric halves of these certificates (row counts, padded extents) are
    re-checked by lower._sliced_operand at trace time; this function is
    the static contract distributed.py consults and explain_rounds()
    prints."""
    groups = _contract_groups(node)
    if groups is None:
        return None
    inner = node.contract if isinstance(node, P.TiledMatmul) else node
    if isinstance(inner, P.EinsumContract) and inner.terms:
        for _s, term, ef, _f in inner.terms:
            acc: dict = {}
            _walk_gathers(term, acc)
            if ef is None and acc:
                return None     # unrecognized term needs the gather grid
    bagvars = {a.var for a in node.space.axes if a.kind == "bag"}
    cert: dict = {}
    for factors, factor_axes in groups:
        for f, faxes in zip(factors, factor_axes):
            kind = "static"
            for dim_i, axn in enumerate(faxes):
                if axn != axis and axn not in bagvars:
                    continue
                if dim_i == 0 and f.array in local:
                    kind = "local"
                elif axn == axis and f.array not in local:
                    kind = "window"
                else:
                    return None
            prev = cert.get(f.array)
            if prev is not None and prev != kind and "static" not in \
                    (prev, kind):
                return None     # conflicting requirements across reads
            cert[f.array] = kind if prev in (None, "static") else prev
    return cert


def round_axis(node) -> Optional[str]:
    """The axis a shard_map round for THIS node would split: the single bag
    axis when the space is bag-driven, else the leading destination key
    axis provided it is a range axis starting at 0 (so contiguous row
    blocks of the destination line up with contiguous index blocks of the
    axis).  None when no such axis exists (replicated execution)."""
    bags = [a for a in node.space.axes if a.kind == "bag"]
    if len(bags) == 1:
        return bags[0].var
    if bags:
        return None                      # bag join: no single shard axis
    lead = leading_key_var(node)
    for a in node.space.axes:
        if a.var == lead and a.kind == "range" and _static_zero(a.lo):
            return lead
    return None


_ALIGNED_DEST_NODES = (P.MapExpr, P.Scatter, P.AxisReduce, P.EinsumContract,
                       P.TiledMatmul)


def _benefits_from_sharding(node, name: str) -> bool:
    """Does THIS node's use of `name` ever exploit a ONED_ROW placement?
    True for a destination that can run an aligned (collective-free)
    store/reduce round, and for a read the round can serve from the local
    block.  An unaligned reduce destination (SegmentReduce: computed
    keys) and a gathered read never benefit — sharding them only changes
    the exchange/placement cost."""
    axis = round_axis(node)
    if axis is None:
        return False
    if getattr(node, "dest", None) == name:
        return isinstance(node, _ALIGNED_DEST_NODES) and \
            leading_key_var(node) == axis
    return name in aligned_reads(node, axis)


def demotable_dests(nodes, prog: Program) -> dict:
    """Dense arrays whose EVERY plan use is placement-neutral (unaligned
    reduce destination or cross-shard read): the distributed runtime may
    freely demote them to REP when op_select.choose_reduce_dest says a
    sharded destination doesn't pay for their size (DESIGN.md §8) —
    demotion never forfeits an aligned round and never changes results
    (REP is the lattice ⊥, correct everywhere).  Returns {name: ⊕} — the
    monoid of a reduce writing the array ("+" when it is only read), so
    the placement decision is keyed on the real exchange it replaces."""
    dense = dense_arrays(prog)
    keep: set = set()
    ops: dict = {}
    for n in _all_nodes(nodes):
        if isinstance(n, P.SeqLoop):
            continue
        touched = set(gathers_of(n)) | {getattr(n, "dest", None)}
        if getattr(n, "dest", None) in dense and hasattr(n, "op"):
            ops.setdefault(n.dest, n.op)
        for name in touched & dense:
            if _benefits_from_sharding(n, name):
                keep.add(name)
    return {name: ops.get(name, "+") for name in dense - keep}


def _dest_cap(node) -> Optional[Dist]:
    """Best distribution the distributed executor can PRODUCE for this
    node's destination; None when the destination is a scalar."""
    if isinstance(node, P.Rebalance):
        return Dist.ONED_ROW          # the round's whole point: balance
    if isinstance(node, P.ScalarReduce):
        if node.point is None:
            return None               # scalar destination
        return Dist.ONED_ROW if node.space.has_bag else Dist.REP
    if isinstance(node, P.SegmentReduce):
        # computed keys: partial-⊕ + psum_scatter works only when the bag
        # drives the round; range-driven segment writes run replicated
        return Dist.ONED_ROW if node.space.has_bag else Dist.REP
    if isinstance(node, (P.AxisReduce, P.EinsumContract, P.TiledMatmul)):
        if node.space.has_bag:
            ra = round_axis(node)
            if ra is not None and ra == leading_key_var(node):
                # dest rows walk the bag itself (e.g. a per-point min):
                # live row counts are data-dependent → variable blocks
                return Dist.ONED_VAR
            return Dist.ONED_ROW      # unaligned partial + psum_scatter
        return Dist.ONED_ROW if round_axis(node) is not None else Dist.REP
    if isinstance(node, (P.MapExpr, P.Scatter)):
        if isinstance(node, P.MapExpr) and node.key_axes is None:
            return None               # guarded scalar assignment
        ra = round_axis(node)
        if ra is not None and ra == leading_key_var(node):
            # aligned store round, rows stay local.  A bag-driven or
            # filtered write leaves DATA-DEPENDENT live row counts per
            # shard (HPAT's OneD_Var): the physical blocks stay equal but
            # the logical lengths vary, so the best the executor can
            # claim is ONED_VAR; _rebalance later decides whether a
            # reader needs the blocks rebalanced up to ONED_ROW.
            bagvars = {a.var for a in node.space.axes if a.kind == "bag"}
            if ra in bagvars or node.space.conds:
                return Dist.ONED_VAR
            return Dist.ONED_ROW
        return Dist.REP               # scattered writes cross shards
    return Dist.REP


def _matmul_operands(node) -> frozenset:
    """Gather arrays eligible to stay TWOD_BLOCK: the two rank-2 factors of
    a matmul-shaped contraction (the pass_tiled_fusion shape)."""
    if isinstance(node, P.TiledMatmul):
        node = node.contract
    if not (isinstance(node, P.EinsumContract) and node.product is not None):
        return frozenset()
    fa = node.product.factor_axes
    if len(fa) == 2 and len(fa[0]) == 2 and len(fa[1]) == 2 \
            and fa[0][1] == fa[1][0] \
            and tuple(node.key_axes) == (fa[0][0], fa[1][1]):
        return frozenset(f.array for f in node.product.factors)
    return frozenset()


# ---------------------------------------------------------------------------
# the analysis
# ---------------------------------------------------------------------------

def _rebalance_targets(nodes) -> frozenset:
    """Arrays that, were they left at ONED_VAR, would break or degrade a
    consumer: contraction-certified readers slice factors by GLOBAL
    offsets (shard_slice_certificates assumes equal live blocks), and
    SeqLoop-touched state is re-read every iteration (a skewed block
    compounds across rounds).  Everything else — element-wise readers
    walking the producing axis, computed-key gathers — tolerates variable
    blocks and lets the array keep ONED_VAR."""
    out: set = set()
    for n in leaf_nodes(nodes):
        groups = _contract_groups(n)
        if groups:
            for factors, _axes in groups:
                out.update(f.array for f in factors)
    for n in _all_nodes(nodes):
        if isinstance(n, P.SeqLoop):
            for m in leaf_nodes(n.body):
                out.update(gathers_of(m))
                d = getattr(m, "dest", None)
                if d is not None:
                    out.add(d)
    return frozenset(out)


def analyze(nodes: list, prog: Program, config=None,
            rebalance_out: Optional[dict] = None) -> dict:
    """Infer array distributions by fixed-point meet; annotate every leaf
    node with its `shardings` dict and return {array: Dist}.

    When `rebalance_out` is given it is filled with the `_rebalance`
    decisions: {array: "inserted"} for ONED_VAR arrays pinned up to
    ONED_ROW (an explicit rebalance round must restore balanced blocks
    after their producer) and {array: "elided"} for arrays that keep
    variable blocks."""
    dense = dense_arrays(prog)
    if config is not None and not getattr(config, "infer_distributions", True):
        dists = {a: Dist.REP for a in dense}
        _annotate(nodes, dists)
        return dists

    pins: set = set()       # ONED_VAR arrays lifted to ONED_ROW by rebalance

    def run_base() -> dict:
        dists = {a: Dist.TWOD_BLOCK for a in dense}   # optimistic top

        def cap(name, d):
            if d == Dist.ONED_VAR and name in pins:
                d = Dist.ONED_ROW   # a rebalance round restores balance
            if name in dists and dists[name] > d:
                dists[name] = Dist(d)
                return True
            return False

        changed = True
        while changed:                # monotone descent, lattice height 3
            changed = False
            # sweep 1: write-side constraints (what each node can produce)
            for n in _all_nodes(nodes):
                if isinstance(n, P.SeqLoop):
                    acc: dict = {}
                    _walk_gathers(n.cond, acc)
                    for name in acc:      # cond is evaluated replicated
                        changed |= cap(name, Dist.REP)
                    continue
                dc = _dest_cap(n)
                if dc is not None and n.dest in dists:
                    changed |= cap(n.dest, dc)
            # sweep 2: read-side rebalance (TWOD only for matmul operands)
            for n in leaf_nodes(nodes):
                mm = _matmul_operands(n)
                for name in gathers_of(n):
                    if name not in mm:
                        changed |= cap(name, Dist.ONED_ROW)
                if getattr(n, "dest", None) in dists and n.dest not in mm:
                    changed |= cap(n.dest, Dist.ONED_ROW)
        return dists

    # HPAT's _rebalance_arrs idiom: run to fixed point, promote ONED_VAR
    # arrays whose consumers need balanced blocks, and re-run the whole
    # analysis with the pins until no new pin appears (each iteration can
    # only ADD pins, so this terminates in ≤ |dense| re-runs).
    # skew_rebalance=False disables promotion entirely: every ONED_VAR
    # array keeps variable blocks (the pad+mask fallback), and
    # pass_distribution then inserts no Rebalance nodes.
    needs = _rebalance_targets(nodes) \
        if config is None or getattr(config, "skew_rebalance", True) \
        else frozenset()
    while True:
        dists = run_base()
        promote = {a for a, d in dists.items()
                   if d == Dist.ONED_VAR and a in needs} - pins
        if not promote:
            break
        pins |= promote
    if rebalance_out is not None:
        for a in sorted(pins):
            if dists[a] >= Dist.ONED_ROW:   # still sharded after the pin
                rebalance_out[a] = "inserted"
        for a in sorted(a for a, d in dists.items() if d == Dist.ONED_VAR):
            rebalance_out[a] = "elided"

    _annotate(nodes, dists)
    return dists


def _all_nodes(nodes):
    """Leaf nodes plus the SeqLoop containers themselves."""
    for n in nodes:
        if isinstance(n, P.SeqLoop):
            yield n
            yield from _all_nodes(n.body)
        elif isinstance(n, P.FusedRound):
            yield from _all_nodes(n.parts)
        elif isinstance(n, P.Fused):
            yield from n.parts
        else:
            yield n


def _annotate(nodes, dists: dict):
    for n in leaf_nodes(nodes):
        sh: dict = {}
        axis = round_axis(n)
        dest = getattr(n, "dest", None)
        if dest in dists:
            lead = leading_key_var(n)
            sh[dest] = Sharding(dists[dest],
                                lead if lead == axis and
                                dists[dest] >= Dist.ONED_VAR else None)
        ar = aligned_reads(n, axis) if axis else frozenset()
        for name in sorted(gathers_of(n)):
            if name in dists and name != dest:
                sh[name] = Sharding(dists[name],
                                    axis if name in ar and
                                    dists[name] >= Dist.ONED_VAR else None)
        n.shardings = sh
        if isinstance(n, P.TiledMatmul):
            n.contract.shardings = sh   # explain() shows the dense-lhs form


def collect(nodes) -> dict:
    """Program-level {array: Dist} from node annotations (analyze() wrote a
    single consistent value per array, so any occurrence is the answer)."""
    out: dict = {}
    for n in leaf_nodes(nodes):
        for name, sh in (getattr(n, "shardings", None) or {}).items():
            out[name] = sh.dist
    return out

"""Python-source frontend: `@loop_program` parses the decorated function's
body (via the `ast` module) into the paper's loop language (Figure 1).

Parameter annotations declare types:

    @loop_program
    def matmul(M: matrix["n", "l"], N: matrix["l", "m"],
               R: matrix["n", "m"], n: dim, m: dim, l: dim):
        for i in range(0, n):
            for j in range(0, m):
                R[i, j] = 0.0
                for k in range(0, l):
                    R[i, j] += M[i, k] * N[k, j]

Notes vs. the paper's concrete syntax: `range(lo, hi)` is EXCLUSIVE
(python semantics); `for (s, d) in E` iterates bags of tuples; `for i, v
in items(V)` gives (index, value) pairs; maps are int-keyed with implicit
zero (the paper's benchmarks only ⊕= into maps).
"""
from __future__ import annotations

import ast as pyast
import inspect
import textwrap

from .loop_ast import (Assign, BinOp, Call, Const, DIndex, DVar, Expr,
                       ForIn, ForRange, If, IncUpdate, Index, Program,
                       RejectionError, Stmt, TypeInfo, UnOp, Var, While)


# ------------------------- type annotation helpers -------------------------

class _Ann:
    def __init__(self, kind, dims=(), fields=1, dtype="float"):
        self.info = TypeInfo(kind, tuple(dims), fields, dtype)

    def __getitem__(self, dims):
        if not isinstance(dims, tuple):
            dims = (dims,)
        return _Ann(self.info.kind, [str(d) for d in dims],
                    self.info.fields, self.info.dtype)


class _Bag:
    def __getitem__(self, n):
        return _Ann("bag", (), int(n) if not isinstance(n, tuple) else len(n))


vector = _Ann("vector", ("n",))
matrix = _Ann("matrix", ("n", "m"))
map_ = _Ann("map", ("k",))
bag = _Bag()
dim = _Ann("dim")
scalar = _Ann("scalar")
intscalar = _Ann("scalar", dtype="int")

_ANNOT = {"vector": vector, "matrix": matrix, "map_": map_, "dim": dim,
          "scalar": scalar, "intscalar": intscalar}

_BINOPS = {pyast.Add: "+", pyast.Sub: "-", pyast.Mult: "*", pyast.Div: "/",
           pyast.FloorDiv: "//", pyast.Mod: "%", pyast.Pow: "**"}
_CMPOPS = {pyast.Eq: "==", pyast.NotEq: "!=", pyast.Lt: "<", pyast.LtE: "<=",
           pyast.Gt: ">", pyast.GtE: ">="}
_CALLS = {"sqrt", "exp", "log", "abs", "sin", "cos", "tanh", "sigmoid",
          "float", "int", "min", "max"}


def _expr(node) -> Expr:
    if isinstance(node, pyast.Name):
        return Var(node.id)
    if isinstance(node, pyast.Constant):
        return Const(node.value)
    if isinstance(node, pyast.Subscript):
        if not isinstance(node.value, pyast.Name):
            raise RejectionError("only named arrays can be indexed")
        sl = node.slice
        idxs = tuple(_expr(e) for e in (sl.elts if isinstance(sl, pyast.Tuple)
                                        else [sl]))
        return Index(node.value.id, idxs)
    if isinstance(node, pyast.BinOp):
        op = _BINOPS.get(type(node.op))
        if op is None:
            raise RejectionError(f"unsupported operator {node.op}")
        return BinOp(op, _expr(node.left), _expr(node.right))
    if isinstance(node, pyast.UnaryOp):
        if isinstance(node.op, pyast.USub):
            return UnOp("neg", _expr(node.operand))
        if isinstance(node.op, pyast.Not):
            return UnOp("not", _expr(node.operand))
        raise RejectionError("unsupported unary op")
    if isinstance(node, pyast.Compare):
        if len(node.ops) != 1:
            raise RejectionError("chained comparisons unsupported")
        return BinOp(_CMPOPS[type(node.ops[0])], _expr(node.left),
                     _expr(node.comparators[0]))
    if isinstance(node, pyast.BoolOp):
        op = "and" if isinstance(node.op, pyast.And) else "or"
        e = _expr(node.values[0])
        for v in node.values[1:]:
            e = BinOp(op, e, _expr(v))
        return e
    if isinstance(node, pyast.Call):
        if not isinstance(node.func, pyast.Name) or node.func.id not in _CALLS:
            raise RejectionError(f"unsupported call {pyast.dump(node)[:60]}")
        return Call(node.func.id, tuple(_expr(a) for a in node.args))
    if isinstance(node, pyast.IfExp):
        # e1 if c else e2  ->  where-style select
        return Call("where", (_expr(node.test), _expr(node.body),
                              _expr(node.orelse)))
    raise RejectionError(f"unsupported expression {pyast.dump(node)[:80]}")


_CALLS = _CALLS | {"where"}


def _dest(node) -> DVar | DIndex:
    if isinstance(node, pyast.Name):
        return DVar(node.id)
    if isinstance(node, pyast.Subscript):
        e = _expr(node)
        return DIndex(e.array, e.idxs)
    raise RejectionError("unsupported assignment destination")


_AUGOPS = {pyast.Add: "+", pyast.Mult: "*"}


def _stmts(nodes) -> list[Stmt]:
    out: list[Stmt] = []
    for node in nodes:
        if isinstance(node, pyast.Assign):
            if len(node.targets) != 1:
                raise RejectionError("multi-target assignment unsupported")
            dest = _dest(node.targets[0])
            val = _expr(node.value)
            # `d = min(d, e)` / `d = max(d, e)` sugar for the commutative
            # min/max incremental updates (paper's ⊕=)
            if isinstance(val, Call) and val.fn in ("min", "max") and \
                    len(val.args) == 2:
                d_as_expr = Var(dest.name) if isinstance(dest, DVar) \
                    else Index(dest.array, dest.idxs)
                if val.args[0] == d_as_expr:
                    out.append(IncUpdate(dest, val.fn, val.args[1]))
                    continue
                if val.args[1] == d_as_expr:
                    out.append(IncUpdate(dest, val.fn, val.args[0]))
                    continue
            out.append(Assign(dest, val))
        elif isinstance(node, pyast.AugAssign):
            op = _AUGOPS.get(type(node.op))
            if op is None:
                raise RejectionError(f"unsupported ⊕= operator {node.op}")
            out.append(IncUpdate(_dest(node.target), op, _expr(node.value)))
        elif isinstance(node, pyast.For):
            it = node.iter
            if isinstance(it, pyast.Call) and isinstance(it.func, pyast.Name) \
                    and it.func.id == "range":
                if not isinstance(node.target, pyast.Name):
                    raise RejectionError("range loop needs a simple index var")
                args = it.args
                lo = _expr(args[0]) if len(args) > 1 else Const(0)
                hi = _expr(args[1] if len(args) > 1 else args[0])
                out.append(ForRange(node.target.id, lo, hi, _stmts(node.body)))
            else:
                with_index = False
                if isinstance(it, pyast.Call) and isinstance(it.func, pyast.Name) \
                        and it.func.id == "items":
                    with_index = True
                    bag_name = it.args[0].id
                elif isinstance(it, pyast.Name):
                    bag_name = it.id
                else:
                    raise RejectionError("unsupported loop iterable")
                tgt = node.target
                pats = tuple(e.id for e in tgt.elts) if isinstance(tgt, pyast.Tuple) \
                    else (tgt.id,)
                out.append(ForIn(pats, bag_name, with_index, _stmts(node.body)))
        elif isinstance(node, pyast.While):
            out.append(While(_expr(node.test), _stmts(node.body)))
        elif isinstance(node, pyast.If):
            out.append(If(_expr(node.test), _stmts(node.body),
                          _stmts(node.orelse)))
        elif isinstance(node, pyast.Expr) and isinstance(node.value, pyast.Constant):
            continue  # docstring
        elif isinstance(node, pyast.Pass):
            continue
        else:
            raise RejectionError(f"unsupported statement {type(node).__name__}")
    return out


def _mutated(stmts) -> list[str]:
    names: list[str] = []

    def dest_name(d):
        return d.name if isinstance(d, DVar) else d.array

    def walk(ss):
        for s in ss:
            if isinstance(s, (Assign, IncUpdate)):
                n = dest_name(s.dest)
                if n not in names:
                    names.append(n)
            for attr in ("body", "then", "els"):
                if hasattr(s, attr):
                    walk(getattr(s, attr))
    walk(stmts)
    return names


def parse_program(fn) -> Program:
    src = textwrap.dedent(inspect.getsource(fn))
    tree = pyast.parse(src)
    fdef = tree.body[0]
    assert isinstance(fdef, (pyast.FunctionDef,))
    params: dict[str, TypeInfo] = {}
    hints = fn.__annotations__
    for a in fdef.args.args:
        ann = hints.get(a.arg)
        if isinstance(ann, str):  # PEP-563 stringized annotations
            ann = eval(ann, {**_ANNOT, "bag": bag}, dict(fn.__globals__))
        if isinstance(ann, _Ann):
            params[a.arg] = ann.info
        elif ann is None:
            params[a.arg] = TypeInfo("scalar")
        else:
            raise RejectionError(f"parameter {a.arg}: unknown annotation {ann}")
    body = _stmts(fdef.body)
    outs = tuple(n for n in _mutated(body))
    return Program(fdef.name, params, body, outs, source=src)


def loop_program(fn):
    """Decorator: parse into the loop language; attach the Program."""
    prog = parse_program(fn)
    fn.program = prog
    return fn

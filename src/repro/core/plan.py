"""Physical-plan IR — the artifact between translation and execution.

The translator (translate.py) produces target *comprehensions* (paper Fig. 2);
the pass pipeline (passes.py) turns each comprehension into one of the
physical operators below; the executor (lower.py) materializes the chosen
operator in JAX; distributed.py maps the same nodes onto a device mesh.
Nothing downstream of passes.py re-derives a plan decision — recognition
happens once, here, and every backend consumes the same plan.

Operator catalogue (paper rule in brackets):

  MapExpr         elementwise store over the iteration space  [15b, axis keys]
  Scatter         store at computed affine keys (.at[].set, drop)       [15b]
  SegmentReduce   group-by on computed keys → scatter-⊕ / Pallas kernel [15a]
  AxisReduce      group-by on pure axis keys → ⊕-reduce over the
                  contracted axes, no shuffle            [Rule 17 generalized]
  EinsumContract  +-reduction of a product of gathers → MXU contraction
                  (beyond-paper; falls back to AxisReduce at runtime)
  TiledMatmul     matmul-shaped EinsumContract on a §5 packed lhs →
                  block-sparse Pallas tile_matmul, no unpack
  ScalarReduce    total aggregation into a scalar / fixed cell  [Rule 16]
  SeqLoop         sequential while over the mutated-variable carry   [15f]
  Fused           consecutive reductions sharing one iteration space,
                  merged so distributed execution runs one collective round
  Rebalance       explicit redistribution restoring balanced ONED_ROW row
                  blocks for an ONED_VAR (variable-block) array — inserted
                  by the distribution analysis' _rebalance fixed point
                  (HPAT idiom) when a consumer needs equal blocks; a no-op
                  on a single device

Expression trees inside nodes contain `Gather` — the physical read operator
(clipped gather + inRange mask); `broadcast_ok` marks reads the
identity-traversal pass proved to be whole-array traversals, which the
executor turns into a broadcast instead of a gather when extents line up.

Runtime guards: extents and input representations (packed vs dense) are only
known at run(); optimistic nodes (EinsumContract, TiledMatmul) therefore
carry a `fallback` chain the executor walks when a guard fails.  A fallback
never changes results, only the operator used.

Every leaf node also carries a `shardings` annotation — written by the
distribution-analysis pass (dist_analysis.py) after the pipeline — mapping
each dense operand (destination first, then reads) to its inferred
placement on a device mesh: ``REP`` (replicated), ``ONED_ROW(i)``
(block-partitioned on dim 0, aligned with axis var `i` in this node),
``ONED_ROW`` (partitioned, unaligned access here) or ``TWOD_BLOCK``
(2-D block candidate; matmul operands).  `explain()` prints one
`shardings:` line per node so the chosen distribution is part of the
plan's observable contract; distributed.py consumes the same annotations
to place arrays and pick collectives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

from .comprehension import Get, pretty
from .loop_ast import Expr, Var


# ---------------------------------------------------------------------------
# physical read
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Gather(Expr):
    """Physical array read: gather with clipped indices + inRange mask.
    `broadcast_ok` = indices are distinct generator-axis vars, so when the
    runtime extents cover the array this is the array itself, broadcast."""
    array: str
    idxs: tuple[Expr, ...]
    broadcast_ok: bool = False


# ---------------------------------------------------------------------------
# iteration space
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AxisSpec:
    kind: str                    # "range" | "bag"
    var: str                     # the axis variable (loop index)
    lo: Optional[Expr] = None    # range bounds (None for bag axes)
    hi: Optional[Expr] = None
    bag: Optional[str] = None    # bag name (bag axes)
    vals: tuple[str, ...] = ()   # bag value-column variables


@dataclass(frozen=True)
class IterSpace:
    axes: tuple[AxisSpec, ...]
    conds: tuple[Expr, ...] = ()

    @property
    def axis_vars(self) -> tuple[str, ...]:
        return tuple(a.var for a in self.axes)

    @property
    def bag_names(self) -> tuple[str, ...]:
        return tuple(a.bag for a in self.axes if a.kind == "bag")

    @property
    def has_bag(self) -> bool:
        return any(a.kind == "bag" for a in self.axes)

    @property
    def bagval_vars(self) -> tuple[str, ...]:
        return tuple(v for a in self.axes for v in a.vals)

    def pretty(self) -> str:
        return "×".join(self.axis_vars) if self.axes else "·"


# ---------------------------------------------------------------------------
# static einsum description
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EinsumFactors:
    """A +-product of gathers: factors[i] is indexed purely by generator-axis
    vars (factor_axes[i]); `others` are residual axis-free scalar factors."""
    factors: tuple[Gather, ...]
    factor_axes: tuple[tuple[str, ...], ...]
    others: tuple[Expr, ...] = ()

    def spec(self, key_axes) -> str:
        ins = ",".join("".join(a) for a in self.factor_axes)
        return ins + "->" + "".join(key_axes)


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------

@dataclass
class MapExpr:
    """Elementwise store: dest[key_axes] := value over the space (key_axes
    None = scalar assignment guarded by the space's conds)."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    value: Expr
    key_axes: Optional[tuple[str, ...]] = None
    shardings: Optional[dict] = None   # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        if self.key_axes is None:
            return f"MapExpr(scalar) → {self.dest}"
        return (f"MapExpr[{self.space.pretty()}] → "
                f"{self.dest}[{','.join(self.key_axes)}]")


@dataclass
class DenseMap(MapExpr):
    """Dense fast-path specialization of MapExpr (pass: dense-fastpath):
    the iteration space is a 0-based all-range space whose key order IS the
    axis order, and every read in the value is an identity gather (indexed
    by exactly the key axes, in order) or a scalar.  The executor lowers it
    to a plain vectorized jnp expression over whole arrays — no index-grid
    materialization, no gathers, no masks, no .at[].set — locally and per
    shard (aligned operands are used as local blocks, replicated ones via a
    bounds-certified dynamic slice).  Runtime extent mismatch falls back to
    the general MapExpr path; results never change."""

    def describe(self) -> str:
        return (f"DenseMap[{self.space.pretty()}] → "
                f"{self.dest}[{','.join(self.key_axes)}]"
                f"  (vectorized, gathers elided)")


@dataclass
class Scatter:
    """Store at computed affine keys (restrictions ⇒ no duplicate keys)."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    keys: tuple[Expr, ...]
    value: Expr
    shardings: Optional[dict] = None   # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        return f"Scatter[{self.space.pretty()}] → {self.dest} (drop OOB)"


@dataclass
class SegmentReduce:
    """Group-by on computed keys → segment-⊕ into the destination index
    space (the paper's shuffle).  `candidates` is the backend candidate
    set the operator-selection pass attached (op_select.py, DESIGN.md §8):
    scatter-⊕ / sort-based segment reduce / one-hot dot_general / the
    Pallas MXU kernel.  `backend="auto"` defers the choice to the
    cost-model/autotune selector at trace time (shapes are known there);
    any concrete name pins it.  The executor records the resolved choice —
    explain() prints it as a `selected:` line after a run."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    keys: tuple[Expr, ...]
    op: str
    value: Expr
    backend: str = "scatter"     # "auto" | one of `candidates`
    candidates: tuple[str, ...] = ("scatter",)
    shardings: Optional[dict] = None   # dist_analysis annotation
    salt: Optional[int] = None   # hot-key salting static hint: spread each
    # key over S sub-destinations (key*S + salt), fold salts after; None =
    # let op_select.choose_salt decide per shape class / runtime probe
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        b = self.backend if self.backend != "auto" else \
            "auto{" + "|".join(self.candidates) + "}"
        return (f"SegmentReduce({self.op}, backend={b})"
                f"[{self.space.pretty()}] → {self.dest}")


@dataclass
class AxisReduce:
    """Group-by on pure axis keys (Rule 17 generalized): ⊕-reduce the
    contracted axes; elementwise merge when nothing is contracted.

    `product` is the dense fast-path MXU certificate (pass:
    dense-fastpath): when the +-reduced value is recognized as a product of
    axis-indexed gathers, the executor materializes THIS SAME operator via
    jnp.einsum instead of the dense iteration grid.  Unlike EinsumContract
    this is not a plan-level operator change — the node stays an
    AxisReduce (the paper-faithful operator choice, kept under
    optimize_contractions=False) and only its local materialization rides
    the MXU; guard failure falls back to the grid."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    key_axes: tuple[str, ...]
    op: str
    value: Expr
    product: Optional[EinsumFactors] = None   # dense-fastpath MXU certificate
    shardings: Optional[dict] = None   # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    @property
    def contracted(self) -> tuple[str, ...]:
        ks = set(self.key_axes)
        return tuple(a for a in self.space.axis_vars if a not in ks)

    def describe(self) -> str:
        over = ",".join(self.contracted) or "·"
        base = (f"AxisReduce({self.op} over {over}) → "
                f"{self.dest}[{','.join(self.key_axes)}]")
        if self.product is not None:
            base += f"  [mxu: '{self.product.spec(self.key_axes)}']"
        return base


@dataclass
class EinsumContract:
    """+-contraction of a product of gathers (or a ±-sum of such products in
    `terms` mode) lowered to jnp.einsum.  Falls back to `fallback` when a
    runtime extent guard fails."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    key_axes: tuple[str, ...]
    product: Optional[EinsumFactors] = None
    scalars: tuple[Expr, ...] = ()        # axis-free factors (terms mode)
    terms: Optional[tuple] = None         # ((sign, Expr, EinsumFactors|None), ...)
    fallback: Optional[AxisReduce] = None
    candidates: tuple[str, ...] = ("einsum", "dense-grid")  # guard chain
    shardings: Optional[dict] = None      # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    @property
    def op(self) -> str:
        return "+"      # einsum recognition only fires on +-reductions

    @property
    def contracted(self) -> tuple[str, ...]:
        ks = set(self.key_axes)
        return tuple(a for a in self.space.axis_vars if a not in ks)

    def describe(self) -> str:
        if self.product is not None:
            ops = ",".join(f.array for f in self.product.factors)
            return (f"EinsumContract('{self.product.spec(self.key_axes)}'; "
                    f"{ops}) → {self.dest}")
        return (f"EinsumContract(term-split, {len(self.terms or ())} terms "
                f"over {','.join(self.contracted)}) → {self.dest}")


@dataclass
class TiledMatmul:
    """§5 packed-array fusion: a matmul-shaped contraction whose lhs arrives
    as a TiledMatrix runs the block-sparse Pallas tile_matmul directly on
    the tiles (no unpack).  Dense lhs at runtime → `contract`."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    contract: EinsumContract
    candidates: tuple[str, ...] = ("pallas-tiled", "unpack-einsum")
    shardings: Optional[dict] = None   # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    @property
    def op(self) -> str:
        return "+"

    @property
    def lhs(self) -> str:
        return self.contract.product.factors[0].array

    @property
    def rhs(self) -> str:
        return self.contract.product.factors[1].array

    def describe(self) -> str:
        return (f"TiledMatmul(pallas tile_matmul on packed {self.lhs}, "
                f"rhs {self.rhs}) → {self.dest}")


@dataclass
class ScalarReduce:
    """Rule 16: total ⊕-aggregation into a scalar, or into one fixed cell
    (`point`) for constant group-by keys.  `dense` is the dense fast-path
    certificate (pass: dense-fastpath): the value and conditions read only
    bag value columns and scalars — the reduction is a pure columnar
    ⊕-fold with no gathers and no index-grid materialization."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str
    op: str
    value: Expr
    point: Optional[tuple[int, ...]] = None
    bool_any: Optional[Expr] = None  # peephole: max/min of float(bool) → any/all
    dense: bool = False              # dense-fastpath columnar certificate
    shardings: Optional[dict] = None  # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        tgt = self.dest if self.point is None else \
            f"{self.dest}[{','.join(map(str, self.point))}]"
        tail = "  [dense: columnar, no gathers]" if self.dense else ""
        return f"ScalarReduce({self.op})[{self.space.pretty()}] → {tgt}{tail}"


@dataclass
class SeqLoop:
    """lax.while_loop over the carry of body-mutated variables."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    cond: Expr
    body: list = field(default_factory=list)
    carry: tuple[str, ...] = ()
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        return f"SeqLoop(carry={','.join(self.carry)})"


@dataclass
class Rebalance:
    """Explicit redistribution of one ONED_VAR array back to balanced
    ONED_ROW row blocks (HPAT's rebalance round).  Inserted by
    pass_distribution when the analysis' `_rebalance` fixed point pins the
    array up from ONED_VAR (dist_analysis.analyze rebalance_out =
    "inserted").  Distributed execution is a cached shard_map round built
    from the existing exchange machinery: per-shard live-row counts are
    exchanged with a one-hot `psum` (size exchange), exclusive-cumsummed
    into global offsets, and rows are scattered to their balanced global
    positions then redistributed with `psum_scatter` (each target position
    receives exactly one addend, so the composition is an exact all-to-all,
    not an approximate reduction).  On canonical front-packed layouts the
    round is value-identity; the single-device executor runs it as a
    no-op.  Results never change — only the placement contract."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    dest: str                          # the array being rebalanced in place
    shardings: Optional[dict] = None   # dist_analysis annotation
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        return (f"Rebalance({self.dest}) "
                f"(size exchange + all-to-all, ONED_VAR→ONED_ROW)")


@dataclass
class Fused:
    """Cross-statement fusion: consecutive reductions over one iteration
    space with disjoint destinations; distributed mode runs them as a single
    shard_map round."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    parts: list = field(default_factory=list)
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    def describe(self) -> str:
        return f"Fused[{self.space.pretty()}] {{{len(self.parts)} updates}}"


@dataclass
class FusedRound:
    """Round-fusion region (pass 11, round-fusion): adjacent plan nodes the
    distributed executor may run as ONE shard_map program, with the
    collectives (psum / psum_scatter / all_gather) placed INSIDE the fused
    body instead of one jit+shard_map dispatch per node.  Unlike `Fused`
    (one iteration space, disjoint destinations, parallel parts) the
    members here execute SEQUENTIALLY — later members see earlier results —
    and each member keeps its own round classification (aligned store /
    aligned reduce / unaligned reduce / replicated scalar).  A SeqLoop
    whose whole body is one region additionally runs as an ON-DEVICE
    lax.while_loop inside the same shard_map program when its condition is
    computable from the carry, eliminating the per-iteration host sync.
    lineage = None   # RoundLineage recovery recipe (core/lineage.py, §13)

    The single-device executor treats the region as plain sequencing; the
    distributed executor verifies member compatibility against runtime
    shapes at round-build time and falls back to per-member rounds when a
    guard fails.  Grouping never changes results, only dispatch."""
    stmt: Any
    space: IterSpace
    reads: frozenset
    parts: list = field(default_factory=list)

    def describe(self) -> str:
        return f"FusedRound{{{len(self.parts)} members}}"


PlanNode = Any

REDUCE_NODES = (SegmentReduce, AxisReduce, EinsumContract, TiledMatmul,
                ScalarReduce)


def dests_of(node: PlanNode) -> tuple[str, ...]:
    if isinstance(node, Fused):
        return tuple(p.dest for p in node.parts)
    if isinstance(node, FusedRound):
        out: list = []
        for p in node.parts:
            for d in dests_of(p):
                if d not in out:
                    out.append(d)
        return tuple(out)
    if isinstance(node, SeqLoop):
        return node.carry
    return (node.dest,)


def flatten(nodes) -> list:
    """Top-level nodes with FusedRound regions opened (members in order).
    SeqLoop and Fused are NOT opened — they are operators, not regions."""
    out: list = []
    for n in nodes:
        if isinstance(n, FusedRound):
            out.extend(flatten(n.parts))
        else:
            out.append(n)
    return out


def seq_loops(nodes) -> list:
    """(index, SeqLoop) for every top-level sequential loop in execution
    order, FusedRound containers opened — the stable loop numbering the
    checkpoint/resume path keys carry snapshots by (DESIGN.md §11).
    Nested SeqLoops are not enumerated: they execute inside their parent
    loop's body and their state is covered by the parent's carry."""
    return [(i, n) for i, n in enumerate(
        n for n in flatten(nodes) if isinstance(n, SeqLoop))]


def is_reduce(node: PlanNode) -> bool:
    return isinstance(node, REDUCE_NODES) or (
        isinstance(node, Fused)
        and all(isinstance(p, REDUCE_NODES) for p in node.parts))


# ---------------------------------------------------------------------------
# bag-row alignment (batchable-entry hook, serving layer — DESIGN.md §10)
# ---------------------------------------------------------------------------

def _walk_exprs(e, fn):
    if e is None:
        return
    fn(e)
    for attr in ("lhs", "rhs", "e"):
        if hasattr(e, attr):
            _walk_exprs(getattr(e, attr), fn)
    for attr in ("args", "idxs"):
        if hasattr(e, attr):
            for a in getattr(e, attr):
                _walk_exprs(a, fn)


def bag_row_arrays(plan) -> dict:
    """array name → bag name for every dense array whose dim-0 rides a
    bag's ROW axis: somewhere in the plan the array is read with a bag
    AXIS var (the `items()` index) as its leading index, or stored with a
    bag axis var as its leading key axis.  Such an array's dim-0 extent is
    the bag's row count by construction, so a caller padding the bag's
    rows (the serving layer's shape buckets, DESIGN.md §10) must pad the
    array's dim-0 in lockstep and thread a matching `array_limits` entry.
    Arrays whose leading index is a range var or a computed expression are
    NOT included — their dim-0 is pinned by a static dim, never the bag
    length.  An array aligned with two different bags is dropped (no
    single pad length is correct for it)."""
    out: dict = {}
    dropped: set = set()

    def note(arr: str, bag: str):
        if out.setdefault(arr, bag) != bag:
            dropped.add(arr)

    def visit(nodes):
        for node in nodes:
            if isinstance(node, SeqLoop):
                visit(node.body)
                continue
            if isinstance(node, (Fused, FusedRound)):
                visit(node.parts)
                continue
            space = getattr(node, "space", None)
            if space is None:
                continue
            bagvars = {a.var: a.bag for a in space.axes if a.kind == "bag"}
            if not bagvars:
                continue

            def read(e, _bv=bagvars):
                if isinstance(e, (Gather, Get)) and e.idxs:
                    i0 = e.idxs[0]
                    if isinstance(i0, Var) and i0.name in _bv:
                        note(e.array, _bv[i0.name])

            for attr in ("value", "cond", "bool_any"):
                _walk_exprs(getattr(node, attr, None), read)
            for k in getattr(node, "keys", ()) or ():
                _walk_exprs(k, read)
            for c in space.conds:
                _walk_exprs(c, read)
            key_axes = getattr(node, "key_axes", None)
            if key_axes and key_axes[0] in bagvars:
                note(node.dest, bagvars[key_axes[0]])
            if isinstance(node, EinsumContract) and node.fallback is not None:
                visit([node.fallback])
            elif isinstance(node, TiledMatmul):
                visit([node.contract])
    visit(plan)
    return {a: b for a, b in out.items() if a not in dropped}


# ---------------------------------------------------------------------------
# plan pretty-printer (Spark-EXPLAIN-style)
# ---------------------------------------------------------------------------

def _node_lines(node: PlanNode, indent: int, tiled, out: list,
                decisions=None):
    pre = "  " * indent
    if isinstance(node, SeqLoop):
        out.append(f"{pre}{node.describe()}")
        for b in node.body:
            _node_lines(b, indent + 1, tiled, out, decisions)
        return
    if isinstance(node, (Fused, FusedRound)):
        out.append(f"{pre}{node.describe()}")
        for p in node.parts:
            _node_lines(p, indent + 1, tiled, out, decisions)
        return
    if isinstance(node, TiledMatmul) and node.lhs not in tiled:
        # resolve the runtime representation guard for display
        _node_lines(node.contract, indent, tiled, out, decisions)
        return
    line = f"{pre}{node.describe()}"
    if isinstance(node, EinsumContract) and node.fallback is not None:
        line += f"  [fallback: {node.fallback.describe()}]"
    if isinstance(node, TiledMatmul):
        line += f"  [dense lhs: {node.contract.describe()}]"
    out.append(line)
    if node.stmt is not None:
        out.append(f"{pre}    {pretty(node.stmt)}")
    if getattr(node, "shardings", None):
        out.append(f"{pre}    shardings: " + ", ".join(
            f"{k}={v}" for k, v in node.shardings.items()))
    if decisions:
        d = decisions.get(id(node))
        if d is None and isinstance(node, TiledMatmul):
            d = decisions.get(id(node.contract))
        if d is not None:
            out.append(f"{pre}    selected: {d}")


def explain(plan: list, name: str = "", tiled=(), decisions=None) -> str:
    """Pretty-print the chosen physical operator per statement.  `tiled`
    names parameters assumed to arrive as §5 packed TiledMatrix inputs,
    resolving the TiledMatmul-vs-einsum runtime guard for display.
    `decisions` (id(node) → tag, the executor's trace-time record) adds a
    `selected:` line per node — the operator-selection subsystem's
    observable contract (op_select.py): which backend actually ran."""
    out = [f"== physical plan{': ' + name if name else ''} =="]
    for i, node in enumerate(plan):
        sub: list = []
        _node_lines(node, 0, frozenset(tiled), sub, decisions)
        out.append(f"[{i}] {sub[0]}")
        out.extend("    " + s for s in sub[1:])
    return "\n".join(out)

"""AST of the loop-based language (paper Figure 1).

Destinations (L-values), expressions and statements.  Types of interest:
scalars, vector[n], matrix[n,m], map[K]->V (bounded int-keyed, implicit
zero), and bags (read-only input collections, struct-of-arrays).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Expr:
    pass


@dataclass(frozen=True)
class Var(Expr):
    name: str


@dataclass(frozen=True)
class Const(Expr):
    value: Any


@dataclass(frozen=True)
class Index(Expr):
    """Array access v[e1, ..., en]."""
    array: str
    idxs: tuple[Expr, ...]


@dataclass(frozen=True)
class BinOp(Expr):
    op: str          # + - * / // % ** min max == != < <= > >= and or
    lhs: Expr
    rhs: Expr


@dataclass(frozen=True)
class UnOp(Expr):
    op: str          # neg not
    e: Expr


@dataclass(frozen=True)
class Call(Expr):
    fn: str          # sqrt exp log abs sin cos tanh sigmoid float int
    args: tuple[Expr, ...]


# ---------------------------------------------------------------------------
# Destinations (L-values)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Dest:
    pass


@dataclass(frozen=True)
class DVar(Dest):
    name: str


@dataclass(frozen=True)
class DIndex(Dest):
    array: str
    idxs: tuple[Expr, ...]


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------

@dataclass
class Stmt:
    pass


@dataclass
class Assign(Stmt):
    dest: Dest
    value: Expr


@dataclass
class IncUpdate(Stmt):
    """d ⊕= e for commutative ⊕ in {+, *, min, max}."""
    dest: Dest
    op: str
    value: Expr


@dataclass
class ForRange(Stmt):
    var: str
    lo: Expr
    hi: Expr          # EXCLUSIVE (python range semantics)
    body: list[Stmt] = field(default_factory=list)


@dataclass
class ForIn(Stmt):
    """Iterate over a bag: `for (a, b) in E` / `for v in V` (values) /
    `for i, v in items(V)` (index+value)."""
    pats: tuple[str, ...]
    bag: str
    with_index: bool
    body: list[Stmt] = field(default_factory=list)


@dataclass
class While(Stmt):
    cond: Expr
    body: list[Stmt] = field(default_factory=list)


@dataclass
class If(Stmt):
    cond: Expr
    then: list[Stmt] = field(default_factory=list)
    els: list[Stmt] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Declared types of program variables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TypeInfo:
    kind: str                 # scalar | vector | matrix | map | bag | dim
    dims: tuple[str, ...] = ()   # symbolic dim names (vector/matrix/map)
    fields: int = 1           # components for bags of tuples
    dtype: str = "float"


@dataclass
class Program:
    name: str
    params: dict[str, TypeInfo]
    body: list[Stmt]
    outputs: tuple[str, ...]     # mutated variables (in declaration order)
    source: str = ""

    def pretty(self) -> str:
        out = [f"program {self.name}({', '.join(self.params)}):"]

        def pe(e: Expr) -> str:
            if isinstance(e, Var):
                return e.name
            if isinstance(e, Const):
                return repr(e.value)
            if isinstance(e, Index):
                return f"{e.array}[{', '.join(pe(i) for i in e.idxs)}]"
            if isinstance(e, BinOp):
                return f"({pe(e.lhs)} {e.op} {pe(e.rhs)})"
            if isinstance(e, UnOp):
                return f"({e.op} {pe(e.e)})"
            if isinstance(e, Call):
                return f"{e.fn}({', '.join(pe(a) for a in e.args)})"
            return str(e)

        def pd(d: Dest) -> str:
            if isinstance(d, DVar):
                return d.name
            return f"{d.array}[{', '.join(pe(i) for i in d.idxs)}]"

        def ps(s: Stmt, ind: int):
            pre = "  " * ind
            if isinstance(s, Assign):
                out.append(f"{pre}{pd(s.dest)} := {pe(s.value)}")
            elif isinstance(s, IncUpdate):
                out.append(f"{pre}{pd(s.dest)} {s.op}= {pe(s.value)}")
            elif isinstance(s, ForRange):
                out.append(f"{pre}for {s.var} = {pe(s.lo)}, {pe(s.hi)}-1 do")
                for b in s.body:
                    ps(b, ind + 1)
            elif isinstance(s, ForIn):
                pats = ", ".join(s.pats)
                out.append(f"{pre}for ({pats}) in {s.bag} do")
                for b in s.body:
                    ps(b, ind + 1)
            elif isinstance(s, While):
                out.append(f"{pre}while ({pe(s.cond)}) do")
                for b in s.body:
                    ps(b, ind + 1)
            elif isinstance(s, If):
                out.append(f"{pre}if ({pe(s.cond)})")
                for b in s.then:
                    ps(b, ind + 1)
                if s.els:
                    out.append(f"{pre}else")
                    for b in s.els:
                        ps(b, ind + 1)

        for s in self.body:
            ps(s, 1)
        return "\n".join(out)


class RejectionError(Exception):
    """Program violates the parallelization restrictions (paper Def. 3.1)."""

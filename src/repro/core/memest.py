"""Peak-device-bytes estimation over a physical plan (DESIGN.md §12).

A static pass: given the plan and the *shapes* of a call's inputs (never
the values), predict how many device bytes the all-resident executor
needs at its worst moment.  The estimate drives three consumers:

  * admission — `CompiledProgram` compares it against `memory_budget`
    before dispatch and routes oversized calls to the chunked
    out-of-core tier (core/chunked.py) instead of letting XLA OOM;
  * chunk sizing — `chunked.choose_chunk_rows` solves
    ``fixed + rows·per_row ≤ budget`` for the streaming tile;
  * serving — `serve/plans.py` caps concurrent lanes per flush at
    ``budget // peak`` so a batch never projects past the budget.

The model is deliberately simple and leans conservative (admission
errs toward chunking, which is always correct, never toward OOM):

  resident   every parameter array and bag column, at the dtype the
             executor would place it with (prepare_env canonicalizes
             floats to f32 / ints to i32);
  temps      grid nodes materialize index grids + gathered operand
             values + masks over the full iteration space — counted as
             ``cells × 4 bytes × (value + keys + reads + conds + mask)``;
             dense fast-path nodes (DenseMap, columnar ScalarReduce,
             einsum) skip the grids and cost operands + partial only;
  dest copy  a non-donated functional update holds old and new
             destination simultaneously; whole-program donation credits
             it back (the `donation credit` line);
  collective per-round partial-⊕ buffers + gathered remote operands
             when the plan runs on `nshards` > 1 devices.

peak = resident + max over nodes (temp + dest copy + collective).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from . import plan as P
from .loop_ast import Const, Var

__all__ = ["MemEstimate", "NodeCost", "shape_env", "shape_env_from_signature",
           "estimate", "fmt_bytes"]


def fmt_bytes(n: int) -> str:
    n = int(n)
    if abs(n) < 1024:
        return f"{n}B"
    for unit, div in (("KiB", 1024), ("MiB", 1024 ** 2), ("GiB", 1024 ** 3)):
        if abs(n) < div * 1024 or unit == "GiB":
            return f"{n / div:.1f}{unit}"
    return f"{n}B"


def _canon_dtype(dt) -> np.dtype:
    """Mirror prepare_env/jnp.asarray x64→x32 canonicalization."""
    dt = np.dtype(dt)
    if dt == np.float64:
        return np.dtype(np.float32)
    if dt == np.int64:
        return np.dtype(np.int32)
    if dt == np.uint64:
        return np.dtype(np.uint32)
    return dt


# ---------------------------------------------------------------------------
# shape environments — name → ("dim", v) | ("bag", rows, cols) | ("array", shape, itemsize)
# ---------------------------------------------------------------------------

def shape_env(prog, inputs: dict) -> dict:
    """Shape-only view of a concrete inputs dict (host-side; never forces
    a device transfer — only `.shape`/`.dtype` are touched)."""
    env: dict = {}
    for name, t in prog.params.items():
        v = inputs[name]
        if t.kind == "dim":
            env[name] = ("dim", int(v))
        elif t.kind == "bag":
            cols = v if isinstance(v, tuple) else (v,)
            centries = tuple(
                (tuple(np.shape(c)), _canon_dtype(getattr(c, "dtype", np.float32)).itemsize)
                for c in cols)
            rows = centries[0][0][0] if centries and centries[0][0] else 0
            env[name] = ("bag", int(rows), centries)
        else:
            itemsize = 4        # executor places f32 / i32
            env[name] = ("array", tuple(np.shape(v)), itemsize)
    return env


def shape_env_from_signature(prog, sig) -> dict:
    """Same view built from a `CompiledProgram._signature` tuple — what the
    serving layer has for a shape bucket (DESIGN.md §10) without any
    concrete request payload."""
    env: dict = {}
    for entry in sig:
        name, kind = entry[0], entry[1]
        if kind == "dim":
            env[name] = ("dim", int(entry[2]))
        elif kind == "bag":
            centries = tuple((tuple(shape), _canon_dtype(dt).itemsize)
                             for shape, dt in entry[2])
            rows = centries[0][0][0] if centries and centries[0][0] else 0
            env[name] = ("bag", int(rows), centries)
        else:
            env[name] = ("array", tuple(entry[2]), 4)
    return env


def _bag_bytes(entry) -> int:
    _, rows, cols = entry
    return sum(int(np.prod(shape or (1,))) * item for shape, item in cols)


def _bag_row_bytes(entry) -> int:
    _, rows, cols = entry
    if rows <= 0:
        return sum(item for _, item in cols)
    return max(1, _bag_bytes(entry) // max(rows, 1))


def _array_bytes(entry) -> int:
    _, shape, item = entry
    return int(np.prod(shape or (1,))) * item


# ---------------------------------------------------------------------------
# static extent evaluation
# ---------------------------------------------------------------------------

def _static(e, dims: dict) -> int | None:
    if e is None:
        return None
    if isinstance(e, Const):
        return int(e.value)
    if isinstance(e, Var):
        v = dims.get(e.name)
        return int(v) if isinstance(v, (int, np.integer)) else None
    lhs = getattr(e, "lhs", None)
    rhs = getattr(e, "rhs", None)
    op = getattr(e, "op", None)
    if lhs is not None and rhs is not None and op is not None:
        a, b = _static(lhs, dims), _static(rhs, dims)
        if a is None or b is None:
            return None
        try:
            return int({"+": a + b, "-": a - b, "*": a * b,
                        "//": a // b if b else 0, "/": a // b if b else 0,
                        "%": a % b if b else 0}.get(op))
        except (TypeError, ZeroDivisionError):
            return None
    return None


def _axis_extent(a: P.AxisSpec, dims: dict, bags: dict) -> int:
    if a.kind == "bag":
        entry = bags.get(a.bag)
        return entry[1] if entry else 0
    lo = _static(a.lo, dims)
    hi = _static(a.hi, dims)
    if lo is None or hi is None:
        return 1
    return max(0, hi - lo)


def _space_cells(space: P.IterSpace, dims: dict, bags: dict) -> int:
    cells = 1
    for a in space.axes:
        cells *= max(1, _axis_extent(a, dims, bags))
    return cells


def _count_reads(node) -> int:
    """Gathered operand values materialized over the grid."""
    seen = 0

    def visit(e):
        nonlocal seen
        if isinstance(e, P.Gather):
            seen += 1

    exprs = []
    for attr in ("value", "bool_any"):
        v = getattr(node, attr, None)
        if v is not None:
            exprs.append(v)
    exprs.extend(getattr(node, "keys", ()) or ())
    space = getattr(node, "space", None)
    if space is not None:
        exprs.extend(space.conds)
    for e in exprs:
        P._walk_exprs(e, visit)
    return seen


# ---------------------------------------------------------------------------
# per-node temp model
# ---------------------------------------------------------------------------

@dataclass
class NodeCost:
    label: str
    temp: int = 0          # grid / operand temporaries while the node runs
    dest: int = 0          # destination bytes (the functional-update copy)
    collective: int = 0    # per-round exchange buffers when nshards > 1
    per_row: dict = field(default_factory=dict)   # bag → streaming bytes/row


def _dest_bytes(name: str, env: dict) -> int:
    entry = env.get(name)
    if entry is None:
        return 4                       # loop counters / fresh scalars
    if entry[0] == "array":
        return _array_bytes(entry)
    if entry[0] == "bag":
        return _bag_bytes(entry)
    return 4                           # dim


def _node_cost(node, env: dict, dims: dict, bags: dict, nshards: int) -> NodeCost:
    if isinstance(node, (P.Fused, P.FusedRound)):
        parts = [_node_cost(p, env, dims, bags, nshards) for p in node.parts]
        if isinstance(node, P.Fused):       # parts share one grid: temps coexist
            c = NodeCost(node.describe(),
                         temp=sum(p.temp for p in parts),
                         dest=sum(p.dest for p in parts),
                         collective=sum(p.collective for p in parts))
        else:                               # members run sequentially
            c = NodeCost(node.describe(),
                         temp=max((p.temp for p in parts), default=0),
                         dest=max((p.dest for p in parts), default=0),
                         collective=max((p.collective for p in parts), default=0))
        for p in parts:
            for bag, pr in p.per_row.items():
                c.per_row[bag] = max(c.per_row.get(bag, 0), pr)
        return c

    if isinstance(node, P.SeqLoop):
        body = [_node_cost(p, env, dims, bags, nshards) for p in node.body]
        c = NodeCost(node.describe(),
                     temp=max((p.temp for p in body), default=0),
                     dest=sum(_dest_bytes(d, env) for d in node.carry),
                     collective=max((p.collective for p in body), default=0))
        for p in body:
            for bag, pr in p.per_row.items():
                c.per_row[bag] = max(c.per_row.get(bag, 0), pr)
        return c

    if isinstance(node, P.Rebalance):
        d = _dest_bytes(node.dest, env)
        return NodeCost(node.describe(), temp=d, dest=d,
                        collective=d if nshards > 1 else 0)

    space = getattr(node, "space", None)
    dest = _dest_bytes(getattr(node, "dest", ""), env)
    label = node.describe()
    cells = _space_cells(space, dims, bags) if space is not None else 1
    n_reads = _count_reads(node)
    n_keys = len(getattr(node, "keys", ()) or
                 getattr(node, "key_axes", ()) or ())
    n_conds = len(space.conds) if space is not None else 0

    if isinstance(node, P.DenseMap):
        # vectorized whole-array expression: operands + result, no grids
        temp = dest + n_reads * dest
    elif isinstance(node, (P.EinsumContract, P.TiledMatmul)):
        contract = node.contract if isinstance(node, P.TiledMatmul) else node
        ops = 0
        prod = contract.product
        if prod is not None:
            for g in prod.factors:
                ops += _dest_bytes(g.array, env)
        temp = ops + dest
    elif isinstance(node, P.ScalarReduce) and node.dense:
        # columnar fold over bag value columns: one value vector + masks
        rows = max((bags[b][1] for b in space.bag_names if b in bags),
                   default=cells) if space is not None else 1
        temp = rows * 4 * 2
    else:
        # general grid path: index grids per axis-keyed slot, a gathered
        # value per read, one mask stack (4 bytes/cell each, f32/u32)
        slots = 1 + n_keys + n_reads + max(1, n_conds)
        temp = cells * 4 * slots

    coll = 0
    if nshards > 1 and P.is_reduce(node):
        # partial-⊕ buffer on every shard + gathered remote operands
        coll = dest + sum(_dest_bytes(g, env)
                          for g in _gather_names(node))

    cost = NodeCost(label, temp=temp, dest=dest, collective=coll)
    if space is not None:
        for a in space.axes:
            if a.kind == "bag" and a.bag in bags:
                rows = max(1, bags[a.bag][1])
                cost.per_row[a.bag] = max(1, math.ceil(temp / rows))
    return cost


def _gather_names(node) -> set:
    names: set = set()

    def visit(e):
        if isinstance(e, P.Gather):
            names.add(e.array)

    for attr in ("value", "bool_any"):
        v = getattr(node, attr, None)
        if v is not None:
            P._walk_exprs(v, visit)
    for k in getattr(node, "keys", ()) or ():
        P._walk_exprs(k, visit)
    return names


# ---------------------------------------------------------------------------
# the estimate
# ---------------------------------------------------------------------------

@dataclass
class MemEstimate:
    program: str
    resident: int                  # all params placed on device
    bag_bytes: dict                # bag → total bytes (streamable share)
    dest_bytes: int                # bytes of all plan destinations
    nodes: list                    # NodeCost, plan order
    donation_credit: int           # dest copies whole-program donation elides
    peak: int                      # resident + worst node moment
    nshards: int = 1

    @property
    def peak_bytes(self) -> int:
        return self.peak

    @property
    def fixed_bytes(self) -> int:
        """What stays device-resident under chunked streaming: everything
        except the bags themselves (dests, dense params, scalars)."""
        return max(0, self.resident - sum(self.bag_bytes.values())) \
            + self.dest_bytes

    def per_row(self, bag: str | None = None) -> int:
        """Streaming bytes per bag row: the tile's columns (double-buffered
        host→device prefetch keeps two tiles in flight) plus the widest
        per-row grid temp of any node that consumes the bag."""
        rows_pr = {}
        for b, total in self.bag_bytes.items():
            base = 2 * max(1, total // max(1, self._bag_rows.get(b, 1)))
            node_pr = max((c.per_row.get(b, 0) for c in self.nodes), default=0)
            rows_pr[b] = base + node_pr
        if bag is not None:
            return rows_pr.get(bag, 1)
        return max(rows_pr.values(), default=1)

    _bag_rows: dict = field(default_factory=dict)

    def summary(self, budget: int | None = None) -> str:
        line = (f"memory: peak≈{fmt_bytes(self.peak)} "
                f"(resident {fmt_bytes(self.resident)}, "
                f"worst-node temps {fmt_bytes(self.peak - self.resident)}"
                + (f", donation credit {fmt_bytes(self.donation_credit)}"
                   if self.donation_credit else "") + ")")
        if budget is not None:
            verdict = "all-resident" if self.peak <= budget else "chunked"
            line += f"  budget={fmt_bytes(budget)} → {verdict}"
        return line

    def explain(self, budget: int | None = None) -> str:
        out = [f"== memory estimate: {self.program} =="]
        out.append(f"resident: {fmt_bytes(self.resident)}"
                   + (f"  (bags {fmt_bytes(sum(self.bag_bytes.values()))})"
                      if self.bag_bytes else "")
                   + (f"  [{self.nshards} shards]" if self.nshards > 1 else ""))
        for i, c in enumerate(self.nodes):
            extra = ""
            if c.collective:
                extra += f" +collective {fmt_bytes(c.collective)}"
            out.append(f"[{i}] {c.label}: temp {fmt_bytes(c.temp)}"
                       f" +dest-copy {fmt_bytes(c.dest)}{extra}")
        out.append(self.summary(budget))
        if self.bag_bytes:
            prs = ", ".join(f"{b}≈{fmt_bytes(self.per_row(b))}/row"
                            for b in sorted(self.bag_bytes))
            out.append(f"streaming: fixed {fmt_bytes(self.fixed_bytes)}, {prs}")
        return "\n".join(out)


def estimate(plan, prog, env: dict, *, donate: bool = False,
             nshards: int = 1) -> MemEstimate:
    """env: a `shape_env`/`shape_env_from_signature` dict."""
    dims = {n: e[1] for n, e in env.items() if e[0] == "dim"}
    bags = {e_name: entry for e_name, entry in
            ((n, e) for n, e in env.items() if e[0] == "bag")}
    # bag axes refer to bags by BAG NAME == param name
    resident = 0
    bag_bytes = {}
    for name, entry in env.items():
        if entry[0] == "bag":
            b = _bag_bytes(entry)
            resident += b
            bag_bytes[name] = b
        elif entry[0] == "array":
            resident += _array_bytes(entry)

    nodes = P.flatten(plan)
    costs = [_node_cost(n, env, dims, bags, nshards) for n in nodes]

    dests: list = []
    for n in nodes:
        for d in P.dests_of(n):
            if d not in dests:
                dests.append(d)
    dest_total = sum(_dest_bytes(d, env) for d in dests)

    credit = 0
    worst = 0
    for c in costs:
        copy = 0 if donate else c.dest
        if donate:
            credit = max(credit, c.dest)
        worst = max(worst, c.temp + copy + c.collective)

    est = MemEstimate(program=getattr(prog, "name", "?"),
                      resident=resident, bag_bytes=bag_bytes,
                      dest_bytes=dest_total, nodes=costs,
                      donation_credit=credit,
                      peak=resident + worst, nshards=nshards)
    est._bag_rows = {n: e[1] for n, e in env.items() if e[0] == "bag"}
    return est

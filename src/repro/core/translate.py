"""Translation of loop programs to target comprehension code — the paper's
Figure 2 rules, with Rule (2) (comprehension unnesting) applied on the fly
so every produced comprehension is already flat, and the §3.6 loop-
iteration elimination specialized to dense arrays (array accesses become
`Get` gathers with implicit inRange guards).

Rule map:
  E  (11a-g): `_expr` — expressions lift to (qualifiers, value expr)
  K  (12a-c): destination key exprs = translated destination indexes
  D  (13a-c): old destination value — implicit in the ◁⊕ merge of BulkUpdate
  U  (14a-c): `BulkStore`/`BulkUpdate` carry the dest merge
  S  (15a-h): `translate_stmt` threading the loop-qualifier list q̄
  Rule (16):  constant (empty) key group-by → ScalarAgg total aggregation
  Rule (17):  unique affine keys → handled in lower.py (axis reduction /
              elementwise merge instead of a shuffle-style segment reduce)
"""
from __future__ import annotations

from .comprehension import (BagGen, BulkStore, BulkUpdate, Cond, Get,
                            RangeGen, ScalarAgg, ScalarAssign, SeqWhile)
from .loop_ast import (Assign, BinOp, Call, Const, DIndex, DVar, Expr, ForIn,
                       ForRange, If, IncUpdate, Index, Program,
                       RejectionError, Stmt, UnOp, Var, While)


class Translator:
    def __init__(self, prog: Program):
        self.prog = prog
        self.fresh = 0

    # ---- rule E: lift an expression to (extra qualifiers, value expr) ----
    def _expr(self, e: Expr, quals: list) -> Expr:
        if isinstance(e, (Var, Const)):
            return e                                     # rules (11a)/(11g)
        if isinstance(e, Index):                         # rule (11c) + §3.6
            idxs = tuple(self._expr(i, quals) for i in e.idxs)
            return Get(e.array, idxs)
        if isinstance(e, BinOp):                         # rule (11d)
            return BinOp(e.op, self._expr(e.lhs, quals),
                         self._expr(e.rhs, quals))
        if isinstance(e, UnOp):
            return UnOp(e.op, self._expr(e.e, quals))
        if isinstance(e, Call):
            return Call(e.fn, tuple(self._expr(a, quals) for a in e.args))
        raise RejectionError(f"untranslatable expression {e}")

    # ---- rules S (15a-h) ----
    def translate_stmt(self, s: Stmt, quals: list) -> list:
        if isinstance(s, IncUpdate):                     # rule (15a)
            q = list(quals)
            val = self._expr(s.value, q)
            if isinstance(s.dest, DVar):                 # rule (16): () key
                return [ScalarAgg(s.dest.name, s.op, val, q)]
            keys = tuple(self._expr(i, q) for i in s.dest.idxs)  # rule K
            return [BulkUpdate(s.dest.array, keys, s.op, val, q)]

        if isinstance(s, Assign):                        # rule (15b)
            q = list(quals)
            val = self._expr(s.value, q)
            if isinstance(s.dest, DVar):
                if any(isinstance(x, (RangeGen, BagGen)) for x in q):
                    raise RejectionError(
                        f"scalar '{s.dest.name}' assigned inside a loop")
                return [ScalarAssign(s.dest.name, val, q)]
            keys = tuple(self._expr(i, q) for i in s.dest.idxs)
            return [BulkStore(s.dest.array, keys, val, q)]

        if isinstance(s, ForRange):                      # rule (15d)
            q = quals + [RangeGen(s.var, s.lo, s.hi)]
            out = []
            for b in s.body:                             # rule (15h) + Thm 3.1
                out += self.translate_stmt(b, q)
            return out

        if isinstance(s, ForIn):                         # rule (15e)
            self.fresh += 1
            idx = s.pats[0] if s.with_index else f"$i{self.fresh}"
            vals = s.pats[1:] if s.with_index else s.pats
            q = quals + [BagGen(idx, tuple(vals), s.bag)]
            out = []
            for b in s.body:
                out += self.translate_stmt(b, q)
            return out

        if isinstance(s, If):                            # rule (15g)
            qc = list(quals)
            c = self._expr(s.cond, qc)
            out = []
            for b in s.then:
                out += self.translate_stmt(b, qc + [Cond(c)])
            for b in s.els:
                out += self.translate_stmt(b, qc + [Cond(UnOp("not", c))])
            return out

        if isinstance(s, While):                         # rule (15f)
            if quals:
                raise RejectionError("while inside for is sequentialized by "
                                     "the paper; rejected here")
            body = []
            for b in s.body:
                body += self.translate_stmt(b, [])
            qc: list = []
            cond = self._expr(s.cond, qc)
            return [SeqWhile(cond, body)]

        raise RejectionError(f"untranslatable statement {s}")

    def translate(self) -> list:
        out = []
        for s in self.prog.body:
            out += self.translate_stmt(s, [])
        return out


def translate(prog: Program) -> list:
    return Translator(prog).translate()

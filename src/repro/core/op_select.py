"""Operator selection: cost-modeled + autotuned backend choice for the
group-by-⊕ hot path (DESIGN.md §8).

The paper's Rule-16 group-by translation is the one operator family where
a single static lowering cannot be "as fast as the hardware allows": the
right materialization of `SegmentReduce` depends on the shape of the
reduction (rows N, segments K, value columns D), the dtype, the platform,
and — distributed — on where the destination lives and how many rows each
shard holds.  This module owns that choice.  Candidate backends:

  scatter   native scatter-⊕ with drop semantics (dest.at[keys].⊕); the
            all-rounder on CPU, serialized per duplicate key on TPU
  sort      sort keys, then jax.ops.segment_⊕ with indices_are_sorted —
            the classic GPU shape; loses on CPU (measured, see
            BENCH_kernels.json)
  onehot    [N, K] one-hot × [N, D] values on the MXU via dot_general —
            group-by as matmul; wins for small K everywhere (measured ~6x
            over scatter at K=16 even on CPU BLAS)
  pallas    the blocked Pallas one-hot-MXU kernel (kernels/segment_reduce)
            — the TPU-native form; interpret-mode (CPU) cost is python-
            level, so the model only picks it on a real TPU backend

plus the distributed-exchange choice for a sharded group-by round
(`psum_scatter` vs allreduce+slice) and the §5 packed-matmul choice
(`pallas-tiled` vs unpack+einsum).

Two modes, one interface:

  cost      (default) an analytical model over shape classes — abstract
            per-element costs per platform, CPU constants calibrated
            against measurement (benchmarks/kernels_bench.py), TPU/GPU
            constants first-principles estimates.  Deterministic: same
            shapes → same decision (golden-testable).
  autotune  measure every candidate once per SHAPE CLASS ((N, K, D)
            bucketed to powers of two, dtype, op, dest sharding) on the
            first encounter, persist the winner to an on-disk cache
            (`.repro_autotune.json` by default) that later sessions — and
            CI — reload, so the timing cost is paid once per class ever.

`force:<backend>` short-circuits both (tests, A/B benchmarks, and the
legacy `use_kernels=True` flag, which maps to `force:pallas`).

Decisions are made at TRACE time — concrete shapes are known there, and a
decision changes only the traced computation, never its result (every
backend implements the same ⊕-merge with paper §3.4 drop semantics).  The
executor records each decision; `explain()`/`explain_rounds()` print it
per node, which is the subsystem's observable contract.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass
from typing import Optional

# candidate sets per monoid ⊕ (correctness, not preference: onehot only
# sums; sort covers every monoid via jax.ops.segment_*; pallas does + via
# the MXU dot and min/max via the one-hot select path)
SEGMENT_CANDIDATES = {
    "+": ("scatter", "sort", "onehot", "pallas"),
    "min": ("scatter", "sort", "pallas"),
    "max": ("scatter", "sort", "pallas"),
    "*": ("scatter", "sort"),
}

EXCHANGE_CANDIDATES = ("psum_scatter", "allreduce")
CONTRACT_CANDIDATES = ("pallas-tiled", "unpack-einsum")

# hot-key salting sub-destination factors (the S in key*S + salt); "none"
# is always a candidate — it is the status quo
SALT_FACTORS = (4, 8, 16)

CACHE_FILE = ".repro_autotune.json"


def _bucket(x: int) -> int:
    """Ceil-log2 shape-class bucket: 1→0, 2→1, 3..4→2, 5..8→3, ..."""
    return max(0, int(x) - 1).bit_length()


@dataclass(frozen=True)
class Decision:
    """One resolved backend choice, with its provenance for explain()."""
    backend: str
    source: str          # "cost" | "autotune" | "cache" | "forced"
    why: str = ""

    def __str__(self) -> str:
        tail = f": {self.why}" if self.why else ""
        return f"{self.backend}[{self.source}{tail}]"


# ---------------------------------------------------------------------------
# the analytical cost model
# ---------------------------------------------------------------------------
# Abstract cost in µs: fixed dispatch overhead + per-element rates.  The
# cpu row is CALIBRATED against measurement on the container (see
# BENCH_kernels.json; scatter ~0.12µs/row, onehot ~0.002µs/cell, argsort
# ~0.05µs/(row·log₂N), Pallas interpret mode is python-level — modeled as
# a prohibitive fixed cost so it is never cost-picked off-TPU).  tpu/gpu
# rows are first-principles estimates (scatter serializes on duplicate
# keys; the MXU streams one-hot cells at matmul rate) — autotune mode
# replaces them with measurement the first time a class is seen on the
# real hardware.

_COSTS = {
    "cpu": dict(fixed=60.0, scatter_row=0.12, sort_row=0.05,
                onehot_cell=0.002, pallas_cell=0.002, pallas_fixed=2e5,
                coll_row=0.004, coll_fixed=400.0, dest_shard_fixed=1500.0,
                tile_mxu=math.inf, einsum_cell=4e-5, unpack_cell=1.5e-3,
                dup_row=0.0, salt_fold=0.004),
    "tpu": dict(fixed=5.0, scatter_row=1.0, sort_row=0.01,
                onehot_cell=2e-4, pallas_cell=1.2e-5, pallas_fixed=30.0,
                coll_row=1e-4, coll_fixed=10.0, dest_shard_fixed=5.0,
                tile_mxu=1.5e-5, einsum_cell=1.5e-5, unpack_cell=2e-4,
                dup_row=1.0, salt_fold=2e-4),
    "gpu": dict(fixed=10.0, scatter_row=0.05, sort_row=0.008,
                onehot_cell=3e-4, pallas_cell=math.inf, pallas_fixed=math.inf,
                coll_row=2e-4, coll_fixed=20.0, dest_shard_fixed=50.0,
                tile_mxu=math.inf, einsum_cell=2e-5, unpack_cell=3e-4,
                dup_row=0.01, salt_fold=3e-4),
}
# dup_row: extra per-row cost when rows COLLIDE on one destination row —
# hardware scatter serializes duplicate-key updates (severe on TPU, atomics
# contend mildly on GPU, the CPU loop is sequential regardless, so 0: cost
# mode never salts on CPU).  salt_fold: per-cell cost of the [K, S] ⊕-fold
# that merges the salted sub-destinations back.


def _segment_cost(c: dict, backend: str, n: int, k: int, d: int) -> float:
    nd = n * max(1, d)
    nkd = n * k * max(1, d)
    if backend == "scatter":
        return c["fixed"] + c["scatter_row"] * nd
    if backend == "sort":
        return c["fixed"] + c["sort_row"] * n * (math.log2(max(2, n)) +
                                                 max(1, d))
    if backend == "onehot":
        return c["fixed"] + c["onehot_cell"] * nkd
    if backend == "pallas":
        return c["pallas_fixed"] + c["pallas_cell"] * nkd
    return math.inf


def probe_hot_fraction(keys, cap: int = 4096) -> float:
    """Run-time skew probe: the fraction of rows held by the most frequent
    key in a host-side prefix sample of the key column (≤ `cap` rows — a
    numpy unique over 4096 int32s is microseconds, paid once per distinct
    (shapes, skew-bucket) signature because the resulting decision is part
    of the compile-cache key).  A prefix sample is exact for the
    distributions that matter here: a hot key that holds ≥ 1/8 of a
    uniformly-ordered stream holds ≈ the same share of any prefix."""
    import numpy as np
    a = np.asarray(keys)[:cap].reshape(-1)
    if a.size == 0:
        return 0.0
    _, counts = np.unique(a, return_counts=True)
    return float(counts.max()) / float(a.size)


def _hot_bucket(hot_frac: float) -> int:
    """Skew bucket for the salt shape class: eighths of the stream held by
    the hottest key (0 = uniform … 8 = single-key)."""
    return max(0, min(8, int(hot_frac * 8.0 + 0.5)))


# ---------------------------------------------------------------------------
# autotune measurement (standalone impls mirroring the executor backends)
# ---------------------------------------------------------------------------

_MEASURE_CELL_CAP = 2e8     # onehot materializes N×K: skip beyond this
_MEASURE_INTERP_CAP = 1e7   # pallas interpret mode is python-level: skip
#                             big classes off-TPU instead of stalling the
#                             first autotuned run for minutes


def _measure_segment(backend: str, n: int, k: int, d: int, op: str,
                     dtype) -> float:
    """µs per call of one backend on synthetic data of the class shape.
    Mirrors the executor's materialization closely enough for ranking."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    cells = n * k * max(1, d)
    if backend == "onehot" and cells > _MEASURE_CELL_CAP:
        return math.inf
    if backend == "pallas" and jax.default_backend() != "tpu" \
            and cells > _MEASURE_INTERP_CAP:
        return math.inf
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    vshape = (n,) if d <= 1 else (n, d)
    vals = jnp.asarray(rng.standard_normal(vshape)).astype(dtype)
    dest = jnp.zeros((k,) if d <= 1 else (k, d), dtype)

    if backend == "scatter":
        from .lower import _scatter_op
        fn = jax.jit(lambda de, i, v: _scatter_op(de.at[i], op)(
            v, mode="drop"))
    elif backend == "sort":
        seg = {"+": jax.ops.segment_sum, "min": jax.ops.segment_min,
               "max": jax.ops.segment_max, "*": jax.ops.segment_prod}[op]

        def fn(de, i, v, _seg=seg, _k=k):
            order = jnp.argsort(i)
            from .lower import COMBINE
            return COMBINE[op](de, _seg(v[order], i[order], num_segments=_k,
                                        indices_are_sorted=True))
        fn = jax.jit(fn)
    elif backend == "onehot":
        def fn(de, i, v, _k=k):
            acc = v.dtype if jnp.issubdtype(v.dtype, jnp.integer) \
                else jnp.float32
            oh = (i[:, None] == jnp.arange(_k)[None, :]).astype(acc)
            v2 = v[:, None] if v.ndim == 1 else v
            part = jax.lax.dot_general(oh, v2.astype(acc),
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=acc)
            part = part[:, 0] if v.ndim == 1 else part
            return de + part.astype(de.dtype)
        fn = jax.jit(fn)
    elif backend == "pallas":
        from ..kernels import ops as kops

        def fn(de, i, v, _k=k):
            from .lower import COMBINE
            return COMBINE[op](de, kops.segment_reduce(i, v, _k, op=op)
                               .astype(de.dtype))
        fn = jax.jit(fn)
    else:
        return math.inf

    try:
        jax.block_until_ready(fn(dest, ids, vals))   # compile
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn(dest, ids, vals)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / reps * 1e6
    except Exception:
        return math.inf          # a candidate that cannot run never wins


# ---------------------------------------------------------------------------
# the selector
# ---------------------------------------------------------------------------

class OpSelector:
    """Resolves backend choices per shape class.  One instance per
    CompiledProgram (shared with its executor); the on-disk cache is
    shared across instances via its path.

    Autotune MEASURES segment classes only (they need no mesh).  The
    exchange / reduce-dest / contract lookups consult the same cache, but
    their entries are supplied externally — hand-written or emitted by
    mesh-owning tooling — as the override channel for platforms where the
    analytical model's ranking is wrong."""

    def __init__(self, mode: str = "cost",
                 cache_path: Optional[str] = CACHE_FILE,
                 platform: Optional[str] = None):
        self.mode = mode
        self.cache_path = cache_path
        self._platform = platform
        self._cache: dict = {}
        self._dirty = False
        if mode.startswith("force:"):
            self.forced: Optional[str] = mode.split(":", 1)[1]
        else:
            self.forced = None
            if mode not in ("cost", "autotune"):
                raise ValueError(f"unknown op_select mode {mode!r}")
        # the cache is the override channel in EVERY mode: autotune writes
        # measured segment classes into it, and hand-/tool-supplied
        # entries (exchange, dest, contract classes) must be honored by
        # cost mode too — a cost-mode lookup hit reports source "cache"
        if cache_path and os.path.exists(cache_path):
            self.load(cache_path)

    # ---- platform / cost table ----
    @property
    def platform(self) -> str:
        if self._platform is None:
            import jax
            self._platform = jax.default_backend()
        return self._platform

    def _costs(self) -> dict:
        return _COSTS.get(self.platform, _COSTS["cpu"])

    # ---- cache ----
    def load(self, path: str) -> None:
        try:
            with open(path) as f:
                blob = json.load(f)
            if blob.get("version") == 1 and \
                    blob.get("platform") == self.platform:
                self._cache.update(blob.get("decisions", {}))
        except (OSError, ValueError):
            pass                 # unreadable cache never breaks execution

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.cache_path
        if not path:
            return
        with open(path, "w") as f:
            json.dump({"version": 1, "platform": self.platform,
                       "decisions": dict(sorted(self._cache.items()))},
                      f, indent=1)
        self._dirty = False

    def _remember(self, key: str, entry: dict) -> None:
        self._cache[key] = entry
        self._dirty = True
        if self.cache_path:
            try:
                self.save()
            except OSError:
                pass             # read-only FS: keep the in-memory decision

    # ---- segment reduce ----
    def segment_class(self, n: int, k: int, d: int, op: str, dtype,
                      dest_dist: str) -> str:
        return (f"segment|{op}|{dtype}|n{_bucket(n)}|k{_bucket(k)}"
                f"|d{_bucket(max(1, d))}|{dest_dist}")

    def choose_segment(self, *, n: int, k: int, d: int, op: str, dtype,
                       dest_dist: str = "REP",
                       candidates: Optional[tuple] = None) -> Decision:
        cands = candidates or SEGMENT_CANDIDATES.get(op, ("scatter",))
        if self.forced is not None and self.forced in cands:
            return Decision(self.forced, "forced")
        # a forced backend the candidate set does not admit (e.g.
        # force:onehot on a min-group-by) falls through to the model —
        # pinning only applies where the pin is correct
        key = self.segment_class(n, k, d, op, str(dtype), dest_dist)
        hit = self._cache.get(key)
        if hit is not None and hit.get("backend") in cands:
            return Decision(hit["backend"], "cache", key)
        if self.mode == "autotune":
            us = {b: _measure_segment(b, n, k, max(1, d), op, dtype)
                  for b in cands}
            best = min(us, key=us.get)
            self._remember(key, {"backend": best, "shape": [n, k, d],
                                 "us": {b: (round(t, 1) if
                                            math.isfinite(t) else None)
                                        for b, t in us.items()}})
            return Decision(best, "autotune", key)
        c = self._costs()
        cost = {b: _segment_cost(c, b, n, k, max(1, d)) for b in cands}
        best = min(cost, key=cost.get)
        return Decision(best, "cost", key)

    # ---- hot-key salting (skew-aware group-by, DESIGN.md §6) ----
    def salt_class(self, n: int, k: int, op: str, nshards: int,
                   hot_frac: float) -> str:
        return (f"salt|{op}|n{_bucket(n)}|k{_bucket(k)}|p{nshards}"
                f"|h{_hot_bucket(hot_frac)}")

    def choose_salt(self, *, n: int, k: int, op: str, nshards: int = 1,
                    hot_frac: float = 0.0) -> Decision:
        """Should this group-by salt its hot keys — spread each key over S
        sub-destinations (`key*S + salt`) and ⊕-fold the [K, S] partial
        back — and at which S?  Salting trades a k·S fold (and k·S partial
        memory) against the duplicate-update serialization a skewed key
        column induces in hardware scatters: a key holding fraction h of n
        rows forces h·n colliding updates on one destination row, and
        salting divides that chain by S.  The class is keyed on the PROBED
        skew bucket (`probe_hot_fraction`), so a cache entry pinned for a
        hot class never fires on uniform data.  The CPU cost row has
        dup_row=0 (the scatter loop is sequential either way), so cost
        mode only ever salts where collisions actually serialize; tests
        and A/B runs pin decisions via `PlanConfig.skew_salting`
        ("force:<S>") or the `SegmentReduce.salt` static hint instead."""
        key = self.salt_class(n, k, op, nshards, hot_frac)
        hit = self._cache.get(key)
        if hit is not None:
            return Decision(hit["backend"], "cache", key)
        # skew guard: a key is only "hot" when it holds several times its
        # fair 1/K share — below that, the collision chain is the inherent
        # n/K every group-by pays, and salting can only add fold cost
        if hot_frac * max(1, k) < 4.0:
            return Decision("none", "cost", key)
        c = self._costs()
        # only EXCESS collisions beyond the balanced chain serialize extra
        dup = c["dup_row"] * max(0.0, hot_frac - 1.0 / max(1, k)) * n
        cost = {"none": dup}
        for s in SALT_FACTORS:
            cost[f"salt:{s}"] = c["fixed"] + dup / s + c["salt_fold"] * k * s
        best = min(cost, key=cost.get)
        return Decision(best, "cost", key)

    # ---- distributed exchange (sharded group-by rounds) ----
    def exchange_class(self, k: int, d: int, op: str, nshards: int,
                       n_local: int) -> str:
        return (f"exchange|{op}|k{_bucket(k)}|d{_bucket(max(1, d))}"
                f"|p{nshards}|n{_bucket(max(1, n_local))}")

    def choose_exchange(self, *, k: int, d: int, op: str, nshards: int,
                        n_local: int = 1, dest_dist: str = "ONED_ROW"
                        ) -> Decision:
        """The cross-shard ⊕ of a dense [K(,D)] partial.  For a REP
        destination (and non-+ monoids, which have no reduce-scatter
        primitive) allreduce is the only candidate.  For a ONED_ROW `+`
        destination the analytical model makes reduce-scatter dominant BY
        CONSTRUCTION — it moves strictly less data than allreduce+slice
        (K·D/P received per shard vs K·D everywhere), so the cost
        comparison can only flip through a CACHE entry: platforms whose
        reduce-scatter lowering underperforms (observed on the XLA host
        backend under some shapes) can pin `allreduce` per exchange class
        in the autotune cache file; `_measure` tooling does not auto-time
        collectives (it has no mesh), so these entries are supplied by
        hand or by mesh-owning benchmarks.  The small-K regime where
        neither exchange pays is handled upstream by
        `choose_reduce_dest` demoting the destination to REP."""
        if self.forced is not None and self.forced in EXCHANGE_CANDIDATES:
            return Decision(self.forced, "forced")
        if dest_dist != "ONED_ROW" or op != "+":
            return Decision("allreduce", "cost",
                            "only candidate for this dest/op")
        key = self.exchange_class(k, d, op, nshards, n_local)
        hit = self._cache.get(key)
        if hit is not None:
            return Decision(hit["backend"], "cache", key)
        return Decision("psum_scatter", "cost", key)

    # ---- reduce-destination placement (sharded group-by rounds) ----
    def dest_class(self, k: int, d: int, op: str, nshards: int) -> str:
        return f"dest|{op}|k{_bucket(k)}|d{_bucket(max(1, d))}|p{nshards}"

    def choose_reduce_dest(self, *, k: int, d: int, op: str, nshards: int,
                           n_local: int = 1) -> Decision:
        """Dense-partial-exchange vs local-scatter-then-psum: should a
        group-by DESTINATION that only ever receives unaligned reduces
        live as ONED_ROW row blocks (partial-⊕ then reduce-scatter; each
        shard keeps K/P rows) or stay REP (partial-⊕ then allreduce)?
        Sharding pays a fixed per-run placement/dispatch overhead for the
        K/P-row layout and wins back K·D·(P-1)/P exchange volume and
        memory — so it loses exactly where the paper's shuffle loses:
        small K.  distributed.py applies the decision only to arrays the
        plan never uses in an aligned round (dist_analysis.
        demotable_dests), so REP here never forfeits an alignment win."""
        if self.forced is not None and self.forced in ("shard", "replicate"):
            return Decision(self.forced, "forced")
        key = self.dest_class(k, d, op, nshards)
        hit = self._cache.get(key)
        if hit is not None:
            return Decision(hit["backend"], "cache", key)
        c = self._costs()
        kd = k * max(1, d)
        shard = c["dest_shard_fixed"] + c["coll_fixed"] + c["coll_row"] * kd
        rep = c["coll_fixed"] + 2.0 * c["coll_row"] * kd
        best = "shard" if shard <= rep else "replicate"
        return Decision(best, "cost", key)

    # ---- §5 packed contraction ----
    def choose_contract(self, *, m: int, k: int, n: int,
                        candidates: tuple = CONTRACT_CANDIDATES) -> Decision:
        """Packed-lhs matmul: the block-sparse Pallas kernel on the tiles
        vs unpacking and contracting on the dense einsum path.  Keyed on
        the dense flop volume; the Pallas rate is the target-hardware MXU
        (∞ off-TPU: interpret mode is python-level)."""
        if self.forced is not None and self.forced in candidates:
            return Decision(self.forced, "forced")
        key = f"contract|m{_bucket(m)}|k{_bucket(k)}|n{_bucket(n)}"
        hit = self._cache.get(key)
        if hit is not None and hit.get("backend") in candidates:
            return Decision(hit["backend"], "cache", key)
        c = self._costs()
        flops = m * k * n
        pallas = c["tile_mxu"] * flops
        einsum = c["einsum_cell"] * flops + c["unpack_cell"] * m * k
        best = "pallas-tiled" if pallas <= einsum else "unpack-einsum"
        return Decision(best, "cost", key)

"""Dependence analysis and parallelization restrictions (paper §3.2,
Definition 3.1).

For every statement in a for-loop nest we compute readers R[s], writers
W[s] and aggregators A[s] (L-values), the context (enclosing loop indexes)
and affinity of destinations, then check:

  1. every non-incremental update destination is affine (different location
     at each iteration, covering all loop indexes in context);
  2. no (writer|aggregator, reader) overlap, except
     (a) same L-value, writer precedes (or equals) the reader statement;
     (b) aggregate-then-read of the same L-value, read destination affine,
         and context(s1) ∩ context(s2) == indexes(d1).

Two hardenings beyond the paper's letter (gaps in Def. 3.1 that break
Theorem 3.1; documented in DESIGN.md):
  * overlapping aggregator destinations must use the SAME ⊕ monoid;
  * write/aggregate overlap on one array is only allowed in the matmul
    shape: identical L-value, writer first, writer affine, and
    context(writer) ∩ context(agg) == indexes(d).

For-loops containing while-loops: the paper sequentializes them; we reject
with a diagnostic (none of the paper's benchmarks need it).

Scope note: this module decides WHETHER a loop parallelizes (AST-level,
reject-or-accept).  The complementary question of WHERE each array lives
on a device mesh — replicated or partitioned — is answered later, over
the finished physical plan, by dist_analysis.py (DESIGN.md §6); that
analysis never rejects, it only meets distributions down to REP.
"""
from __future__ import annotations

from dataclasses import dataclass

from .loop_ast import (Assign, BinOp, Call, Const, Dest, DIndex, DVar, Expr,
                       ForIn, ForRange, If, IncUpdate, Index, Program,
                       RejectionError, Stmt, UnOp, Var, While)


# ---------------------------------------------------------------------------
# expression helpers
# ---------------------------------------------------------------------------

def expr_reads(e: Expr, acc: list):
    """Collect read L-values: array accesses and scalar variables."""
    if isinstance(e, Index):
        acc.append(("arr", e.array, e.idxs))
        for i in e.idxs:
            expr_reads(i, acc)
    elif isinstance(e, Var):
        acc.append(("var", e.name, ()))
    elif isinstance(e, BinOp):
        expr_reads(e.lhs, acc)
        expr_reads(e.rhs, acc)
    elif isinstance(e, UnOp):
        expr_reads(e.e, acc)
    elif isinstance(e, Call):
        for a in e.args:
            expr_reads(a, acc)


def expr_vars(e: Expr) -> set[str]:
    acc: set[str] = set()

    def go(x):
        if isinstance(x, Var):
            acc.add(x.name)
        elif isinstance(x, Index):
            for i in x.idxs:
                go(i)
        elif isinstance(x, BinOp):
            go(x.lhs)
            go(x.rhs)
        elif isinstance(x, UnOp):
            go(x.e)
        elif isinstance(x, Call):
            for a in x.args:
                go(a)
    go(e)
    return acc


def is_affine_expr(e: Expr, affine_vars: set[str]) -> bool:
    """c0 + c1*i1 + ... over loop-index vars (paper's affine expressions)."""
    if isinstance(e, Const):
        return isinstance(e.value, (int, float))
    if isinstance(e, Var):
        return e.name in affine_vars
    if isinstance(e, UnOp) and e.op == "neg":
        return is_affine_expr(e.e, affine_vars)
    if isinstance(e, BinOp):
        if e.op in ("+", "-"):
            return is_affine_expr(e.lhs, affine_vars) and \
                is_affine_expr(e.rhs, affine_vars)
        if e.op == "*":
            lc = isinstance(e.lhs, Const)
            rc = isinstance(e.rhs, Const)
            return (lc and is_affine_expr(e.rhs, affine_vars)) or \
                   (rc and is_affine_expr(e.lhs, affine_vars))
    return False


def dest_key(d: Dest):
    return d.name if isinstance(d, DVar) else d.array


def dest_equal(d1: Dest, d2) -> bool:
    """Syntactic equality between a destination and a read L-value tuple."""
    if isinstance(d1, DVar):
        return d2[0] == "var" and d2[1] == d1.name
    return d2[0] == "arr" and d2[1] == d1.array and d2[2] == d1.idxs


@dataclass
class UpdateInfo:
    stmt: Stmt
    order: int
    context: tuple[str, ...]       # enclosing loop index tokens
    affine_vars: set[str]          # loop index vars usable in affine exprs
    reads: list                    # [(kind, name, idxs)]


def _collect(stmts, ctx, affine_vars, out, order, loop_id=[0]):
    for s in stmts:
        if isinstance(s, (Assign, IncUpdate)):
            reads: list = []
            expr_reads(s.value, reads)
            if isinstance(s.dest, DIndex):
                for i in s.dest.idxs:
                    expr_reads(i, reads)
            out.append(UpdateInfo(s, order[0], ctx, set(affine_vars), reads))
            order[0] += 1
        elif isinstance(s, ForRange):
            _collect(s.body, ctx + (s.var,), affine_vars | {s.var}, out, order)
        elif isinstance(s, ForIn):
            loop_id[0] += 1
            idx = s.pats[0] if s.with_index else f"$i{loop_id[0]}"
            val_pats = s.pats[1:] if s.with_index else s.pats
            # value pattern vars are NOT affine indexes (non-injective)
            _collect(s.body, ctx + (idx,), affine_vars | {idx}, out, order)
        elif isinstance(s, If):
            # condition reads participate as readers of a pseudo-statement
            reads = []
            expr_reads(s.cond, reads)
            out.append(UpdateInfo(s, order[0], ctx, set(affine_vars), reads))
            order[0] += 1
            _collect(s.then, ctx, affine_vars, out, order)
            _collect(s.els, ctx, affine_vars, out, order)
        elif isinstance(s, While):
            raise RejectionError(
                "while-loop inside a for-loop: the paper sequentializes this "
                "case; unsupported here (rejected)")


def _affine_dest(d: Dest, u: UpdateInfo) -> bool:
    if isinstance(d, DVar):
        return len(u.context) == 0
    if not all(is_affine_expr(i, u.affine_vars) for i in d.idxs):
        return False
    used = set()
    for i in d.idxs:
        used |= expr_vars(i) & u.affine_vars
    return set(u.context) <= used


def _indexes(d: Dest, u: UpdateInfo) -> set[str]:
    if isinstance(d, DVar):
        return set()
    used = set()
    for i in d.idxs:
        used |= expr_vars(i) & set(u.context)
    return used


def check_loop(loop: Stmt):
    """Check Def. 3.1 for one outermost for-loop."""
    out: list[UpdateInfo] = []
    if isinstance(loop, ForRange):
        _collect(loop.body, (loop.var,), {loop.var}, out, [0])
    else:
        assert isinstance(loop, ForIn)
        idx = loop.pats[0] if loop.with_index else "$i0"
        _collect(loop.body, (idx,), {idx}, out, [0])

    updates = [u for u in out if isinstance(u.stmt, (Assign, IncUpdate))]

    # Restriction 1: non-incremental destinations must be affine
    for u in updates:
        if isinstance(u.stmt, Assign) and not _affine_dest(u.stmt.dest, u):
            raise RejectionError(
                f"non-affine destination in '{type(u.stmt).__name__}' of "
                f"{dest_key(u.stmt.dest)}: destination must be a distinct "
                f"location covering loop indexes {u.context} "
                f"(paper §3.2 Restriction 1)")

    # Restriction 2 with exceptions (a), (b)
    for u1 in updates:
        d1 = u1.stmt.dest
        is_agg = isinstance(u1.stmt, IncUpdate)
        for u2 in out:
            for r in u2.reads:
                if r[1] != dest_key(d1):
                    continue
                if (r[0] == "var") != isinstance(d1, DVar):
                    continue
                # overlap found: try exceptions
                if not is_agg:
                    # (a): same L-value, write precedes (or is) the read
                    if dest_equal(d1, r) and u1.order <= u2.order:
                        continue
                else:
                    # (b): aggregate-then-read same L-value, read dest
                    # affine, context(s1) ∩ context(s2) == indexes(d1)
                    rd = DVar(r[1]) if r[0] == "var" else DIndex(r[1], r[2])
                    if dest_equal(d1, r) and u1.order < u2.order and \
                            _affine_dest(rd, u2) and \
                            set(u1.context) & set(u2.context) == \
                            _indexes(d1, u1):
                        continue
                raise RejectionError(
                    f"recurrence: '{dest_key(d1)}' is "
                    f"{'aggregated' if is_agg else 'written'} and read in "
                    f"the same loop without a Def-3.1 exception "
                    f"(paper §3.2 Restriction 2)")

    # Hardening 1: overlapping aggregator destinations need one monoid
    ops: dict[str, set[str]] = {}
    for u in updates:
        if isinstance(u.stmt, IncUpdate):
            ops.setdefault(dest_key(u.stmt.dest), set()).add(u.stmt.op)
    for name, s in ops.items():
        if len(s) > 1:
            raise RejectionError(
                f"mixed ⊕ monoids {sorted(s)} aggregate into '{name}' in one "
                f"loop — loop splitting would reorder them (hardening of "
                f"Def. 3.1, see DESIGN.md)")

    # Hardening 3: two writers into one array must use IDENTICAL index
    # expressions.  The paper's Def. 3.1 only restricts (writer, reader)
    # pairs; `for i {D[i]:=i; D[i+1]:=i}` passes its letter but loop
    # splitting changes the result (found by hypothesis fuzzing — see
    # EXPERIMENTS.md §Paper-gaps).
    writers: dict[str, list[UpdateInfo]] = {}
    for u in updates:
        if isinstance(u.stmt, Assign) and isinstance(u.stmt.dest, DIndex):
            writers.setdefault(u.stmt.dest.array, []).append(u)
    for name, us in writers.items():
        idxs = {u.stmt.dest.idxs for u in us}
        if len(idxs) > 1:
            raise RejectionError(
                f"two non-incremental writes into '{name}' with different "
                f"index maps in one loop — loop splitting would reorder "
                f"them (hardening 3 of Def. 3.1, see DESIGN.md)")

    # Hardening 2: write/aggregate overlap only in the matmul shape
    for u1 in updates:
        if not isinstance(u1.stmt, Assign):
            continue
        d1 = u1.stmt.dest
        for u2 in updates:
            if not isinstance(u2.stmt, IncUpdate):
                continue
            d2 = u2.stmt.dest
            if dest_key(d1) != dest_key(d2):
                continue
            ok = (isinstance(d1, DIndex) == isinstance(d2, DIndex)
                  and u1.order < u2.order
                  and _affine_dest(d1, u1)
                  and set(u1.context) & set(u2.context) == _indexes(d1, u1)
                  and (isinstance(d1, DVar) or d1.idxs == d2.idxs))
            if not ok:
                raise RejectionError(
                    f"write+aggregate of '{dest_key(d1)}' in one loop outside "
                    f"the init-then-accumulate shape (hardening of Def. 3.1)")


def check(prog: Program):
    """Check all outermost for-loops of the program (statements outside
    loops are sequential glue and always fine)."""
    def walk(stmts):
        for s in stmts:
            if isinstance(s, (ForRange, ForIn)):
                check_loop(s)
            elif isinstance(s, While):
                walk(s.body)
            elif isinstance(s, If):
                walk(s.then)
                walk(s.els)
    walk(prog.body)
    return True

# DIABLO-JAX: the paper's primary contribution — translation of array-based
# loops to distributed data-parallel programs — retargeted from Spark to JAX.
#
# Pipeline: @loop_program (Python-source frontend, paper Fig. 1 language)
#   → analysis.check (Def. 3.1 restrictions)
#   → translate (Fig. 2 rules E/K/D/U/S + Rule 2 unnesting)
#   → passes.plan_program (optimizer pipeline → physical-plan IR, plan.py:
#     Rules 16/17, einsum recognition, §5 tiled fusion, DSE, update fusion,
#     distribution analysis: dist_analysis.py infers a per-array sharding
#     REP ≤ ONED_VAR ≤ ONED_ROW ≤ TWOD_BLOCK — ONED_VAR marks bag-derived/
#     filtered arrays with variable live blocks, rebalanced to ONED_ROW
#     only where readers need it — printed by CompiledProgram.explain())
#   → lower.PlanExecutor (plan nodes → JAX, runtime guards + fallbacks)
#   → distributed (shard_map / gspmd execution of the same plan over a mesh;
#     bags AND inferred-ONED_ROW dense arrays shard as row blocks)
from .analysis import check
from .chunked import ChunkLoop, ChunkRunner, chunk_plan, choose_chunk_rows
from .frontend import (bag, dim, intscalar, loop_program, map_, matrix,
                       parse_program, scalar, vector)
from .interp import run as interpret
from .loop_ast import Program, RejectionError
from .lower import CompiledProgram, PlanExecutor, compile_program
from .memest import MemEstimate, estimate, shape_env, shape_env_from_signature
from .passes import PlanConfig, plan_program
from .translate import translate

__all__ = ["loop_program", "parse_program", "compile_program", "interpret",
           "check", "translate", "CompiledProgram", "PlanExecutor",
           "PlanConfig", "plan_program", "Program",
           "RejectionError", "vector", "matrix", "map_", "bag", "dim",
           "scalar", "intscalar",
           "MemEstimate", "estimate", "shape_env", "shape_env_from_signature",
           "ChunkLoop", "ChunkRunner", "chunk_plan", "choose_chunk_rows"]

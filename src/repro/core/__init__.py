# DIABLO-JAX: the paper's primary contribution — translation of array-based
# loops to distributed data-parallel programs — retargeted from Spark to JAX.
#
# Pipeline: @loop_program (Python-source frontend, paper Fig. 1 language)
#   → analysis.check (Def. 3.1 restrictions)
#   → translate (Fig. 2 rules E/K/D/U/S + Rule 2 unnesting + Rules 16/17)
#   → lower (gather / segment-⊕ / axis-reduce / einsum physical plans)
#   → distributed (shard_map execution over a device mesh)
from .analysis import check
from .frontend import (bag, dim, intscalar, loop_program, map_, matrix,
                       parse_program, scalar, vector)
from .interp import run as interpret
from .loop_ast import Program, RejectionError
from .lower import CompiledProgram, compile_program
from .translate import translate

__all__ = ["loop_program", "parse_program", "compile_program", "interpret",
           "check", "translate", "CompiledProgram", "Program",
           "RejectionError", "vector", "matrix", "map_", "bag", "dim",
           "scalar", "intscalar"]

"""Packed (tiled) matrices — paper §5.

A TiledMatrix stores MXU-aligned [bm, bn] dense tiles plus a tile-presence
mask.  `pack`/`unpack` are the paper's conversion comprehensions; the
compiler FUSES them away: when a tiled matrix flows into the matmul-shaped
contraction the einsum recognizer emits the block-sparse Pallas
`tile_matmul` directly on the packed representation (no unpack), which is
the §5 claim ("programs directly access the packed structures").  Any other
access unpacks on the fly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TiledMatrix:
    tiles: jax.Array       # [Mt, Nt, bm, bn]
    mask: jax.Array        # [Mt, Nt] (1 = tile present)
    shape: tuple[int, int]  # logical (un-padded) shape

    @property
    def tile_shape(self):
        return self.tiles.shape[2], self.tiles.shape[3]


def pack(m: jax.Array, bm: int = 128, bn: int = 128,
         prune_zero: bool = True) -> TiledMatrix:
    """Dense/sparse matrix -> tiles (paper's pack(M) comprehension)."""
    h, w = m.shape
    hp, wp = -(-h // bm) * bm, -(-w // bn) * bn
    mp = jnp.zeros((hp, wp), m.dtype).at[:h, :w].set(m)
    tiles = mp.reshape(hp // bm, bm, wp // bn, bn).transpose(0, 2, 1, 3)
    if prune_zero:
        mask = (jnp.abs(tiles).sum(axis=(2, 3)) > 0).astype(jnp.float32)
    else:
        mask = jnp.ones(tiles.shape[:2], jnp.float32)
    return TiledMatrix(tiles, mask, (h, w))


def unpack(t: TiledMatrix) -> jax.Array:
    """Tiles -> dense matrix (paper's unpack(N) comprehension)."""
    mt, nt, bm, bn = t.tiles.shape
    tiles = t.tiles * t.mask[:, :, None, None].astype(t.tiles.dtype)
    full = tiles.transpose(0, 2, 1, 3).reshape(mt * bm, nt * bn)
    return full[:t.shape[0], :t.shape[1]]


def matmul_tiled(a: TiledMatrix, b, *, interpret=None) -> jax.Array:
    """Block-sparse matmul on the packed representation via the Pallas
    tile_matmul kernel (mask skips absent tiles)."""
    from ..kernels import ops
    bm, bk = a.tile_shape
    bdense = unpack(b) if isinstance(b, TiledMatrix) else b
    mt, kt, _, _ = a.tiles.shape
    a_dense = a.tiles.transpose(0, 2, 1, 3).reshape(mt * bm, kt * bk)
    kw = {} if interpret is None else {"interpret": interpret}
    kp = a_dense.shape[1]
    b_p = jnp.zeros((kp, bdense.shape[1]), bdense.dtype) \
        .at[:bdense.shape[0]].set(bdense)
    out = ops.tile_matmul(a_dense, b_p, tile_mask=a.mask, bm=bm, bk=bk, **kw)
    return out[:a.shape[0]]

"""Distributed execution of compiled loop programs over a device mesh —
the paper's DISC backend, retargeted from Spark shuffles to TPU collectives
(DESIGN.md §4, §6).

Both modes consume the SAME physical plan (CompiledProgram.plan) through
the public executor interface; bag offsets/limits and the dense-array
analogues (row offsets, logical row limits, axis overrides) are plan
parameters (lower.ExecContext), not lowerer state.

* ``shardmap`` (paper-faithful operator mapping): bags shard over the dp
  axes, and — per the distribution-analysis pass (dist_analysis.py) —
  dense arrays inferred ONED_ROW/TWOD_BLOCK shard as contiguous dim-0 row
  blocks too, instead of replicating.  Each plan node runs as one of:

    aligned store round    MapExpr/Scatter whose leading destination key IS
                           the round axis: every shard writes only its own
                           row block; no collective at all.
    aligned reduce round   AxisReduce/EinsumContract/TiledMatmul keyed by
                           the round axis: local partial-⊕ into the local
                           block; no collective.
    unaligned reduce round local partial-⊕ into a dense [K(, D)] partial
                           BEFORE any exchange, then `psum` (REP
                           destination), or — per the operator-selection
                           subsystem (op_select.py, DESIGN.md §8) —
                           `psum_scatter` / allreduce+slice (ONED_ROW
                           destination), the decision keyed on (K, D, ⊕,
                           shard count, shard-local rows).  The partial
                           itself is computed by whichever SegmentReduce
                           backend the selector picks for the SHARD-LOCAL
                           (N/P, K) shape class.  This is the
                           reduction-based replacement for the paper's
                           shuffle-based group-by.  When the trace-time
                           hot-key probe (or a static hint) salts the
                           group-by (DESIGN.md §6), the shard-local
                           partial is computed over key*S+salt
                           sub-destinations and ⊕-folded back to [K]
                           BEFORE the exchange — the wire format never
                           changes.
    rebalance round        plan.Rebalance (ONED_VAR → ONED_ROW): per-shard
                           live-row counts exchange via psum, exclusive
                           cumsum assigns every live row its global slot,
                           and one psum_scatter all-to-all restores equal
                           blocks — exact (pure data movement, no ⊕).
                           Elided when the array is already balanced or
                           replicated; explain_rounds() prints the
                           per-shard counts and balance factor either way.
    replicated             everything else — identical on all shards; also
                           the guaranteed fallback whenever a runtime shape
                           guard fails.  Correct regardless of placement:
                           outside shard_map the env holds global arrays
                           and XLA resharding is transparent.

  Reads inside a round localize when the analysis proved them aligned with
  the round axis (the shard's row block serves every access); otherwise a
  ONED_ROW operand is `all_gather`ed on entry — the only place a gather
  collective is ever inserted, exactly where the analysis says a read
  crosses shards.  A `Fused` node still runs all its parts in ONE
  shard_map round (mixed aligned/unaligned parts allowed).

  Inside an aligned reduce round the executor keeps the MXU contraction
  path PER SHARD: aligned operands are their local blocks (slice at local
  0), replicated ones a bounds-certified lax.dynamic_slice window — the
  certificates come from the distribution analysis
  (dist_analysis.shard_slice_certificates) plus the padded-extent bound in
  ExecContext.axis_overrides, so a dynamic slice is only ever emitted when
  it provably cannot clamp.  `explain_rounds()` prints, per node, the
  round strategy, the slice certificates, and the per-shard operator the
  executor actually traced (e.g. ``mxu-einsum`` vs ``fallback:dense-grid``
  — the observable contract that generated rounds run jnp.einsum, not the
  dense iteration grid).

  Round fusion (pass 11, DESIGN.md §9): a `plan.FusedRound` region runs
  as ONE jit+shard_map program — members execute sequentially inside the
  traced body with their collectives (psum / psum_scatter / all_gather)
  placed between them, instead of one dispatch per node.  A SeqLoop whose
  whole body is one region runs as an ON-DEVICE lax.while_loop inside
  that same program whenever its condition reads only replicated state —
  zero per-iteration host syncs (the host-driven loop with one blocking
  condition sync per iteration remains the fallback, and a
  fully-replicated body short-circuits through the single-device
  lax.while_loop).  Guard failures fall back to per-member rounds;
  fusion never changes results, only dispatch.

* ``gspmd``: the single-device plan executed on sharded inputs; XLA's
  SPMD partitioner inserts the collectives.  Works for every program,
  including range-driven contractions (matmul → partitioned einsum).

Bags AND ONED_ROW dense arrays whose dim-0 length is not divisible by the
shard count are PADDED with zero rows to the next multiple; the original
length travels as a bag limit / array limit and the executor masks reads
and drops writes beyond it, so padding can never change a result (the
paper's §3.4 empty-bag semantics are enforced against the LOGICAL bound).
Padded outputs are sliced back to their logical length on return.

`shard_dense=False` (or `PlanConfig.infer_distributions=False`) restores
REP-everything — the pre-analysis behaviour and the ⊥ of the lattice.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from . import faults as F
from . import plan
from .dist_analysis import (Dist, aligned_reads, leading_key_var,
                            round_axis, shard_slice_certificates)
from .lower import (COMBINE, CompiledProgram, ExecContext, identity,
                    salt_for_node)

_STORE_NODES = (plan.MapExpr, plan.Scatter)
_ALIGNABLE_REDUCES = (plan.AxisReduce, plan.EinsumContract, plan.TiledMatmul)


class DistributedProgram:
    def __init__(self, cp: CompiledProgram, mesh, dp_axes=("data",),
                 mode: str = "shardmap", shard_dense: bool = True):
        self.cp = cp
        self.mesh = mesh
        self.dp = tuple(dp_axes)
        self.mode = mode
        self.dp_n = 1
        for a in self.dp:
            self.dp_n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        # placement = inferred distribution, capped at ONED_ROW: TWOD_BLOCK
        # records 2-D legality but both executors place row blocks today
        self.dists = dict(cp.dists) if shard_dense else \
            {a: Dist.REP for a in cp.dists}
        self.placements = {a: min(d, Dist.ONED_ROW)
                           for a, d in self.dists.items()}
        # arrays the plan only ever touches as unaligned reduce dests /
        # cross-shard reads: place() may demote them to REP per run when
        # the op_select cost model says a sharded destination doesn't pay
        # for their concrete size (dense-partial + reduce-scatter vs
        # local-scatter + psum, DESIGN.md §8).  Placement-only: results
        # never change, and arrays with any aligned use are never touched.
        from .dist_analysis import demotable_dests
        self._demotable = demotable_dests(cp.plan, cp.program) \
            if shard_dense else {}
        self._base_placements = dict(self.placements)
        self._demoted: dict = {}        # name → Decision, per run
        # compiled shard_map round per (node, strategy, static params):
        # SeqLoop iterations and repeated run() calls reuse the traced
        # round instead of paying trace+compile every time.  Fused regions
        # (plan.FusedRound, pass 11) share the cache; the trace/hit
        # counters are the compile-cache observability explain_rounds()
        # reports (DESIGN.md §9)
        self._round_cache: dict = {}
        self._round_traces = 0
        self._round_hits = 0
        # region ids whose fused execution failed a runtime guard THIS run
        # (per-member fallback taken): don't re-attempt every loop iteration
        self._fused_bail: set = set()
        # id(node) → human-readable round strategy of the LAST run(), and
        # id(leaf) → the per-shard materialization that round used.  Both
        # refreshed on every node execution — cache-hit rounds restore the
        # snapshot taken when their round was traced (_round_notes), so
        # explain_rounds() stays accurate even when classification changed
        # between runs or a single-device run touched the shared executor
        # in between.
        self._strategy: dict = {}
        self._decisions: dict = {}
        self._strategy_by_key: dict = {}
        self._round_notes: dict = {}
        # env-independent node facts (round axis, aligned reads, gather
        # names): expression trees are walked once per node, not once per
        # SeqLoop iteration
        self._static_cache: dict = {}
        # skew observability (explain_rounds "balance:" lines): per-run
        # per-shard live row counts + max/mean factor for every ONED_VAR /
        # rebalanced array, and the analysis' insert-vs-elide decision
        self._rebalanced = frozenset(
            n.dest for n in _walk_plan(cp.plan)
            if isinstance(n, plan.Rebalance))
        self._balance: dict = {}
        # failure policy (DESIGN.md §11): the ledger and retry policy are
        # SHARED with the wrapped CompiledProgram — one ladder per program,
        # whichever layer descends it.  _force_rep is the REP-everything
        # ladder level: place() replicates every dense array (the ⊥ of the
        # distribution lattice, same as shard_dense=False) for one run.
        self.faults = cp.faults
        self.policy = cp.policy
        self._force_rep = False
        # ---- surgical recovery (DESIGN.md §13) ----
        # shard index → faults.clock() time of its LAST loss: a second
        # loss of the same shard inside policy.shard_loss_ttl_s means the
        # worker is flapping — escalate to the ladder instead of
        # recomputing onto a corpse again.  Deliberately NOT reset per
        # run: flapping spans runs.
        self._shard_loss: dict = {}
        self.lineage_enabled = getattr(cp.config, "lineage", True)
        # straggler speculation: ≤1 backup execution per straggling round
        # label per run (first finisher wins, loser cancelled)
        self.speculative = getattr(cp.config, "speculative", True)
        self._spec_done: set = set()

    def _placed_oned(self, name) -> bool:
        # ONED_VAR counts: variable-length arrays still shard as equal
        # physical row blocks — only their LOGICAL live lengths differ
        # (tracked by the array limit and masked like every padded array)
        return self.placements.get(name, Dist.REP) >= Dist.ONED_VAR

    # ------------------------- input placement -------------------------
    def place(self, inputs: dict):
        """Shard bags and ONED_ROW dense arrays over dp (padding dim 0 with
        zero rows to a multiple of the shard count), replicate the rest.
        Returns (placed, bag_limits, array_limits); the limit dicts map
        each padded name to its logical dim-0 length — consumers MUST mask
        rows beyond the limit (run() threads them through ExecContext)."""
        out = {}
        bag_limits: dict[str, int] = {}
        array_limits: dict[str, int] = {}
        # per-run placement decision for demotion-neutral reduce dests
        # (shapes are known here): shard vs replicate is an op_select call
        self.placements = dict(self._base_placements)
        self._demoted = {}
        if self._force_rep:
            # REP-everything ladder level: every dense array replicates
            # (bags still shard — they are the iteration space); the
            # demotion loop below is vacuous since nothing is placed ONED
            self.placements = {a: Dist.REP for a in self.placements}
        import numpy as _np
        for name, t in self.cp.program.params.items():
            if t.kind not in ("vector", "matrix", "map") \
                    or name not in self._demotable \
                    or not self._placed_oned(name):
                continue
            shp = _np.shape(inputs[name])
            if not shp:
                continue
            d_rest = 1
            for d_ in shp[1:]:
                d_rest *= int(d_)
            dec = self.cp.selector.choose_reduce_dest(
                k=int(shp[0]), d=d_rest, op=self._demotable[name],
                nshards=self.dp_n)
            if dec.backend == "replicate":
                self.placements[name] = Dist.REP
                self._demoted[name] = dec
        for name, t in self.cp.program.params.items():
            v = inputs[name]
            if t.kind == "bag":
                cols = v if isinstance(v, tuple) else (v,)
                cols = tuple(jnp.asarray(c) for c in cols)
                n = int(cols[0].shape[0])
                pad = (-n) % self.dp_n
                if pad:
                    cols = tuple(jnp.concatenate(
                        [c, jnp.zeros((pad,) + c.shape[1:], c.dtype)])
                        for c in cols)
                    bag_limits[name] = n
                out[name] = tuple(
                    jax.device_put(c, NamedSharding(self.mesh, P(self.dp)))
                    for c in cols)
            elif t.kind == "dim":
                out[name] = int(v)
            elif t.kind in ("vector", "matrix", "map") \
                    and self._placed_oned(name):
                arr = jnp.asarray(v)
                n = int(arr.shape[0])
                pad = (-n) % self.dp_n
                if pad:
                    arr = jnp.concatenate(
                        [arr, jnp.zeros((pad,) + arr.shape[1:], arr.dtype)])
                    array_limits[name] = n
                out[name] = jax.device_put(
                    arr, NamedSharding(self.mesh, P(self.dp)))  # row blocks
            else:
                arr = jnp.asarray(v)
                out[name] = jax.device_put(
                    arr, NamedSharding(self.mesh, P()))  # broadcast join
        return out, bag_limits, array_limits

    # ------------------------- shardmap mode -------------------------
    def _psum(self, part, op: str):
        if op == "+":
            return jax.lax.psum(part, self.dp)
        if op == "min":
            return -jax.lax.pmax(-part, self.dp)
        if op == "max":
            return jax.lax.pmax(part, self.dp)
        raise NotImplementedError(op)

    def _combine_shard(self, part, op: str, shard, dest_oned: bool,
                       exchange: str = "psum_scatter"):
        """Cross-shard ⊕ of an unaligned partial: psum for a replicated
        destination; for a row-block destination the exchange the
        operator-selection subsystem chose — reduce-scatter (each shard
        receives its K/P rows) or allreduce + local slice (the only
        correct form for non-+ monoids, which have no reduce-scatter
        primitive)."""
        F.site("dist.exchange", op=op, dest_oned=dest_oned,
               exchange=exchange)
        if not dest_oned:
            return self._psum(part, op)
        if op == "+" and exchange == "psum_scatter":
            return jax.lax.psum_scatter(part, self.dp, scatter_dimension=0,
                                        tiled=True)
        full = self._psum(part, op)
        blk = full.shape[0] // self.dp_n
        return jax.lax.dynamic_slice_in_dim(full, shard * blk, blk, axis=0)

    # ------------------- rebalance rounds (ONED_VAR → ONED_ROW) ----------
    def _rebalance_local(self, x, shard, lim):
        """The rebalance round body, inside a shard_map trace: per-shard
        size exchange (one-hot `psum` of live-row counts), exclusive-cumsum
        global offsets, scatter of live rows to their balanced global
        positions, then a `psum_scatter` redistribution back to equal row
        blocks.  Each target position receives exactly ONE nonzero addend
        (every other shard contributes the zero buffer row), so the
        composition is an exact all-to-all, not an approximate reduction —
        bit-identical results on canonical front-packed layouts."""
        blk = x.shape[0]
        npad = blk * self.dp_n
        rows = shard * blk + jnp.arange(blk)
        live = rows < lim
        cnt = jnp.sum(live.astype(jnp.int32))
        # size exchange: every shard learns every live count
        counts = jax.lax.psum(
            jnp.where(jnp.arange(self.dp_n) == shard, cnt, 0), self.dp)
        start = (jnp.cumsum(counts) - counts)[shard]   # exclusive cumsum
        pos = start + jnp.cumsum(live.astype(jnp.int32)) - 1
        pos = jnp.where(live, pos, npad)               # dead rows drop
        buf = jnp.zeros((npad,) + tuple(x.shape[1:]), x.dtype)
        buf = buf.at[pos].add(x, mode="drop")
        return jax.lax.psum_scatter(buf, self.dp, scatter_dimension=0,
                                    tiled=True)

    def _shard_counts(self, npad: int, lim):
        """Host-side mirror of the size exchange (for observability): the
        logical live row count each shard holds under the canonical
        front-packed layout, plus the max/mean balance factor."""
        blk = npad // self.dp_n
        if lim is None:
            lim = npad
        counts = [max(0, min(blk, lim - s * blk)) for s in range(self.dp_n)]
        mean = sum(counts) / len(counts)
        factor = (max(counts) / mean) if mean else float("inf")
        return counts, factor

    def _exec_rebalance(self, node, env, array_limits):
        """Run a plan.Rebalance as its own cached jit+shard_map round (the
        fused-region path inlines `_rebalance_local` instead).  Elided —
        with an explain_rounds note — when the destination is replicated
        (nothing to balance) or carries no limit (blocks already equal)."""
        dest = node.dest
        if not self._placed_oned(dest):
            self._strategy[id(node)] = "rebalance: elided (replicated dest)"
            return
        v = jnp.asarray(env[dest])
        npad = int(v.shape[0])
        blk = npad // self.dp_n
        lim = array_limits.get(dest)
        if lim is None:
            self._strategy[id(node)] = (
                f"rebalance: elided (already balanced, {blk} rows × "
                f"{self.dp_n} shards)")
            return
        cache_key = ("rebalance", id(node), tuple(v.shape), str(v.dtype),
                     lim)
        fn = self._round_cache.get(cache_key)
        if fn is None:
            def local_fn(x, _lim=lim):
                shard = 0
                for a in self.dp:
                    shard = shard * self.mesh.shape[a] + \
                        jax.lax.axis_index(a)
                return self._rebalance_local(x, shard, _lim)
            fn = jax.jit(shard_map(local_fn, mesh=self.mesh,
                                   in_specs=(P(self.dp),),
                                   out_specs=P(self.dp)))
            self._round_cache[cache_key] = fn
            self._round_traces += 1
        else:
            self._round_hits += 1
        prev = env[dest]
        env[dest] = fn(prev)
        counts, factor = self._shard_counts(npad, lim)
        self._strategy[id(node)] = (
            f"rebalance(size-exchange psum + all-to-all psum_scatter)"
            f"→{dest}; rows/shard={counts} balance={factor:.2f}")
        self._shard_lost_site(
            node, "rebalance", env, [(dest, "rebalance")], {dest: prev},
            lambda _fn=fn, _p=prev: {dest: _fn(_p)},
            unit="rebalance round")

    # ---- per-node round classification (runtime shape guards) ----
    def _rows(self, name, env) -> int:
        v = env[name]
        col = v[0] if isinstance(v, tuple) else v
        return int(jnp.shape(col)[0])

    def _round_spec(self, node, env):
        """Decide how to run `node`: None = replicated; else a dict with
        the round axis, per-part kinds (store / aligned / reduce) and the
        read classification (localize vs all_gather).  Every guard failure
        degrades to a coarser-but-correct strategy, never to an error."""
        parts = list(node.parts) if isinstance(node, plan.Fused) else [node]
        dests_set = {p.dest for p in parts}
        space = node.space
        static = self._static_cache.get(id(node))
        if static is None:
            axis = round_axis(node if not isinstance(node, plan.Fused)
                              else parts[0])
            static = (axis,
                      aligned_reads(node, axis) if axis is not None
                      else frozenset(),
                      _gather_names(node))
            self._static_cache[id(node)] = static
        axis, aligned, gather_names = static
        rng = None
        if space.has_bag:
            if axis is None and not plan.is_reduce(node):
                return None
            axis_rows = self._rows(next(
                a.bag for a in space.axes if a.kind == "bag"), env) \
                if axis is not None else None
        else:
            if axis is None:
                return None
            aspec = next(a for a in space.axes if a.var == axis)
            try:
                lo = self.cp.executor.static_int(aspec.lo, env)
                hi = self.cp.executor.static_int(aspec.hi, env)
            except Exception:
                return None
            if lo != 0 or hi <= 0:
                return None
            axis_rows = hi + (-hi) % self.dp_n
            # (block, limit, total): no mask needed when the rows tile
            # evenly (limit=None); `total` = padded global extent, the
            # static bound certifying per-shard dynamic slices of
            # replicated operands (lower._sliced_operand, DESIGN.md §7)
            rng = (axis_rows // self.dp_n,
                   hi if axis_rows != hi else None,
                   axis_rows)

        def dest_aligned(p):
            return (axis is not None
                    and leading_key_var(p) == axis
                    and self._placed_oned(p.dest)
                    and self._rows(p.dest, env) == axis_rows)

        kinds = []
        for p in parts:
            if isinstance(p, _STORE_NODES):
                # stores run replicated unless every shard writes (and
                # reads, for read-modify-writes) strictly within its block
                if not dest_aligned(p):
                    return None
                if p.dest in gather_names and p.dest not in aligned:
                    return None            # self-read not block-local
                kinds.append("store")
            elif plan.is_reduce(p):
                if isinstance(p, _ALIGNABLE_REDUCES) and dest_aligned(p):
                    kinds.append("aligned")
                elif space.has_bag:
                    kinds.append("reduce")
                else:
                    return None            # range round: no psum source
            else:
                return None
        # localized reads must tile exactly like the round axis
        local = frozenset(n for n in aligned
                          if n not in dests_set
                          and self._placed_oned(n)
                          and self._rows(n, env) == axis_rows)
        return {"parts": parts, "kinds": kinds, "axis": axis, "rng": rng,
                "local": local, "axis_rows": axis_rows}

    def _exec_shardmap(self, nodes, env, limits, array_limits):
        cp = self.cp
        for node in nodes:
            if isinstance(node, plan.SeqLoop):
                # best: the whole loop as ONE shard_map program with an
                # on-device lax.while_loop (fused body, collectives inside
                # — zero per-iteration host syncs)
                if len(node.body) == 1 \
                        and isinstance(node.body[0], plan.FusedRound) \
                        and self._exec_fused(node.body[0], env, limits,
                                             array_limits, loop=node):
                    continue
                # next: a fully-replicated body needs no collectives at
                # all — run the loop through the single-device executor
                # (one on-device lax.while_loop; the old path paid a
                # blocking host sync on the condition EVERY iteration)
                if self._loop_replicated(node, env):
                    self._strategy[id(node)] = (
                        "on-device lax.while_loop (replicated body, "
                        "0 host syncs)")
                    cp.execute(env, bag_limits=limits,
                               array_limits=array_limits, nodes=[node])
                    for b in plan.flatten(node.body):
                        self._decisions.update(self._part_notes(b))
                    continue
                # fallback: host-driven loop, body nodes distributed
                # recursively with one condition sync per iteration
                syncs = 0
                while bool(cp.executor.eval_scalar(node.cond, env)):
                    syncs += 1
                    self._exec_shardmap(node.body, env, limits, array_limits)
                self._strategy[id(node)] = \
                    f"host-driven ({syncs + 1} condition syncs)"
                continue

            if isinstance(node, plan.FusedRound):
                if self._exec_fused(node, env, limits, array_limits):
                    continue
                # a runtime guard failed: per-member rounds (old behaviour)
                self._exec_shardmap(node.parts, env, limits, array_limits)
                continue

            if isinstance(node, plan.Rebalance):
                self._exec_rebalance(node, env, array_limits)
                continue

            spec = self._round_spec(node, env) \
                if (plan.is_reduce(node) or isinstance(node, _STORE_NODES)) \
                else None
            if spec is None:
                # replicated execution (identical result on all shards)
                self._strategy[id(node)] = "replicated"
                cp.execute(env, bag_limits=limits,
                           array_limits=array_limits, nodes=[node])
                self._decisions.update(self._part_notes(node))
                continue
            self._run_round(node, spec, env, limits, array_limits)

    def _loop_replicated(self, node, env) -> bool:
        """True when every leaf of the SeqLoop body classifies replicated
        (no round axis anywhere): the whole loop can run as ONE
        single-device lax.while_loop dispatch instead of a host-driven
        loop that syncs on the condition every iteration."""
        for b in plan.flatten(node.body):
            if isinstance(b, plan.SeqLoop):
                if not self._loop_replicated(b, env):
                    return False
                continue
            if plan.is_reduce(b) or isinstance(b, _STORE_NODES):
                if self._round_spec(b, env) is not None:
                    return False
        return True

    def _call_round(self, fn, args, site_name, label):
        """Execute a traced round/fused program under the failure policy:
        the injection site fires per attempt, transients retry at this
        level (bounded, backoff), and the wall time feeds the straggler
        watchdog.  Capacity/deterministic errors re-raise — descending is
        the caller's move (per-member bail for fused, the run() ladder
        for rounds).

        A flagged straggler additionally triggers speculative
        re-execution (DESIGN.md §13): at most ONE backup copy of the
        flagged round per label per run, first finisher wins, the loser
        is cancelled.  Both copies run the same traced executable on the
        same operands, so adopting the faster one never changes results —
        speculation only buys back the tail latency a slow worker cost."""
        def attempt():
            F.site(site_name, label=label)
            return fn(*args)
        t0 = self.faults.clock()
        out = F.run_with_retries(attempt, policy=self.policy,
                                 ledger=self.faults, label=label)
        dt = self.faults.clock() - t0
        straggled = self.faults.note_time(label, dt)
        if straggled and self.speculative and label not in self._spec_done:
            self._spec_done.add(label)
            t1 = self.faults.clock()
            backup = fn(*args)        # no injection site: the backup runs
            #                           on a different (healthy) worker
            dt2 = self.faults.clock() - t1
            if dt2 < dt:
                saved = dt - dt2
                self.faults.spec_saved_s += saved
                self.faults.record(
                    "speculative", label,
                    f"backup won: {dt2 * 1e3:.1f}ms vs straggler "
                    f"{dt * 1e3:.1f}ms (saved {saved * 1e3:.1f}ms); "
                    f"straggler copy cancelled")
                out = backup
            else:
                self.faults.record(
                    "speculative", label,
                    f"original finished first ({dt * 1e3:.1f}ms); backup "
                    f"cancelled after {dt2 * 1e3:.1f}ms")
        return out

    def _run_round(self, node, spec, env, limits, array_limits):
        cp = self.cp
        parts, kinds = spec["parts"], spec["kinds"]
        axis, rng, local = spec["axis"], spec["rng"], spec["local"]
        dests = [p.dest for p in parts]
        params = cp.program.params
        reads = sorted(set(node.reads) - set(dests))
        # dims are static python ints (they define extents): close over
        # them — as shard_map operands they would arrive as tracers
        dims = {n: env[n] for n in reads
                if n in params and params[n].kind == "dim"}
        names = [n for n in reads if n not in dims]
        bagnames = node.space.bag_names
        # ONED_ROW reads the analysis could NOT prove aligned cross shards:
        # pass them as blocks and all_gather on entry
        gathered = tuple(n for n in names
                         if n not in bagnames and n not in local
                         and self._placed_oned(n))
        in_specs = []
        args = []
        for n in names:
            v = env[n]
            if n in bagnames:
                in_specs.append(tuple(P(self.dp) for _ in v))
            elif n in local or n in gathered:
                in_specs.append(P(self.dp))
            else:
                in_specs.append(P() if not isinstance(v, tuple)
                                else tuple(P() for _ in v))
            args.append(v)
        store_dests = [p.dest for p, k in zip(parts, kinds) if k == "store"]
        for d in store_dests:
            in_specs.append(P(self.dp))
            args.append(env[d])

        dest_shapes = tuple(jnp.shape(env[d]) for d in dests)
        dest_dtypes = tuple(jnp.asarray(env[d]).dtype for d in dests)
        node_lims = {b: limits[b] for b in bagnames if b in limits}
        arr_lims = {n: array_limits[n]
                    for n in set(names) | set(dests) if n in array_limits}
        dest_oned = {d: self._placed_oned(d) for d in dests}
        out_specs = tuple(
            P(self.dp) if k in ("store", "aligned") or dest_oned[p.dest]
            else P()
            for p, k in zip(parts, kinds))

        # operator selection for the round's exchanges (DESIGN.md §8): the
        # cross-shard ⊕ of every unaligned reduce part is a cost-model /
        # autotune decision keyed on (K, D, op, shard count, shard-local
        # rows, dest sharding) — dense-partial + reduce-scatter vs
        # allreduce + local slice.  Static at round-build time (shapes are
        # concrete here), so the choice is part of the traced round and of
        # its cache key.
        n_loc = (spec["axis_rows"] or self.dp_n) // self.dp_n
        exchanges = {}
        for p, k in zip(parts, kinds):
            if k == "reduce":
                shp = jnp.shape(env[p.dest])
                d_rest = 1
                for d_ in shp[1:]:
                    d_rest *= int(d_)
                exchanges[p.dest] = self.cp.selector.choose_exchange(
                    k=int(shp[0]) if shp else 1, d=d_rest, op=p.op,
                    nshards=self.dp_n, n_local=n_loc,
                    dest_dist="ONED_ROW" if dest_oned[p.dest] else "REP")

        # run-time hot-key probe (skew salting): resolved against the
        # concrete key columns HERE, outside the trace — the factor is
        # part of the cache key, so a skewed and a uniform stream of the
        # same shapes trace different rounds
        salts = {}
        for p in parts:
            s = salt_for_node(p, env, cp.selector,
                              getattr(cp.config, "skew_salting", "auto"),
                              nshards=self.dp_n, bag_limits=limits)
            if s > 1:
                salts[p.dest] = s

        # everything local_fn closes over, so the traced round is reusable
        cache_key = (id(node), tuple(kinds), tuple(names),
                     tuple(store_dests), gathered, tuple(sorted(local)),
                     tuple(sorted(node_lims.items())),
                     tuple(sorted(arr_lims.items())),
                     tuple(sorted(dims.items())),
                     dest_shapes, dest_dtypes,
                     spec["axis"], spec["rng"],
                     tuple(sorted(self._demoted)),
                     tuple(sorted((d, x.backend)
                                  for d, x in exchanges.items())),
                     tuple(sorted(salts.items())))
        rlabel = f"round:{type(node).__name__}"
        # everything a block-restricted shard recompute of THIS round
        # needs (surgical recovery, DESIGN.md §13)
        rec = {"spec": spec, "names": tuple(names),
               "bagnames": frozenset(bagnames),
               "store_dests": tuple(store_dests), "dims": dims,
               "node_lims": node_lims, "arr_lims": arr_lims,
               "salts": salts}

        def replay(_fn=None, _args=tuple(args), _parts=parts,
                   _kinds=kinds):
            res2 = _fn(*_args)
            out2 = {}
            for p, k2, r in zip(_parts, _kinds, res2):
                out2[p.dest] = r if k2 == "store" else \
                    COMBINE[p.op](jnp.asarray(pre[p.dest]), r)
            return out2

        fn = self._round_cache.get(cache_key)
        if fn is not None:
            self._round_hits += 1
            results = self._call_round(fn, args, "dist.round_exec", rlabel)
            # restore the trace-time snapshot: the cached round re-runs
            # exactly what was traced, whatever happened in between
            self._strategy[id(node)] = self._strategy_by_key[cache_key]
            self._decisions.update(self._round_notes[cache_key])
            pre = {p.dest: env[p.dest] for p in parts}
            self._apply(parts, kinds, results, env)
            self._shard_lost_site(
                node, rlabel, env, list(zip(dests, kinds)), pre,
                partial(replay, _fn=fn), rec)
            return

        # trace-time only (cache hits skip it, like the trace itself):
        # record the round strategy + slice certificates for explain_rounds
        self._strategy[id(node)] = self._round_desc(
            parts, kinds, axis, exchanges, dest_oned, gathered, local)

        def local_fn(*vals, _parts=parts, _kinds=kinds,
                     _names=tuple(names), _stores=tuple(store_dests),
                     _bags=tuple(bagnames), _gather=gathered,
                     _local=tuple(local), _lims=node_lims, _alims=arr_lims,
                     _dims=dims, _shapes=dest_shapes, _dtypes=dest_dtypes,
                     _axis=axis, _rng=rng,
                     _exch={d: x.backend for d, x in exchanges.items()},
                     _salts=salts):
            e2 = dict(zip(_names + _stores, vals))
            e2.update(_dims)
            # globalize indexes: shard-local row r is offset + r (needed
            # when a bag/axis index appears in keys or values)
            shard = 0
            for a in self.dp:
                shard = shard * self.mesh.shape[a] + jax.lax.axis_index(a)
            for n in _gather:      # analysis: this read crosses shards
                e2[n] = jax.lax.all_gather(e2[n], self.dp, axis=0,
                                           tiled=True)
            offs = {b: shard * e2[b][0].shape[0] for b in _bags}
            row_offs = {n: shard * e2[n].shape[0] for n in _local}
            axis_ov = {}
            if _rng is not None:
                blk, lim, total = _rng
                axis_ov[_axis] = (shard * blk, blk, lim, total)
            outs = []
            for p, k, shp, dt in zip(_parts, _kinds, _shapes, _dtypes):
                ro = dict(row_offs)
                # alignment certificates: localized reads tile exactly like
                # the round axis (checked in _round_spec), and store/aligned
                # destinations by construction — their local dim-0 block IS
                # the axis window, so per-shard slices start at local 0
                cert = set(_local)
                if k == "store":
                    ro[p.dest] = shard * e2[p.dest].shape[0]
                    cert.add(p.dest)
                    ctx = ExecContext(offs, _lims, ro, _alims, axis_ov,
                                      frozenset(cert), _salts)
                    outs.append(cp.executor.run_node(p, e2, ctx))
                elif k == "aligned":
                    blk0 = shp[0] // self.dp_n
                    e2[p.dest] = jnp.full((blk0,) + tuple(shp[1:]),
                                          identity(p.op, dt))
                    ro[p.dest] = shard * blk0
                    cert.add(p.dest)
                    ctx = ExecContext(offs, _lims, ro, _alims, axis_ov,
                                      frozenset(cert), _salts)
                    outs.append(cp.executor.run_node(p, e2, ctx))
                else:
                    e2[p.dest] = jnp.full(shp, identity(p.op, dt))
                    ctx = ExecContext(offs, _lims, ro, _alims, axis_ov,
                                      frozenset(cert), _salts)
                    part_res = cp.executor.run_node(p, e2, ctx)
                    outs.append(self._combine_shard(
                        part_res, p.op, shard, dest_oned[p.dest],
                        _exch.get(p.dest, "psum_scatter")))
            return tuple(outs)

        fn = jax.jit(shard_map(local_fn, mesh=self.mesh,
                               in_specs=tuple(in_specs),
                               out_specs=out_specs))
        self._round_cache[cache_key] = fn
        self._round_traces += 1
        # traces: executor notes decisions
        results = self._call_round(fn, args, "dist.round_exec", rlabel)
        notes = self._part_notes(node)
        self._round_notes[cache_key] = notes
        self._decisions.update(notes)
        self._strategy_by_key[cache_key] = self._strategy[id(node)]
        pre = {p.dest: env[p.dest] for p in parts}
        self._apply(parts, kinds, results, env)
        self._shard_lost_site(node, rlabel, env, list(zip(dests, kinds)),
                              pre, partial(replay, _fn=fn), rec)

    def _round_desc(self, parts, kinds, axis, exchanges, dest_oned,
                    gathered, local) -> str:
        """The human-readable round strategy explain_rounds() prints —
        shared between single-node rounds and fused-region members so the
        observable format is identical in both paths."""
        desc = []
        for p, k in zip(parts, kinds):
            if k == "reduce":
                x = exchanges[p.dest]
                coll = f"{x.backend}[{x.source}]" if dest_oned[p.dest] \
                    else "psum"
                desc.append(f"reduce({coll})→{p.dest}")
            else:
                desc.append(f"{k}→{p.dest}")   # store/aligned: no collective
        extras = []
        if gathered:
            extras.append("all_gather: " + ",".join(gathered))
        if local:
            extras.append("local blocks: " + ",".join(sorted(local)))
        for p, k in zip(parts, kinds):
            if k == "aligned":   # per-shard contraction: print the static
                cert = shard_slice_certificates(   # bounds certificates
                    p, axis, frozenset(local))
                extras.append(
                    f"slice-certs[{p.dest}]: " + (", ".join(
                        f"{a}={c}" for a, c in sorted(cert.items()))
                        if cert else "none (dense grid)"))
        return (f"{' + '.join(desc)} over {axis}"
                + ("; " + "; ".join(extras) if extras else ""))

    # ------------------- fused regions (pass 11, DESIGN.md §9) -----------
    def _exec_fused(self, region, env, limits, array_limits,
                    loop=None) -> bool:
        """Run a FusedRound region as ONE jit+shard_map program: members
        execute sequentially inside the traced body with their collectives
        (psum / psum_scatter / all_gather) placed between them, instead of
        one shard_map dispatch per member with a host hop in between.
        With `loop`, the member sequence additionally runs under an
        on-device lax.while_loop over the SeqLoop carry — zero host syncs
        for the whole loop.  Returns False when a runtime guard fails
        (member not round-classifiable, §5 packed value, condition not
        computable from replicated state); the caller then falls back to
        per-member rounds / the host-driven loop.  Fusion never changes
        results, only dispatch."""
        from .passes import _expr_names, _scalar_member
        from .tiles import TiledMatrix
        cp = self.cp
        bail_key = id(region) if loop is None else id(loop)
        if bail_key in self._fused_bail:
            return False

        def bail() -> bool:
            self._fused_bail.add(bail_key)
            return False

        # ---- classify members against runtime shapes ----
        units = []
        for m in region.parts:
            if isinstance(m, plan.Rebalance):
                units.append(("rebalance", m, None))
                continue
            spec = self._round_spec(m, env) \
                if (plan.is_reduce(m) or isinstance(m, _STORE_NODES)) \
                else None
            if spec is not None:
                units.append(("round", m, spec))
                continue
            if not _scalar_member(m) or m.space.has_bag or any(
                    jnp.shape(env[d]) != () for d in plan.dests_of(m)):
                return bail()
            units.append(("scalar", m, None))

        # ---- name universe, entry representations ----
        params = cp.program.params
        all_names: set = set()
        bagnames_all: set = set()
        for _k, m, _s in units:
            all_names |= set(m.reads) | set(plan.dests_of(m))
            bagnames_all |= set(m.space.bag_names)
        if loop is not None:
            creads: set = set()
            _expr_names(loop.cond, creads)
            all_names |= {n for n in creads
                          if n in params or n in cp.program.outputs}
        dims = {n: env[n] for n in all_names
                if n in params and params[n].kind == "dim"}
        names = sorted(n for n in all_names if n not in dims)
        if any(isinstance(env[n], TiledMatrix) for n in names):
            return bail()                 # §5 reps cannot cross shard_map
        reps = {}
        for n in names:
            if n in bagnames_all:
                reps[n] = "bag"
            elif self._placed_oned(n):
                reps[n] = "block"
            else:
                reps[n] = "global"
        entry_reps = dict(reps)
        if loop is not None:
            # cond evaluates per shard: every read must be replicated
            for n in creads:
                if n in dims:
                    continue
                if reps.get(n, "global") == "block":
                    return bail()

        # ---- static instruction plan (rep transitions, collectives) ----
        instrs = []
        exchanges_all = {}
        for kind, m, spec in units:
            if kind == "rebalance":
                # active only when the dest is a row-block at this point
                # AND carries a logical limit (else blocks already equal)
                lim = array_limits.get(m.dest)
                active = reps.get(m.dest) == "block" and lim is not None
                instrs.append(("rebalance", m, active, lim))
                continue
            if kind == "scalar":
                reads = sorted(n for n in m.reads if n not in dims)
                g = tuple(n for n in reads if reps.get(n) == "block")
                instrs.append(("scalar", m, g))
                for d in plan.dests_of(m):
                    reps[d] = "global"
                continue
            parts, kinds = spec["parts"], spec["kinds"]
            axis, rng = spec["axis"], spec["rng"]
            member_dests = {p.dest for p in parts}
            reads = sorted(set(m.reads) - member_dests - set(dims))
            bagnames = tuple(m.space.bag_names)
            local_eff = tuple(sorted(
                n for n in spec["local"] if reps.get(n) == "block"))
            gathered = tuple(sorted(
                n for n in reads
                if n not in bagnames and n not in local_eff
                and reps.get(n) == "block"))
            convs = []
            exch = {}
            doned = []
            n_loc = (spec["axis_rows"] or self.dp_n) // self.dp_n
            for p, k in zip(parts, kinds):
                if k == "reduce":
                    shp = jnp.shape(env[p.dest])
                    d_rest = 1
                    for d_ in shp[1:]:
                        d_rest *= int(d_)
                    oned = self._placed_oned(p.dest)
                    exch[p.dest] = cp.selector.choose_exchange(
                        k=int(shp[0]) if shp else 1, d=d_rest, op=p.op,
                        nshards=self.dp_n, n_local=n_loc,
                        dest_dist="ONED_ROW" if oned else "REP")
                    need = "block" if oned else "global"
                else:                     # store/aligned: dest is ONED
                    oned = True
                    need = "block"
                doned.append(oned)
                if reps.get(p.dest, "global") != need:
                    convs.append((p.dest, need))
                reps[p.dest] = need
            exchanges_all.update(exch)
            salts = {}
            for p in parts:
                s = salt_for_node(p, env, cp.selector,
                                  getattr(cp.config, "skew_salting", "auto"),
                                  nshards=self.dp_n, bag_limits=limits)
                if s > 1:
                    salts[p.dest] = s
            instrs.append(("round", m, parts, tuple(kinds), axis, rng,
                           gathered, local_eff, tuple(convs),
                           {d: x.backend for d, x in exch.items()},
                           tuple(doned), bagnames, salts))
        endconvs = []
        if loop is not None:
            # while_loop carries need a stable representation: convert
            # back to the entry rep at body end (normally a no-op)
            for c in loop.carry:
                if reps.get(c) != entry_reps.get(c):
                    endconvs.append((c, entry_reps[c]))
                    reps[c] = entry_reps[c]
        dests_order = []
        for _k, m, _s in units:
            for d in plan.dests_of(m):
                if d not in dests_order:
                    dests_order.append(d)

        # ---- operands, specs, cache key ----
        node_lims = {b: limits[b] for b in sorted(bagnames_all)
                     if b in limits}
        arr_lims = {n: array_limits[n] for n in names if n in array_limits}
        in_specs = []
        args = []
        shapes = {}
        dtypes = {}
        sig = []
        for n in names:
            v = env[n]
            if entry_reps[n] == "bag":
                in_specs.append(tuple(P(self.dp) for _ in v))
                sig.append((n, "bag", tuple(
                    (tuple(c.shape), str(c.dtype)) for c in v)))
            else:
                shapes[n] = tuple(jnp.shape(v))
                dtypes[n] = jnp.asarray(v).dtype
                sig.append((n, entry_reps[n], shapes[n], str(dtypes[n])))
                in_specs.append(P(self.dp) if entry_reps[n] == "block"
                                else P())
            args.append(v)
        out_specs = tuple(P(self.dp) if reps[d] == "block" else P()
                          for d in dests_order)
        def _ikey(i):
            if i[0] == "scalar":
                return (i[0], id(i[1]), i[2])
            if i[0] == "rebalance":
                return (i[0], id(i[1]), i[2], i[3])
            return (i[0], id(i[1]), i[3], i[4], i[5], i[6], i[7], i[8],
                    tuple(sorted(i[9].items())), i[10], i[11],
                    tuple(sorted(i[12].items())))

        cache_key = ("fused", bail_key, tuple(sig),
                     tuple(_ikey(i) for i in instrs),
                     tuple(endconvs), tuple(sorted(node_lims.items())),
                     tuple(sorted(arr_lims.items())),
                     tuple(sorted(dims.items())),
                     tuple(sorted(self._demoted)))
        fn = self._round_cache.get(cache_key)
        if fn is not None:
            self._round_hits += 1
            try:
                results = self._call_round(fn, args, "dist.fused_compile",
                                           "fused")
            except Exception as ex:      # noqa: BLE001 — ladder descent
                # classified descent: the per-member fallback is the next
                # ladder level for a fused region (fusion never changes
                # results, so falling back is always sound)
                self.faults.descend("fused", "per-member rounds", ex)
                return bail()
            self._strategy.update(self._strategy_by_key[cache_key])
            self._decisions.update(self._round_notes[cache_key])
            pre = {d: env[d] for d in dests_order}
            for d, res in zip(dests_order, results):
                env[d] = res
            self._shard_lost_site(
                region, "fused", env,
                [(d, "fused") for d in dests_order], pre,
                lambda _fn=fn, _a=tuple(args):
                    dict(zip(dests_order, _fn(*_a))),
                unit="fused loop" if loop is not None else "fused region")
            return True

        # trace-time: record the region + per-member strategies
        strat = {}
        n_members = len(units)
        head = f"fused round: {n_members} member" + \
            ("s" if n_members != 1 else "") + ", 1 shard_map program"
        if loop is not None:
            head += "; on-device lax.while_loop (0 host syncs)"
            strat[id(loop)] = ("on-device lax.while_loop inside ONE fused "
                               "shard_map round (0 host syncs)")
        strat[id(region)] = head
        for instr in instrs:
            if instr[0] == "scalar":
                strat[id(instr[1])] = "replicated scalar (inside fused round)"
                continue
            if instr[0] == "rebalance":
                _t, m, active, lim = instr
                if active:
                    cts, fac = self._shard_counts(
                        int(jnp.shape(env[m.dest])[0]), lim)
                    strat[id(m)] = (
                        f"rebalance(size-exchange psum + all-to-all "
                        f"psum_scatter)→{m.dest} (inside fused round); "
                        f"rows/shard={cts} balance={fac:.2f}")
                else:
                    strat[id(m)] = ("rebalance: elided ("
                                    + ("already balanced"
                                       if reps.get(m.dest) == "block"
                                       else "replicated dest") + ")")
                continue
            (_t, m, parts, kinds, axis, _rng, gathered, local_eff,
             _convs, exch_b, doned, _bags, _salts) = instr
            strat[id(m)] = self._round_desc(
                parts, kinds, axis, exchanges_all,
                {p.dest: o for p, o in zip(parts, doned)},
                gathered, local_eff)
        self._strategy.update(strat)

        dp, dp_n = self.dp, self.dp_n
        mesh_shape = {a: self.mesh.shape[a] for a in dp}
        carry_names = loop.carry if loop is not None else ()
        cond_expr = loop.cond if loop is not None else None
        dshapes = {d: tuple(jnp.shape(env[d])) for d in dests_order}
        ddtypes = {d: jnp.asarray(env[d]).dtype for d in dests_order}

        def local_fn(*vals):
            e2 = dict(zip(names, vals))
            e2.update(dims)
            shard = 0
            for a in dp:
                shard = shard * mesh_shape[a] + jax.lax.axis_index(a)

            def to_global(v):
                return jax.lax.all_gather(v, dp, axis=0, tiled=True)

            def to_block(v, nme):
                blk = (shapes.get(nme) or dshapes[nme])[0] // dp_n
                return jax.lax.dynamic_slice_in_dim(v, shard * blk, blk,
                                                    axis=0)

            def convert(e, nme, need):
                e[nme] = to_block(e[nme], nme) if need == "block" \
                    else to_global(e[nme])

            def run_body(e2):
                for instr in instrs:
                    if instr[0] == "rebalance":
                        _t, m, active, lim = instr
                        if active:
                            e2[m.dest] = self._rebalance_local(
                                jnp.asarray(e2[m.dest]), shard, lim)
                        continue
                    if instr[0] == "scalar":
                        _t, m, g = instr
                        eu = dict(e2)
                        for n in g:
                            eu[n] = to_global(eu[n])
                        ctx = ExecContext({}, node_lims, {}, arr_lims, {},
                                          frozenset())
                        e2[m.dest] = cp.executor.run_node(m, eu, ctx)
                        continue
                    (_t, m, parts, kinds, axis, rng, gathered, local_eff,
                     convs, exch, doned, bagnames, salts) = instr
                    for d, need in convs:
                        convert(e2, d, need)
                    eu = dict(e2)
                    for n in gathered:
                        eu[n] = to_global(eu[n])
                    offs = {b: shard * eu[b][0].shape[0] for b in bagnames}
                    row_offs = {n: shard * eu[n].shape[0]
                                for n in local_eff}
                    axis_ov = {}
                    if rng is not None:
                        blk, lim, total = rng
                        axis_ov[axis] = (shard * blk, blk, lim, total)
                    for p, k, oned in zip(parts, kinds, doned):
                        shp, dt = dshapes[p.dest], ddtypes[p.dest]
                        ro = dict(row_offs)
                        cert = set(local_eff)
                        if k == "store":
                            eu[p.dest] = e2[p.dest]
                            ro[p.dest] = shard * eu[p.dest].shape[0]
                            cert.add(p.dest)
                            ctx = ExecContext(offs, node_lims, ro, arr_lims,
                                              axis_ov, frozenset(cert),
                                              salts)
                            e2[p.dest] = cp.executor.run_node(p, eu, ctx)
                        elif k == "aligned":
                            prev = e2[p.dest]
                            blk0 = shp[0] // dp_n
                            eu[p.dest] = jnp.full(
                                (blk0,) + tuple(shp[1:]), identity(p.op, dt))
                            ro[p.dest] = shard * blk0
                            cert.add(p.dest)
                            ctx = ExecContext(offs, node_lims, ro, arr_lims,
                                              axis_ov, frozenset(cert),
                                              salts)
                            res = cp.executor.run_node(p, eu, ctx)
                            e2[p.dest] = COMBINE[p.op](prev, res)
                        else:             # unaligned reduce
                            prev = jnp.asarray(e2[p.dest])
                            eu[p.dest] = jnp.full(shp, identity(p.op, dt))
                            ctx = ExecContext(offs, node_lims, ro, arr_lims,
                                              axis_ov, frozenset(cert),
                                              salts)
                            part_res = cp.executor.run_node(p, eu, ctx)
                            exchd = self._combine_shard(
                                part_res, p.op, shard, oned,
                                exch.get(p.dest, "psum_scatter"))
                            e2[p.dest] = COMBINE[p.op](prev, exchd)
                return e2

            if cond_expr is None:
                e2 = run_body(e2)
                return tuple(e2[d] for d in dests_order)

            def cond_fn(c):
                ec = dict(e2)
                ec.update(dict(zip(carry_names, c)))
                return jnp.asarray(cp.executor.eval_scalar(cond_expr, ec),
                                   bool)

            def body_fn(c):
                eb = dict(e2)
                eb.update(dict(zip(carry_names, c)))
                eb = run_body(eb)
                for nme, need in endconvs:
                    convert(eb, nme, need)
                return tuple(jnp.asarray(eb[n]) for n in carry_names)

            carry0 = tuple(jnp.asarray(e2[n]) for n in carry_names)
            out = jax.lax.while_loop(cond_fn, body_fn, carry0)
            e2.update(dict(zip(carry_names, out)))
            return tuple(e2[d] for d in dests_order)

        fn = jax.jit(shard_map(local_fn, mesh=self.mesh,
                               in_specs=tuple(in_specs),
                               out_specs=out_specs, check_rep=False))
        try:
            # traces: executor notes decisions
            results = self._call_round(fn, args, "dist.fused_compile",
                                       "fused")
        except Exception as ex:           # noqa: BLE001 — ladder descent
            # a member materialization the fused ctx cannot express, or a
            # classified non-transient fault — guaranteed fallback to
            # per-member rounds, results unchanged
            self.faults.descend("fused", "per-member rounds", ex)
            for k in strat:
                self._strategy.pop(k, None)
            return bail()
        self._round_cache[cache_key] = fn
        self._round_traces += 1
        notes = {}
        for _k, m, _s in units:
            notes.update(self._part_notes(m))
        self._round_notes[cache_key] = notes
        self._decisions.update(notes)
        self._strategy_by_key[cache_key] = strat
        pre = {d: env[d] for d in dests_order}
        for d, res in zip(dests_order, results):
            env[d] = res
        self._shard_lost_site(
            region, "fused", env, [(d, "fused") for d in dests_order], pre,
            lambda _fn=fn, _a=tuple(args): dict(zip(dests_order, _fn(*_a))),
            unit="fused loop" if loop is not None else "fused region")
        return True

    def _part_notes(self, node) -> dict:
        """Snapshot the executor's materialization decisions for the
        node's leaves, as they stand right after this node executed."""
        notes = {}
        parts = node.parts if isinstance(node, plan.Fused) else [node]
        for p in parts:
            d = self.cp.executor.decisions.get(id(p))
            if d is None and isinstance(p, plan.TiledMatmul):
                # dense lhs resolved to the einsum underneath
                d = self.cp.executor.decisions.get(id(p.contract))
            if d is not None:
                notes[id(p)] = d
        return notes

    @staticmethod
    def _apply(parts, kinds, results, env):
        """Fold a round's outputs back into the env: stores replace their
        destination, reductions ⊕-combine with it."""
        for p, k, res in zip(parts, kinds, results):
            if k == "store":
                env[p.dest] = res
            else:
                env[p.dest] = COMBINE[p.op](jnp.asarray(env[p.dest]), res)

    # ------------- surgical shard recovery (DESIGN.md §13) -------------
    def _shard_lost_site(self, node, rlabel, env, writes, pre, replay,
                         rec=None, unit="round"):
        """Fire the post-round shard-loss site (a worker dying while
        holding the partition it just produced) and recover surgically.
        `writes` is [(dest, kind)] for everything the round applied,
        `pre` maps each dest to its pre-apply value (the surviving peer /
        carry-snapshot copy recovery re-fetches), `replay` re-executes the
        round's cached executable and returns {dest: full result}, and
        `rec` (leaf rounds only) carries what a block-restricted host
        recompute of shard k needs."""
        if F.active() is None:
            return                    # zero-cost outside the fault harness
        try:
            F.site("dist.shard_lost", label=rlabel)
        except F.ShardLostFault as ex:
            self._recover_shard(node, rlabel, env, ex, writes, pre,
                                replay, rec, unit)

    def _recover_shard(self, node, rlabel, env, ex, writes, pre, replay,
                       rec, unit):
        """Lineage-based recovery of ONE lost shard partition (DESIGN.md
        §13): replicated destinations cost nothing (every survivor holds
        a full copy); aligned stores / aligned reduces recompute ONLY
        shard k's block from surviving inputs (1/P of the round); sharded
        unaligned reduces and fused regions replay the cached round
        executable and re-slice.  Every recovered block is verified
        against the checksum the peer replica holds (covers the §3.4 mask
        rows too — pad bytes are part of the stamp) before it is spliced
        back.  No ladder descent — unless the same shard was already lost
        within the policy TTL (a flapping worker) or verification fails,
        in which case the original fault re-raises and run()'s ladder
        takes over."""
        lin = getattr(node, "lineage", None)
        k = ex.shard % self.dp_n
        now = self.faults.clock()
        last = self._shard_loss.get(k)
        self._shard_loss[k] = now
        if not self.lineage_enabled or lin is None:
            ex.escalated = True       # pre-§13 behaviour: ladder descent,
            raise ex                  # not a same-level re-dispatch
        if last is not None and (now - last) < self.policy.shard_loss_ttl_s:
            self.faults.record(
                "escalate", rlabel,
                f"shard {k} lost twice within "
                f"{self.policy.shard_loss_ttl_s:.0f}s TTL — flapping "
                f"worker, recomputing onto it again is throwaway; ladder "
                f"takes over")
            ex.escalated = True       # run(): skip same-level re-dispatch
            raise ex
        lost, free = [], []
        for dest, kind in writes:
            if not self._placed_oned(dest):
                free.append(dest)     # survivors hold the full copy
                continue
            v = jnp.asarray(env[dest])
            blk = int(v.shape[0]) // self.dp_n
            start = k * blk
            crc = F.checksum(v[start:start + blk])   # the peer-held stamp
            # the partition died with its worker: poison it so a recovery
            # bug that reads the dead block cannot pass verification
            env[dest] = _kill_block(v, start, blk)
            lost.append((dest, kind, start, blk, crc))
        if not lost:
            self.faults.recovered(
                rlabel,
                f"shard {k}/{self.dp_n}: nothing to recompute — every "
                f"written array is replicated, survivors hold full copies "
                f"(lineage depth={lin.depth})")
            return
        names = ", ".join(f"{d}[{s}:{s + b}]" for d, _k2, s, b, _c in lost)
        blocks = None
        mode = ""
        if rec is not None and all(k2 in ("store", "aligned")
                                   for _d, k2, _s, _b, _c in lost):
            try:
                blocks = self._recompute_blocks(k, pre, env, rec)
            except Exception:         # noqa: BLE001 — fall back to replay
                blocks = None
            if blocks is not None and all(
                    F.checksum(blocks[d]) == c
                    for d, _k2, _s, _b, c in lost):
                mode = (f"block-restricted recompute "
                        f"(1/{self.dp_n} of the round)")
            else:
                blocks = None         # bit mismatch: replay instead
        if blocks is None:
            full = replay()
            blocks = {d: jnp.asarray(full[d])[s:s + b]
                      for d, _k2, s, b, _c in lost}
            if not all(F.checksum(blocks[d]) == c
                       for d, _k2, _s, _b, c in lost):
                self.faults.record(
                    "escalate", rlabel,
                    f"shard {k}: recovered blocks failed peer-checksum "
                    f"verification — ladder takes over")
                ex.escalated = True   # run(): skip same-level re-dispatch
                raise ex
            mode = f"replay {unit} + re-slice"
        for d, _k2, s, b, _c in lost:
            v = jnp.asarray(env[d])
            env[d] = jax.lax.dynamic_update_slice_in_dim(
                v, blocks[d].astype(v.dtype), s, axis=0)
        reads = ", ".join(f"{a}:{k2}" for a, k2 in lin.reads) or "none"
        self.faults.recovered(
            rlabel,
            f"shard {k}/{self.dp_n}: {names} via {mode}; lineage "
            f"depth={lin.depth} (a from-scratch restart would replay "
            f"{lin.depth} round(s)); reads[{reads}]; checksum ok"
            + (f"; free(rep): {','.join(free)}" if free else ""))

    def _recompute_blocks(self, k, pre, env, rec):
        """Host-side mirror of the round's per-shard body for the ONE
        concrete shard k: re-fetch its inputs (replicated arrays are free,
        localized blocks and bag columns are sliced from the surviving
        global copy, gathered reads use the full array any survivor
        already materialized), rebuild the exact ExecContext the dead
        worker ran under, and run the member nodes.  Returns {dest: block}
        for the round's row-block destinations."""
        cp = self.cp
        spec = rec["spec"]
        parts, kinds = spec["parts"], spec["kinds"]
        axis, rng, local = spec["axis"], spec["rng"], spec["local"]
        bagnames = rec["bagnames"]
        e2 = dict(rec["dims"])
        offs, row_offs = {}, {}
        for n in rec["names"]:
            v = env[n]
            if n in bagnames:
                blk_b = int(v[0].shape[0]) // self.dp_n
                e2[n] = tuple(c[k * blk_b:(k + 1) * blk_b] for c in v)
                offs[n] = k * blk_b
            elif n in local:
                blk_n = int(v.shape[0]) // self.dp_n
                e2[n] = v[k * blk_n:(k + 1) * blk_n]
                row_offs[n] = k * blk_n
            else:
                e2[n] = v             # replicated or gathered: full copy
        for d in rec["store_dests"]:  # store operands enter as blocks
            v = jnp.asarray(pre[d])
            blk_d = int(v.shape[0]) // self.dp_n
            e2[d] = v[k * blk_d:(k + 1) * blk_d]
        axis_ov = {}
        if rng is not None:
            blk, lim, total = rng
            axis_ov[axis] = (k * blk, blk, lim, total)
        out = {}
        for p, kind in zip(parts, kinds):
            if not self._placed_oned(p.dest):
                continue
            shp = tuple(jnp.shape(pre[p.dest]))
            dt = jnp.asarray(pre[p.dest]).dtype
            blk0 = shp[0] // self.dp_n
            ro = dict(row_offs)
            cert = set(local)
            e3 = dict(e2)
            ro[p.dest] = k * blk0
            cert.add(p.dest)
            if kind == "store":
                ctx = ExecContext(offs, rec["node_lims"], ro,
                                  rec["arr_lims"], axis_ov,
                                  frozenset(cert), rec["salts"])
                out[p.dest] = cp.executor.run_node(p, e3, ctx)
            elif kind == "aligned":
                prev = jnp.asarray(pre[p.dest])[k * blk0:(k + 1) * blk0]
                e3[p.dest] = jnp.full((blk0,) + tuple(shp[1:]),
                                      identity(p.op, dt))
                ctx = ExecContext(offs, rec["node_lims"], ro,
                                  rec["arr_lims"], axis_ov,
                                  frozenset(cert), rec["salts"])
                res = cp.executor.run_node(p, e3, ctx)
                out[p.dest] = COMBINE[p.op](prev, res)
            else:                     # unaligned reduce: replay instead
                return None
        return out

    # ------------------------- explain -------------------------
    def explain_rounds(self) -> str:
        """Spark-EXPLAIN-style dump of the round strategy chosen for every
        plan node in the LAST run() — aligned store / aligned reduce /
        unaligned reduce (with its collective) / replicated — together with
        the per-shard materialization the executor actually traced for it
        (e.g. ``einsum`` vs ``fallback:dense-grid``).  Classification
        depends on runtime row counts, so call after run()."""
        out = [f"== distributed rounds: {self.cp.program.name} "
               f"({self.dp_n} shards over {self.dp}, mode={self.mode}) =="]
        out.append(f"round cache: {self._round_traces} traced, "
                   f"{self._round_hits} hits")
        if self._demoted:
            out.append("placement: " + ", ".join(
                f"{n}→REP (dest-{d.backend}[{d.source}])"
                for n, d in sorted(self._demoted.items())))
        # skew observability: live rows per shard + max/mean balance factor
        # for every variable-length (ONED_VAR / rebalanced) array
        for n, (cts, fac, kind) in sorted(self._balance.items()):
            out.append(f"balance[{n}]: rows/shard={cts} "
                       f"factor={fac:.2f} ({kind})")
        self._round_lines(self.cp.plan, 0, out)
        return "\n".join(out)

    def explain_faults(self) -> str:
        """The shared per-program failure ledger (one ladder per program,
        whichever layer — distributed or single-device — descended it)."""
        return self.cp.explain_faults()

    def _round_lines(self, nodes, indent, out):
        pre = "  " * indent
        for node in nodes:
            if isinstance(node, plan.SeqLoop):
                out.append(f"{pre}{node.describe()}")
                strat = self._strategy.get(id(node))
                if strat is not None:
                    out.append(f"{pre}    loop: {strat}")
                self._round_lines(node.body, indent + 1, out)
                continue
            if isinstance(node, plan.FusedRound):
                out.append(f"{pre}{node.describe()}")
                strat = self._strategy.get(id(node))
                if strat is not None:
                    out.append(f"{pre}    round: {strat}")
                self._round_lines(node.parts, indent + 1, out)
                continue
            out.append(f"{pre}{node.describe()}")
            strat = self._strategy.get(id(node))
            if strat is not None:
                out.append(f"{pre}    round: {strat}")
            parts = node.parts if isinstance(node, plan.Fused) else [node]
            for p in parts:
                d = self._decisions.get(id(p))
                if d is not None:
                    out.append(f"{pre}    per-shard[{p.dest}]: {d}")

    # ------------------------- entry -------------------------
    def run(self, inputs: dict) -> dict:
        """Distributed ladder (DESIGN.md §11/§12): fused → per-member
        rounds (inside _run_once, via _fused_bail) → REP-everything
        placements → the wrapped single-device program, whose own ladder
        ends at the interpreter oracle.  Transients retry at each level
        first; a deterministic error gets exactly ONE descent
        (REP-everything) and surfaces if it reproduces there — it is a
        user error, and the deeper levels would only mask it.

        Capacity errors take a DIFFERENT exit: they must never ascend
        the memory curve.  REP-everything replicates every dense array
        (strictly MORE bytes per device than the sharded placement that
        just OOMed) and single-device concentrates the whole input on
        one device — both rungs are guaranteed re-OOMs.  A classified
        capacity error therefore descends straight to the chunked
        out-of-core tier (core/chunked.py, halving tiles on repeat), or
        to single-device only when out_of_core="off"."""
        try:
            return F.run_with_retries(
                lambda: self._run_once(inputs),
                policy=self.policy, ledger=self.faults, label="dist")
        except Exception as ex:          # noqa: BLE001 — ladder descent
            if F.classify(ex) == "capacity":
                return self._descend_capacity("rounds", inputs, ex)
            if F.classify(ex) == "shard_lost" \
                    and not getattr(ex, "escalated", False):
                # MID-round loss (the worker died before its outputs
                # applied — nothing to recompute): the program's inputs
                # survive on the host, so ONE same-level re-dispatch
                # re-places them onto the surviving pool before any
                # ladder descent.  Escalated post-round losses (flapping
                # worker, failed verification) skip this and descend.
                try:
                    out = self._run_once(inputs)
                    self.faults.recovered(
                        "dist",
                        "mid-round shard loss: same-level re-dispatch "
                        "onto the surviving pool (inputs survive on the "
                        "host; no round output was lost)")
                    return out
                except Exception as ex2:  # noqa: BLE001 — ladder descent
                    ex = ex2
                    if F.classify(ex) == "capacity":
                        return self._descend_capacity("rounds", inputs, ex)
            self.faults.descend("rounds", "rep", ex)
            if F.classify(ex) == "deterministic":
                out = self._run_once(inputs, force_rep=True)
                self.faults.recover("rep")
                return out
            try:
                out = F.run_with_retries(
                    lambda: self._run_once(inputs, force_rep=True),
                    policy=self.policy, ledger=self.faults, label="rep")
                self.faults.recover("rep")
                return out
            except Exception as ex2:     # noqa: BLE001 — ladder descent
                if F.classify(ex2) == "deterministic":
                    raise
                if F.classify(ex2) == "capacity":
                    return self._descend_capacity("rep", inputs, ex2)
                self.faults.descend("rep", "single-device", ex2)
                out = self.cp.run(inputs)
                self.faults.recover("single-device")
                return out

    def _descend_capacity(self, from_level: str, inputs: dict, ex) -> dict:
        """Capacity exit: down the memory curve (DESIGN.md §12)."""
        if self.cp.out_of_core != "off":
            self.faults.descend(from_level, "chunked", ex)
            out = self.cp._run_chunked(inputs, recovering=True)
            return out
        self.faults.descend(from_level, "single-device", ex)
        out = self.cp.run(inputs)
        self.faults.recover("single-device")
        return out

    def _run_once(self, inputs: dict, force_rep: bool = False) -> dict:
        env = {}
        self._fused_bail = set()     # placements/shapes are per-run
        self._spec_done = set()      # speculation budget is per run
        self._force_rep = force_rep
        try:
            placed, limits, array_limits = self.place(inputs)
        finally:
            self._force_rep = False  # place() consumed it; don't leak
        #                              into direct place() calls (tests)
        for name, t in self.cp.program.params.items():
            v = placed[name]
            if t.kind in ("vector", "matrix", "map"):
                env[name] = jnp.asarray(
                    v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = v
        # balance observability for explain_rounds(): the per-shard live
        # row counts every ONED_VAR / rebalanced array holds THIS run
        self._balance = {}
        for name, d in self.dists.items():
            if name in self._rebalanced:
                kind = "rebalance inserted"
            elif d == Dist.ONED_VAR:
                kind = "rebalance elided"
            else:
                continue
            if not self._placed_oned(name):
                continue
            shp = jnp.shape(env[name])
            if not shp:
                continue
            cts, fac = self._shard_counts(int(shp[0]),
                                          array_limits.get(name))
            self._balance[name] = (cts, fac, kind)
        if self.mode == "gspmd":
            self.cp.execute(env, bag_limits=limits,
                            array_limits=array_limits)
        else:
            self._exec_shardmap(self.cp.plan, env, limits, array_limits)
        out = {}
        for n in self.cp.program.outputs:
            v = env[n]
            lim = array_limits.get(n)
            out[n] = v if lim is None else v[:lim]   # drop pad rows
        return out


def _kill_block(v, start, blk):
    """Destroy rows [start, start+blk): the partition died with its
    worker.  Poisoned with NaN / an integer sentinel rather than left
    stale so that any recovery path reading the dead block fails the
    peer-checksum verification instead of silently passing."""
    if jnp.issubdtype(v.dtype, jnp.floating):
        fill = jnp.nan
    elif jnp.issubdtype(v.dtype, jnp.integer):
        fill = jnp.iinfo(v.dtype).min
    else:
        fill = 0
    dead = jnp.full((blk,) + tuple(v.shape[1:]), fill, v.dtype)
    return jax.lax.dynamic_update_slice_in_dim(v, dead, start, axis=0)


def _gather_names(node) -> frozenset:
    from .dist_analysis import gathers_of
    return frozenset(gathers_of(node))


def _walk_plan(nodes):
    """Every leaf plan node, containers opened (SeqLoop bodies, FusedRound
    regions, Fused parts)."""
    for n in nodes:
        if isinstance(n, plan.SeqLoop):
            yield from _walk_plan(n.body)
        elif isinstance(n, plan.FusedRound):
            yield from _walk_plan(n.parts)
        elif isinstance(n, plan.Fused):
            yield from n.parts
        else:
            yield n


def compile_distributed(fn_or_prog, mesh, dp_axes=("data",),
                        mode: str = "shardmap", shard_dense: bool = True,
                        **kw) -> DistributedProgram:
    from .lower import compile_program
    cp = fn_or_prog if isinstance(fn_or_prog, CompiledProgram) \
        else compile_program(fn_or_prog, **kw)
    return DistributedProgram(cp, mesh, dp_axes, mode, shard_dense)

"""Distributed execution of compiled loop programs over a device mesh —
the paper's DISC backend, retargeted from Spark shuffles to TPU collectives
(DESIGN.md §4).

Both modes consume the SAME physical plan (CompiledProgram.plan) through
the public executor interface; bag offsets and logical bag lengths are plan
parameters (lower.ExecContext), not lowerer state.

* ``shardmap`` (paper-faithful operator mapping): bags are sharded over the
  dp axes; every reduction node whose iteration space is bag-driven runs
  as  *local partial-⊕ over the bag shard → psum*  under shard_map — the
  reduction-based replacement for the paper's shuffle-based group-by.  A
  `Fused` node (update-fusion pass) runs all its parts in ONE shard_map
  round.  Dense arrays are replicated (the paper's "broadcast small arrays
  to all workers" future-work optimization, here the default: index spaces
  are bounded).  Nodes without bag axes execute replicated (identical on
  all shards).

* ``gspmd``: the single-device plan executed on sharded inputs; XLA's SPMD
  partitioner inserts the collectives.  Works for every program, including
  range-driven contractions (matmul → partitioned einsum).

Bags whose length is not divisible by the shard count are PADDED with zero
rows to the next multiple; the original length travels as a bag limit and
the executor masks the padding out of every aggregation, so odd-length
bags shard instead of silently replicating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from . import plan
from .lower import COMBINE, CompiledProgram, ExecContext, identity


class DistributedProgram:
    def __init__(self, cp: CompiledProgram, mesh, dp_axes=("data",),
                 mode: str = "shardmap"):
        self.cp = cp
        self.mesh = mesh
        self.dp = tuple(dp_axes)
        self.mode = mode
        self.dp_n = 1
        for a in self.dp:
            self.dp_n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    # ------------------------- input placement -------------------------
    def place(self, inputs: dict):
        """Shard bags over dp, replicate dense arrays.  Bags whose length
        is not divisible by the shard count are padded with zero rows;
        returns (placed, bag_limits) where bag_limits maps each padded bag
        to its logical length — consumers MUST mask rows beyond the limit
        (DistributedProgram.run threads it through lower.ExecContext)."""
        out = {}
        limits: dict[str, int] = {}
        for name, t in self.cp.program.params.items():
            v = inputs[name]
            if t.kind == "bag":
                cols = v if isinstance(v, tuple) else (v,)
                cols = tuple(jnp.asarray(c) for c in cols)
                n = int(cols[0].shape[0])
                pad = (-n) % self.dp_n
                if pad:
                    cols = tuple(jnp.concatenate(
                        [c, jnp.zeros((pad,) + c.shape[1:], c.dtype)])
                        for c in cols)
                    limits[name] = n
                out[name] = tuple(
                    jax.device_put(c, NamedSharding(self.mesh, P(self.dp)))
                    for c in cols)
            elif t.kind == "dim":
                out[name] = int(v)
            else:
                arr = jnp.asarray(v)
                out[name] = jax.device_put(
                    arr, NamedSharding(self.mesh, P()))  # broadcast join
        return out, limits

    # ------------------------- shardmap mode -------------------------
    def _psum(self, part, op: str):
        if op == "+":
            return jax.lax.psum(part, self.dp)
        if op == "min":
            return -jax.lax.pmax(-part, self.dp)
        if op == "max":
            return jax.lax.pmax(part, self.dp)
        raise NotImplementedError(op)

    def _exec_shardmap(self, nodes, env, limits):
        cp = self.cp
        for node in nodes:
            if isinstance(node, plan.SeqLoop):
                # sequential driver; body nodes distributed recursively
                while bool(cp.executor.eval_scalar(node.cond, env)):
                    self._exec_shardmap(node.body, env, limits)
                continue

            bag_driven = plan.is_reduce(node) and node.space.has_bag
            if not bag_driven:
                # replicated execution (identical result on all shards)
                cp.execute(env, bag_limits=limits, nodes=[node])
                continue

            # local partial ⊕ over the bag shard, then psum over dp
            parts = tuple(node.parts) if isinstance(node, plan.Fused) \
                else (node,)
            dests = tuple(p.dest for p in parts)
            ops = plan.ops_of(node)
            params = self.cp.program.params
            reads = sorted(set(node.reads) - set(dests))
            # dims are static python ints (they define extents): close over
            # them — as shard_map operands they would arrive as tracers
            dims = {n: env[n] for n in reads
                    if n in params and params[n].kind == "dim"}
            names = [n for n in reads if n not in dims]
            bagnames = node.space.bag_names
            in_specs = []
            args = []
            for n in names:
                v = env[n]
                if n in bagnames:
                    in_specs.append(tuple(P(self.dp) for _ in v))
                else:
                    in_specs.append(P() if not isinstance(v, tuple)
                                    else tuple(P() for _ in v))
                args.append(v)

            dest_shapes = tuple(jnp.shape(env[d]) for d in dests)
            dest_dtypes = tuple(jnp.asarray(env[d]).dtype for d in dests)
            node_lims = {b: limits[b] for b in bagnames if b in limits}

            def local_fn(*vals, _parts=parts, _names=tuple(names),
                         _bags=tuple(bagnames), _lims=node_lims, _dims=dims,
                         _shapes=dest_shapes, _dtypes=dest_dtypes):
                e2 = dict(zip(_names, vals))
                e2.update(_dims)
                for p, shp, dt in zip(_parts, _shapes, _dtypes):
                    e2[p.dest] = jnp.full(shp, identity(p.op, dt))
                # globalize bag indexes: shard-local row r is global
                # offset + r (needed when the bag index appears in keys)
                shard = 0
                for a in self.dp:
                    shard = shard * self.mesh.shape[a] + jax.lax.axis_index(a)
                offs = {b: shard * e2[b][0].shape[0] for b in _bags}
                ctx = ExecContext(bag_offsets=offs, bag_limits=_lims)
                return tuple(
                    self._psum(cp.executor.run_node(p, e2, ctx), p.op)
                    for p in _parts)

            fn = shard_map(local_fn, mesh=self.mesh,
                           in_specs=tuple(in_specs),
                           out_specs=tuple(P() for _ in parts))
            partials = fn(*args)
            for d, op, partial in zip(dests, ops, partials):
                env[d] = COMBINE[op](jnp.asarray(env[d]), partial)

    # ------------------------- entry -------------------------
    def run(self, inputs: dict) -> dict:
        env = {}
        placed, limits = self.place(inputs)
        for name, t in self.cp.program.params.items():
            v = placed[name]
            if t.kind in ("vector", "matrix", "map"):
                env[name] = jnp.asarray(
                    v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = v
        if self.mode == "gspmd":
            self.cp.execute(env, bag_limits=limits)
        else:
            self._exec_shardmap(self.cp.plan, env, limits)
        return {n: env[n] for n in self.cp.program.outputs}


def compile_distributed(fn_or_prog, mesh, dp_axes=("data",),
                        mode: str = "shardmap", **kw) -> DistributedProgram:
    from .lower import compile_program
    cp = fn_or_prog if isinstance(fn_or_prog, CompiledProgram) \
        else compile_program(fn_or_prog, **kw)
    return DistributedProgram(cp, mesh, dp_axes, mode)

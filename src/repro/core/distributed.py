"""Distributed execution of compiled loop programs over a device mesh —
the paper's DISC backend, retargeted from Spark shuffles to TPU collectives
(DESIGN.md §2).

Two modes:

* ``shardmap`` (paper-faithful operator mapping): bags are sharded over the
  dp axes; every bulk aggregation whose iteration space is bag-driven runs
  as  *local segment-⊕ partials → psum*  under `jax.shard_map` — the
  reduction-based replacement for the paper's shuffle-based group-by.
  Dense arrays are replicated (the paper's "broadcast small arrays to all
  workers" future-work optimization, here the default: index spaces are
  bounded).  Statements without bag generators execute replicated (identical
  on all shards).

* ``gspmd``: the single-device lowering jitted with sharded inputs; XLA's
  SPMD partitioner inserts the collectives.  Works for every program,
  including range-driven contractions (matmul → partitioned einsum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .comprehension import (BagGen, BulkStore, BulkUpdate, ScalarAgg,
                            ScalarAssign, SeqWhile)
from .lower import CompiledProgram, _identity, _COMBINE


def _has_bag(quals) -> bool:
    return any(isinstance(q, BagGen) for q in quals)


class DistributedProgram:
    def __init__(self, cp: CompiledProgram, mesh, dp_axes=("data",),
                 mode: str = "shardmap"):
        self.cp = cp
        self.mesh = mesh
        self.dp = tuple(dp_axes)
        self.mode = mode
        self.dp_n = 1
        for a in self.dp:
            self.dp_n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    # ------------------------- input placement -------------------------
    def place(self, inputs: dict) -> dict:
        out = {}
        for name, t in self.cp.program.params.items():
            v = inputs[name]
            if t.kind == "bag":
                cols = v if isinstance(v, tuple) else (v,)
                cols = tuple(jnp.asarray(c) for c in cols)
                n = cols[0].shape[0]
                spec = P(self.dp) if n % self.dp_n == 0 else P()
                out[name] = tuple(
                    jax.device_put(c, NamedSharding(self.mesh, spec))
                    for c in cols)
            elif t.kind == "dim":
                out[name] = int(v)
            else:
                arr = jnp.asarray(v)
                out[name] = jax.device_put(
                    arr, NamedSharding(self.mesh, P()))  # broadcast join
        return out

    # ------------------------- shardmap mode -------------------------
    def _exec_shardmap(self, stmts, env):
        low = self.cp._low
        for st in stmts:
            if isinstance(st, SeqWhile):
                # sequential driver; body statements distributed recursively
                def cond(env=env, st=st):
                    from .lower import Axes
                    return bool(low.eval(st.cond, env, Axes(), {}, []))
                while cond():
                    self._exec_shardmap(st.body, env)
                continue

            bag_driven = isinstance(st, (BulkUpdate, ScalarAgg)) and \
                _has_bag(st.quals)
            if not bag_driven:
                # replicated execution (identical result on all shards)
                self.cp._exec([st], env)
                continue

            # local partial ⊕ over the bag shard, then psum over dp
            names = sorted(self._refs(st) - {st.dest})
            bagnames = [q.bag for q in st.quals if isinstance(q, BagGen)]
            in_specs = []
            args = []
            for n in names:
                v = env[n]
                if n in bagnames:
                    in_specs.append(tuple(P(self.dp) for _ in v))
                else:
                    in_specs.append(P() if not isinstance(v, tuple)
                                    else tuple(P() for _ in v))
                args.append(v)

            dest = env[st.dest]
            dest_shape = jnp.shape(dest)
            op = st.op

            def local_fn(*vals, _st=st, _names=names, _bags=tuple(bagnames)):
                e2 = dict(zip(_names, vals))
                ident = _identity(op, jnp.asarray(dest).dtype)
                e2[_st.dest] = jnp.full(dest_shape, ident)
                # globalize bag indexes: shard-local row r is global
                # offset + r (needed when the bag index appears in keys)
                shard = 0
                for a in self.dp:
                    shard = shard * self.mesh.shape[a] + jax.lax.axis_index(a)
                offs = {}
                for b in _bags:
                    n_loc = e2[b][0].shape[0]
                    offs[b] = shard * n_loc
                old = low.bag_offset
                low.bag_offset = offs
                try:
                    if isinstance(_st, ScalarAgg):
                        part = low.lower_scalar_agg(_st, e2)
                    else:
                        part = low.lower_update(_st, e2)
                finally:
                    low.bag_offset = old
                if op == "+":
                    return jax.lax.psum(part, self.dp)
                if op == "min":
                    return -jax.lax.pmax(-part, self.dp)
                if op == "max":
                    return jax.lax.pmax(part, self.dp)
                raise NotImplementedError(op)

            fn = jax.shard_map(local_fn, mesh=self.mesh,
                               in_specs=tuple(in_specs),
                               out_specs=P())
            partial = fn(*args)
            env[st.dest] = _COMBINE[op](jnp.asarray(dest), partial)

    def _refs(self, st) -> set[str]:
        """Names of env values a statement reads."""
        from .comprehension import Get, RangeGen
        from .loop_ast import BinOp, Call, Index, UnOp, Var
        names: set[str] = set()

        def ge(e):
            if isinstance(e, (Get, Index)):
                names.add(e.array)
                for i in e.idxs:
                    ge(i)
            elif isinstance(e, BinOp):
                ge(e.lhs)
                ge(e.rhs)
            elif isinstance(e, UnOp):
                ge(e.e)
            elif isinstance(e, Call):
                for a in e.args:
                    ge(a)
            elif isinstance(e, Var):
                names.add(e.name)
        for q in st.quals:
            if isinstance(q, BagGen):
                names.add(q.bag)
            elif isinstance(q, RangeGen):
                ge(q.lo)
                ge(q.hi)
            else:
                ge(q.e)
        ge(st.value)
        if hasattr(st, "keys"):
            for k in st.keys:
                ge(k)
        # loop vars shadow env names
        for q in st.quals:
            if isinstance(q, BagGen):
                names -= set(q.vals) | {q.idx}
            elif isinstance(q, RangeGen):
                names -= {q.var}
        return {n for n in names if n in self.cp.program.params
                or n in self.cp.program.outputs}

    # ------------------------- entry -------------------------
    def run(self, inputs: dict) -> dict:
        env = {}
        placed = self.place(inputs)
        for name, t in self.cp.program.params.items():
            v = placed[name]
            if t.kind in ("vector", "matrix", "map"):
                env[name] = jnp.asarray(
                    v, jnp.float32 if t.dtype == "float" else jnp.int32)
            else:
                env[name] = v
        if self.mode == "gspmd":
            self.cp._exec(self.cp.target, env)
        else:
            self._exec_shardmap(self.cp.target, env)
        return {n: env[n] for n in self.cp.program.outputs}


def compile_distributed(fn_or_prog, mesh, dp_axes=("data",),
                        mode: str = "shardmap", **kw) -> DistributedProgram:
    from .lower import compile_program
    cp = fn_or_prog if isinstance(fn_or_prog, CompiledProgram) \
        else compile_program(fn_or_prog, **kw)
    return DistributedProgram(cp, mesh, dp_axes, mode)

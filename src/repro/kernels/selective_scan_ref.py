"""Pure-jnp oracle for selective_scan (matches models/ssm.py math)."""
import jax
import jax.numpy as jnp


def selective_scan_ref(a, bx, c):
    """a, bx: [B,S,D,N]; c: [B,S,N] -> y [B,S,D]."""
    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a = a.astype(jnp.float32)
    bx = bx.astype(jnp.float32)
    _, h = jax.lax.associative_scan(assoc, (a, bx), axis=1)
    return jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))

"""Public jit'd wrappers over the Pallas kernels.

`interpret` defaults to True off-TPU (the container is CPU-only; Pallas
kernels are authored for TPU and validated in interpret mode against the
pure-jnp oracles in *_ref.py)."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .selective_scan import selective_scan as _selscan
from .segment_reduce import segment_reduce as _segred
from .segment_reduce import segment_sum as _segsum
from .tile_matmul import tile_matmul as _tilemm


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def segment_sum(ids, values, num_segments: int, **kw):
    kw.setdefault("interpret", _interp())
    return _segsum(ids, values, num_segments, **kw)


def segment_reduce(ids, values, num_segments: int, *, op: str = "+", **kw):
    kw.setdefault("interpret", _interp())
    return _segred(ids, values, num_segments, op=op, **kw)


def tile_matmul(a, b, tile_mask=None, **kw):
    kw.setdefault("interpret", _interp())
    return _tilemm(a, b, tile_mask=tile_mask, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interp())
    return _flash(q, k, v, **kw)


def selective_scan(a, bx, c, **kw):
    kw.setdefault("interpret", _interp())
    return _selscan(a, bx, c, **kw)

from .ops import flash_attention, segment_sum, selective_scan, tile_matmul

__all__ = ["segment_sum", "tile_matmul", "flash_attention", "selective_scan"]

"""Pallas TPU kernel: causal flash attention (online softmax).

Grid: (batch*heads, Sq/bq).  Each step holds one query tile and the full
K/V for its (batch, head) in VMEM, scanning K/V in [bk] chunks with the
running (max, sum, acc) online-softmax state — O(bq * hd) live state, no
[Sq, Sk] score materialization.  Used by the LM stack as the TPU target of
`attention_core` (the jnp chunked path is the dry-run/interpret fallback).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # [bq, hd]
    k_full = k_ref[0]                                     # [Sk, hd]
    v_full = v_ref[0]
    sk = k_full.shape[0]
    nk = sk // bk
    hd = q.shape[-1]

    def body(j, carry):
        m_i, l_i, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k_full, j * bk, bk, 0)
        vc = jax.lax.dynamic_slice_in_dim(v_full, j * bk, bk, 0)
        s = jax.lax.dot_general(q, kc.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, vc.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m_i, l_i, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bk: int = 128, causal: bool = True,
                    interpret: bool = True) -> jax.Array:
    """q: [BH, Sq, hd]; k, v: [BH, Sk, hd] -> [BH, Sq, hd]."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal, scale=scale),
        grid=(bh, sq // bq),
        in_specs=[pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)

"""Pallas TPU kernel: segment-sum via one-hot MXU matmul.

This is the TPU-native form of the paper's group-by-⊕: instead of a shuffle
(Spark) or a scatter (GPU), each [bn] block of segment ids becomes a
[bn, bk] one-hot matrix that multiplies the [bn, bd] value block on the
MXU — group-by as matrix multiplication.  Out-of-range ids contribute
nothing (drop semantics, matching the ◁ merge).

Grid: (K/bk, D/bd, N/bn), N innermost so each output tile accumulates
across value blocks in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, val_ref, out_ref, *, bk: int):
    k = pl.program_id(0)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                    # [bn]
    vals = val_ref[...].astype(jnp.float32)               # [bn, bd]
    seg0 = k * bk
    onehot = (ids[:, None] == (seg0 + jax.lax.broadcasted_iota(
        jnp.int32, (1, bk), 1))).astype(jnp.float32)      # [bn, bk]
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # [bk, bd]


@functools.partial(jax.jit, static_argnames=("num_segments", "bn", "bk",
                                             "bd", "interpret"))
def segment_sum(ids: jax.Array, values: jax.Array, num_segments: int,
                *, bn: int = 256, bk: int = 128, bd: int = 128,
                interpret: bool = True) -> jax.Array:
    """ids: [N] int32; values: [N, D] -> [num_segments, D] float32."""
    n, d = values.shape
    bn = min(bn, n)
    bk = min(bk, num_segments)
    bd = min(bd, d)
    # pad to block multiples; padded rows get id = num_segments (dropped)
    np_ = -(-n // bn) * bn
    kp = -(-num_segments // bk) * bk
    dp = -(-d // bd) * bd
    ids_p = jnp.full((np_,), kp, jnp.int32).at[:n].set(ids.astype(jnp.int32))
    vals_p = jnp.zeros((np_, dp), values.dtype).at[:n, :d].set(values)

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(kp // bk, dp // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn,), lambda k, dd, nn: (nn,)),
            pl.BlockSpec((bn, bd), lambda k, dd, nn: (nn, dd)),
        ],
        out_specs=pl.BlockSpec((bk, bd), lambda k, dd, nn: (k, dd)),
        out_shape=jax.ShapeDtypeStruct((kp, dp), jnp.float32),
        interpret=interpret,
    )(ids_p, vals_p)
    return out[:num_segments, :d]

"""Pallas TPU kernel: segment-⊕ via one-hot MXU matmul / one-hot select.

This is the TPU-native form of the paper's group-by-⊕: instead of a shuffle
(Spark) or a scatter (GPU), each [bn] block of segment ids becomes a
[bn, bk] one-hot matrix.  For ⊕ = + the one-hot multiplies the [bn, bd]
value block on the MXU — group-by as matrix multiplication; for ⊕ = min/max
the one-hot SELECTS into a [bn, bk, bd] identity-filled block that reduces
over rows on the VPU (size bn·bk·bd·4 bytes must fit VMEM — shrink the
blocks for large bd).  Out-of-range ids ([num_segments, ∞) and negatives)
contribute nothing (drop semantics, matching the ◁ merge): padded rows get
id = Kp which no k-block matches, and ids in [num_segments, Kp) land in
output rows that are sliced off.

Values may be [N] (returns [num_segments]) or [N, D] (returns
[num_segments, D]).  Integer values accumulate on an EXACT integer path
(int32 one-hot × int32 values with preferred_element_type=int32 — no fp32
rounding); floating values accumulate in float32.  The returned dtype is
the accumulator's (int32 / float32).

Grid: (K/bk, D/bd, N/bn), N innermost so each output tile accumulates
across value blocks in VMEM.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_IDENTITY = {"+": 0.0, "min": np.inf, "max": -np.inf}


def _int_identity(op: str) -> int:
    if op == "+":
        return 0
    big = jnp.iinfo(jnp.int32).max
    return big if op == "min" else -big


def _kernel(ids_ref, val_ref, out_ref, *, bk: int, op: str, acc):
    k = pl.program_id(0)
    n = pl.program_id(2)
    ident = jnp.asarray(_int_identity(op) if acc == jnp.int32
                        else _IDENTITY[op], acc)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    ids = ids_ref[...]                                    # [bn]
    vals = val_ref[...].astype(acc)                       # [bn, bd]
    seg0 = k * bk
    hit = ids[:, None] == (seg0 + jax.lax.broadcasted_iota(
        jnp.int32, (1, bk), 1))                           # [bn, bk]
    if op == "+":
        out_ref[...] += jax.lax.dot_general(
            hit.astype(acc), vals, (((0,), (0,)), ((), ())),
            preferred_element_type=acc)                   # [bk, bd]
    else:
        # one-hot select: rows not in this segment carry the ⊕ identity,
        # then reduce over the row axis and merge into the accumulator
        sel = jnp.where(hit[:, :, None], vals[:, None, :],
                        ident)                            # [bn, bk, bd]
        red = jnp.min if op == "min" else jnp.max
        comb = jnp.minimum if op == "min" else jnp.maximum
        out_ref[...] = comb(out_ref[...], red(sel, axis=0))


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "bn",
                                             "bk", "bd", "interpret"))
def segment_reduce(ids: jax.Array, values: jax.Array, num_segments: int,
                   *, op: str = "+", bn: int = 256, bk: int = 128,
                   bd: int = 128, interpret: bool = True) -> jax.Array:
    """ids: [N] int; values: [N] or [N, D] -> [num_segments(, D)].
    op ∈ {"+", "min", "max"}.  Integer values take the exact-int path
    (int32 accumulation); floats accumulate in float32."""
    if op not in ("+", "min", "max"):
        raise ValueError(f"segment_reduce: unsupported op {op!r}")
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, d = values.shape
    acc = jnp.int32 if jnp.issubdtype(values.dtype, jnp.integer) \
        else jnp.float32
    bn = min(bn, n)
    bk = min(bk, num_segments)
    bd = min(bd, d)
    # pad to block multiples; padded rows get id = Kp (matches no k block)
    np_ = -(-n // bn) * bn
    kp = -(-num_segments // bk) * bk
    dp = -(-d // bd) * bd
    ident = jnp.asarray(_int_identity(op) if acc == jnp.int32
                        else _IDENTITY[op], acc)
    ids32 = ids.astype(jnp.int32)
    values = values.astype(acc)
    if op == "+":
        # dropped rows (id < 0 or ≥ num_segments) hit an all-zero one-hot
        # row, but 0 × inf/NaN would still contaminate the MXU dot — zero
        # their values so they contribute nothing regardless of content
        # (min/max use a pure select, which never multiplies)
        keep = (ids32 >= 0) & (ids32 < num_segments)
        values = jnp.where(keep[:, None], values, jnp.zeros((), acc))
    ids_p = jnp.full((np_,), kp, jnp.int32).at[:n].set(ids32)
    vals_p = jnp.full((np_, dp), ident, acc).at[:n, :d].set(values)

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, op=op, acc=acc),
        grid=(kp // bk, dp // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn,), lambda k, dd, nn: (nn,)),
            pl.BlockSpec((bn, bd), lambda k, dd, nn: (nn, dd)),
        ],
        out_specs=pl.BlockSpec((bk, bd), lambda k, dd, nn: (k, dd)),
        out_shape=jax.ShapeDtypeStruct((kp, dp), acc),
        interpret=interpret,
    )(ids_p, vals_p)
    out = out[:num_segments, :d]
    return out[:, 0] if squeeze else out


def segment_sum(ids: jax.Array, values: jax.Array, num_segments: int,
                *, bn: int = 256, bk: int = 128, bd: int = 128,
                interpret: bool = True) -> jax.Array:
    """ids: [N] int32; values: [N, D] -> [num_segments, D] float32.
    Kept as the historical fp32 entry point; `segment_reduce` is the
    general (dtype-preserving, [N]-or-[N,D], min/max-capable) form."""
    return segment_reduce(ids, values.astype(jnp.float32), num_segments,
                          op="+", bn=bn, bk=bk, bd=bd, interpret=interpret)

"""Pure-jnp oracles for the segment_reduce kernel."""
import jax
import jax.numpy as jnp

_SCATTER = {"+": "add", "min": "min", "max": "max"}


def segment_reduce_ref(ids, values, num_segments: int, op: str = "+"):
    """Same contract as kernels.segment_reduce: [N] or [N, D] values,
    exact-int accumulation for integer dtypes, f32 for floats, paper
    empty-bag semantics (negative AND ≥ num_segments ids drop)."""
    ids = ids.astype(jnp.int32)
    # negative ids DROP (numpy-style .at[] would wrap them to the end)
    ids = jnp.where(ids < 0, num_segments, ids)
    acc = jnp.int32 if jnp.issubdtype(values.dtype, jnp.integer) \
        else jnp.float32
    vals = values.astype(acc)
    if op == "+":
        init = jnp.zeros((), acc)
    else:
        big = jnp.iinfo(acc).max if acc == jnp.int32 else jnp.inf
        init = jnp.asarray(big if op == "min" else -big, acc)
    out = jnp.full((num_segments,) + vals.shape[1:], init, acc)
    return getattr(out.at[ids], _SCATTER[op])(vals, mode="drop")


def segment_sum_ref(ids, values, num_segments: int):
    return segment_reduce_ref(ids, values.astype(jnp.float32), num_segments,
                              op="+")

"""Pure-jnp oracle for the segment_sum kernel."""
import jax
import jax.numpy as jnp


def segment_sum_ref(ids, values, num_segments: int):
    ids = ids.astype(jnp.int32)
    # paper empty-bag semantics: negative ids DROP (numpy-style .at[] would
    # wrap them to the end)
    ids = jnp.where(ids < 0, num_segments, ids)
    vals = values.astype(jnp.float32)
    out = jnp.zeros((num_segments,) + vals.shape[1:], jnp.float32)
    return out.at[ids].add(vals, mode="drop")

"""Pallas TPU kernel: chunked selective-scan (Mamba-1 inner recurrence).

h_t = a_t ⊙ h_{t-1} + b_t ;  y_t = Σ_N c_t ⊙ h_t

Grid: (B, D/bd); each step owns a [bd, N] state slice in VMEM and walks the
sequence in [bk]-step chunks with a fori_loop — the state never leaves
VMEM, matching how the reference CUDA kernel keeps state in registers
(HBM traffic is O(S·(bd + N)) instead of O(S·bd·N) for the materialized
jnp path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, bx_ref, c_ref, y_ref, *, bk: int):
    # a, bx: [1, S, bd, N]; c: [1, S, N]; y: [1, S, bd]
    s = a_ref.shape[1]
    bd, n = a_ref.shape[2], a_ref.shape[3]
    a_full = a_ref[0]
    bx_full = bx_ref[0]
    c_full = c_ref[0]

    def chunk(j, h):
        aj = jax.lax.dynamic_slice_in_dim(a_full, j * bk, bk, 0)
        bj = jax.lax.dynamic_slice_in_dim(bx_full, j * bk, bk, 0)
        cj = jax.lax.dynamic_slice_in_dim(c_full, j * bk, bk, 0)

        def step(t, carry):
            h_in, ys = carry
            h_new = aj[t] * h_in + bj[t]                 # [bd, N]
            y = jnp.sum(h_new * cj[t][None, :], axis=1)  # [bd]
            ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, 0)
            return h_new, ys

        h, ys = jax.lax.fori_loop(0, bk, step,
                                  (h, jnp.zeros((bk, bd), jnp.float32)))
        y_ref[0, pl.dslice(j * bk, bk), :] = ys
        return h

    h0 = jnp.zeros((bd, n), jnp.float32)
    jax.lax.fori_loop(0, s // bk, chunk, h0)


@functools.partial(jax.jit, static_argnames=("bd", "bk", "interpret"))
def selective_scan(a: jax.Array, bx: jax.Array, c: jax.Array, *,
                   bd: int = 128, bk: int = 64,
                   interpret: bool = True) -> jax.Array:
    """a, bx: [B, S, D, N] (discretized decay / input); c: [B, S, N].
    Returns y: [B, S, D] with y_t = Σ_N c_t ⊙ h_t, h_t = a_t h_{t-1} + b_t."""
    b, s, d, n = a.shape
    bd = min(bd, d)
    bk = min(bk, s)
    assert d % bd == 0 and s % bk == 0, (d, bd, s, bk)

    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(b, d // bd),
        in_specs=[
            pl.BlockSpec((1, s, bd, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, bd, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), bx.astype(jnp.float32), c.astype(jnp.float32))

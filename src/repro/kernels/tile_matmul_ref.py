"""Pure-jnp oracle for tile_matmul."""
import jax.numpy as jnp


def tile_matmul_ref(a, b, tile_mask=None, bm: int = 128, bk: int = 128):
    a = a.astype(jnp.float32)
    if tile_mask is not None:
        mt, kt = tile_mask.shape
        mask = jnp.repeat(jnp.repeat(tile_mask.astype(jnp.float32), bm, 0),
                          bk, 1)[:a.shape[0], :a.shape[1]]
        a = a * mask
    return a @ b.astype(jnp.float32)

"""Pure-jnp oracle for flash_attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)

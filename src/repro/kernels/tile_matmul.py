"""Pallas TPU kernel: tiled matmul (paper §5 packed arrays).

C[M,N] = A[M,K] @ B[K,N] with MXU-aligned [bm, bk] x [bk, bn] tiles and
fp32 accumulation in the revisited output tile (grid (m, n, k), k
innermost).  `tile_mask` supports block-sparse tiled matrices: a zero mask
tile contributes nothing (multiplied out — a TPU grid cannot skip blocks
dynamically without scalar prefetch, so this kernel masks; the sparsity
win on TPU is the *pack* step producing fewer tiles, see core/tiles.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _masked_kernel(mask_ref, a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[0, 0].astype(jnp.float32)
    out_ref[...] += m * jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def tile_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 128, tile_mask: jax.Array | None = None,
                interpret: bool = True) -> jax.Array:
    """a: [M,K]; b: [K,N] -> [M,N] fp32.  tile_mask: [M/bm, K/bk] optional."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = (-(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk)
    ap = jnp.zeros((mp, kp), a.dtype).at[:m, :k].set(a)
    bp = jnp.zeros((kp, np_), b.dtype).at[:k, :n].set(b)
    grid = (mp // bm, np_ // bn, kp // bk)

    if tile_mask is None:
        out = pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                      pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(ap, bp)
    else:
        out = pl.pallas_call(
            _masked_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),
                      pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                      pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(tile_mask.astype(jnp.float32), ap, bp)
    return out[:m, :n]

"""AdamW with fp32 moments, global-norm clipping, cosine schedule.

Moments are stored fp32 regardless of param dtype (bf16 training).  An
optional gradient-compression hook (bf16 cast pre-all-reduce) is applied by
the train step, not here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # scalar int32
    mu: object                # pytree like params, fp32
    nu: object                # pytree like params, fp32


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(z, params), jax.tree.map(z, params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, max_norm=1.0):
    """Returns (new_params, new_state, metrics). grads may be bf16; math fp32."""
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype  # bf16 moments supported (cfg.opt_dtype)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / b1t
        vh = v32 / b2t
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr_t}

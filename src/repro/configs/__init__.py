from .base import (ModelConfig, ShapeConfig, SHAPES, get_config, list_archs,
                   register, smoke_config)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "list_archs",
           "register", "smoke_config"]

"""Import all architecture configs (populates the registry)."""
from . import (arctic_480b, falcon_mamba_7b, llama3_8b, minitron_4b,  # noqa: F401
               phi3_medium_14b, qwen2_72b, qwen2_vl_72b, qwen3_moe_30b_a3b,
               recurrentgemma_2b, whisper_tiny)

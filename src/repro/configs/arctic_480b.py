"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
— MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        d_model=7168,
        vocab_size=32000,
        layout=((("moe",), 35),),
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,                   # dense residual MLP (runs alongside MoE)
        moe_d_ff=4864,
        num_experts=128,
        top_k=2,
        dense_residual=True,
        rope_theta=1e6,
        microbatch=8,            # §Perf: 145->32 GB/chip (512-chip pod fits)
        opt_dtype="bf16",        # §Perf: halves the Adam-moment floor
        attn_chunk=512,
    )

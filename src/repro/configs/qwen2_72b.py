"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias [arXiv:2407.10671]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        family="dense",
        d_model=8192,
        vocab_size=152064,
        layout=((("dense",), 80),),
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        qkv_bias=True,
        rope_theta=1e6,
        microbatch=4,            # §Perf: fits 16 GB/chip (31->15 GB)
    )

"""Model/arch configuration and the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

# layout entry kinds: "dense" (attn+SwiGLU), "moe" (attn+MoE),
# "ssm" (mamba), "rec" (RG-LRU+MLP), "lattn" (local-window attn+MLP)
Layout = tuple[tuple[tuple[str, ...], int], ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    layout: Layout
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    pos_embed: str = "rope"          # rope | sinusoidal | none
    window: int = 0                  # local attention window
    mrope_sections: tuple[int, ...] = ()
    scale_embed: bool = False
    logits_softcap: float = 0.0
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False
    capacity_factor: float = 1.25
    # ssm / recurrent
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0
    lru_width: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # numerics / perf knobs
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    scan_chunk: int = 256
    ce_chunk: int = 512              # tokens per chunk in the fused CE loss
    remat: str = "full"              # full | dots | none
    # perf knobs (hillclimb; see EXPERIMENTS.md §Perf)
    shard_embed_vocab: bool = True   # False: replicate vocab rows of the
    #   embedding table (kills the one-hot-matmul lowering of sharded gathers)
    fsdp_experts: bool = True        # False: EP-only expert weights (no
    #   per-layer all-gather of expert shards over `data`)
    microbatch: int = 1              # gradient-accumulation factor: peak
    #   activation memory scales ~1/k at identical math (fp32 accumulators)
    opt_dtype: str = "f32"           # "bf16": half-size Adam moments
    sp_attn: bool = True             # SP fallback when heads don't divide
    #   the model axis (False = initial heads-or-nothing layout)

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def num_layers(self) -> int:
        return sum(len(pat) * reps for pat, reps in self.layout)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, Any] = {}


def register(fn):
    """Decorator: registers `fn() -> ModelConfig` under the config name."""
    cfg = fn()
    _REGISTRY[cfg.name] = cfg
    return fn


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import archs  # noqa: F401  (populates the registry)


def smoke_config(name: str) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    cfg = get_config(name)
    shrink = dict(
        d_model=64, d_ff=128, num_heads=4, num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16, vocab_size=256, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, cache_dtype=jnp.float32,
        attn_chunk=32, scan_chunk=8, microbatch=1,
    )
    if cfg.num_experts:
        shrink.update(num_experts=4, top_k=2, moe_d_ff=64)
    if cfg.ssm_state:
        shrink.update(ssm_state=4, ssm_dt_rank=8)
    if cfg.lru_width:
        shrink.update(lru_width=64)
    if cfg.window:
        shrink.update(window=16)
    if cfg.enc_layers:
        shrink.update(enc_layers=2, enc_seq=16)
    if cfg.mrope_sections:
        shrink.update(mrope_sections=(2, 3, 3))  # sums to head_dim/2 = 8
    # shrink the layout to ~one period + leftovers
    layout = tuple((pat, min(reps, 2)) for pat, reps in cfg.layout[:2])
    return cfg.replace(layout=layout, **shrink)

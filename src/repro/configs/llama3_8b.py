"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        d_model=4096,
        vocab_size=128256,
        layout=((("dense",), 32),),
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        rope_theta=5e5,
        attn_chunk=2048,         # §Perf: -13% HBM traffic at equal memory
    )

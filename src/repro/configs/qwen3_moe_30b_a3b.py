"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936 — MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        d_model=2048,
        vocab_size=151936,
        layout=((("moe",), 48),),
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=0,                      # no dense FFN: MoE only
        moe_d_ff=768,
        num_experts=128,
        top_k=8,
        rope_theta=1e6,
    )

"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536
vocab=51865 — encoder-decoder; conv frontend is a STUB (input_specs()
provides precomputed frame embeddings [B, enc_seq, d]) [arXiv:2212.04356].

Positional embeddings are sinusoidal on both sides (the reference decoder
uses a learned 448-slot table; sinusoidal generalizes to the stress shapes
— adaptation noted in DESIGN.md).
"""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        d_model=384,
        vocab_size=51865,
        layout=((("dec",), 4),),
        enc_layers=4,
        enc_seq=1500,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        pos_embed="sinusoidal",
        microbatch=2,            # §Perf: big-batch tiny-model memory
    )

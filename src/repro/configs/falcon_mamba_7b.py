"""falcon-mamba-7b [ssm]: 64L d_model=4096, attn-free, vocab=65024,
ssm_state=16 — Mamba-1 architecture [arXiv:2410.05355]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        d_model=4096,
        vocab_size=65024,
        layout=((("ssm",), 64),),
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        ssm_dt_rank=256,            # ceil(d_model / 16)
        pos_embed="none",
    )

"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only per assignment: the vision frontend is a STUB — input_specs()
supplies M-RoPE position ids [B, S, 3] (temporal/height/width) as if
produced by the patch-embedding pipeline.
"""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        d_model=8192,
        vocab_size=152064,
        layout=((("dense",), 80),),
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        qkv_bias=True,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),  # sums to head_dim/2
        microbatch=4,            # §Perf: fits 16 GB/chip
    )

"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1:2 ratio [arXiv:2402.19427].

Layout: (rec, rec, lattn) x 8 periods + (rec, rec) leftover = 26 layers.
"""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        d_model=2560,
        vocab_size=256000,
        layout=(
            (("rec", "rec", "lattn"), 8),
            (("rec", "rec"), 1),
        ),
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        window=2048,
        lru_width=2560,
        ssm_conv=4,
        rope_theta=1e4,
        scale_embed=True,
        logits_softcap=30.0,
        microbatch=2,            # §Perf: fits 16 GB/chip
    )

"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from .base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        d_model=5120,
        vocab_size=100352,
        layout=((("dense",), 40),),
        num_heads=40,
        num_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        rope_theta=1e4,
        microbatch=2,            # §Perf: fits 16 GB/chip
    )

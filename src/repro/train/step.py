"""Training step: loss -> grads -> AdamW.

Distributed-optimization features:
* optional bf16 gradient compression (grads cast before the XLA-inserted
  cross-`pod` all-reduce, halving DCN bytes);
* gradient-accumulation microbatching (cfg.microbatch): an inner lax.scan
  over batch slices with fp32 grad accumulators — peak activation memory
  scales ~1/k at identical math (the fix that brings the 70B-class train
  cells under the 16 GB/chip budget, see EXPERIMENTS.md §Perf);
* optional bf16 Adam moments (cfg.opt_dtype) for optimizer-state memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import get_model
from ..optim.adamw import adamw_update


def make_train_step(cfg, mesh=None, dp_axes=("data",), lr=3e-4,
                    compress_grads=True, weight_decay=0.1):
    model = get_model(cfg)
    k = max(1, cfg.microbatch)

    def loss_fn(p, batch):
        loss, metrics = model.loss(p, batch, mesh, dp_axes)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_fn, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss / k
            metrics = {"loss": loss}
        if compress_grads:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16) if g.dtype == jnp.float32
                else g, grads)
        new_params, new_opt, om = adamw_update(params, grads, opt_state,
                                               lr=lr, weight_decay=weight_decay)
        return new_params, new_opt, {**metrics, **om}

    return train_step

"""Render failure-ledger goldens for the chaos CI artifact.

Drives the compiled group_by program and a small PlanServer through one
scripted scenario per degradation-ladder level (DESIGN.md §11) and
writes every ``explain_faults()`` / ``explain_serving()`` rendering to
the path given on the command line.  The artifact makes ledger-text
regressions diffable across CI runs without re-running the job.

  PYTHONPATH=src python tools/fault_goldens.py FAULT_ledgers.txt
"""
from __future__ import annotations

import sys

import numpy as np


def _inputs(seed=0, n=40):
    r = np.random.default_rng(seed)
    return dict(S=(r.integers(0, 10, n).astype(np.float64),
                   r.standard_normal(n)), C=np.zeros(10))


def _fresh_cp():
    from repro.core import compile_program
    from repro.core.programs import ALL
    return compile_program(ALL["group_by"])


def scenarios():
    from repro.core import faults as F

    def clean():
        cp = _fresh_cp()
        cp.run(_inputs())
        return cp.explain_faults()

    def transient_retry():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "transient", nth=1)):
            cp.run(_inputs())
        return cp.explain_faults()

    def deterministic_descent():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "deterministic",
                                  nth=1)):
            cp.run(_inputs())
        return cp.explain_faults()

    def capacity_chunked():
        from repro.core import compile_program
        from repro.core.programs import ALL
        cp = compile_program(ALL["group_by"], op_select="force:scatter")
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "capacity", nth=1,
                                  times=10 ** 4)):
            cp.run(_inputs())
        return cp.explain_faults() + "\n" + cp.explain_chunked()

    def interp_oracle():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.node", "transient", nth=1,
                                  times=10 ** 4)):
            cp.run(_inputs())
        return cp.explain_faults()

    def serve_chaos():
        from repro.serve import PlanServer
        srv = PlanServer({"group_by": _fresh_cp()}, max_batch=8)
        srv.faults.sleep = lambda s: None
        srv.policy.backoff_s = 0.0
        specs = [F.FaultSpec("serve.batched_call", "transient", nth=1),
                 F.FaultSpec("serve.batched_call", "deterministic",
                             rid=3, times=10 ** 4),
                 F.FaultSpec("serve.stack", "poison", rid=5,
                             times=10 ** 4)]
        ts = [srv.submit("group_by", _inputs(i)) for i in range(8)]
        with F.inject(*specs):
            srv.drain()
        states = ",".join(t.state for t in ts)
        return (srv.explain_serving() + "\n" + srv.explain_faults()
                + f"\nticket states: {states}")

    return [("clean run (no faults)", clean),
            ("transient at lower.whole_trace: retried in place",
             transient_retry),
            ("deterministic at lower.whole_trace: one descent to eager",
             deterministic_descent),
            ("capacity at lower.whole_trace: out-of-core chunked rung",
             capacity_chunked),
            ("persistent transient at lower.node: interpreter oracle",
             interp_oracle),
            ("serve chaos: retry + bisection + poisoned lane",
             serve_chaos)]


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "FAULT_ledgers.txt"
    chunks = []
    for title, fn in scenarios():
        chunks.append(f"=== {title} ===\n{fn()}\n")
    text = "\n".join(chunks)
    with open(out, "w") as f:
        f.write(text)
    print(text)
    print(f"[fault_goldens] wrote {out}")


if __name__ == "__main__":
    sys.path.insert(0, "src")
    main()

"""Render failure-ledger goldens for the chaos CI artifact.

Drives the compiled group_by program and a small PlanServer through one
scripted scenario per degradation-ladder level (DESIGN.md §11) and
writes every ``explain_faults()`` / ``explain_serving()`` rendering to
the path given on the command line.  The artifact makes ledger-text
regressions diffable across CI runs without re-running the job.

  PYTHONPATH=src python tools/fault_goldens.py FAULT_ledgers.txt
"""
from __future__ import annotations

import sys

import numpy as np


def _inputs(seed=0, n=40):
    r = np.random.default_rng(seed)
    return dict(S=(r.integers(0, 10, n).astype(np.float64),
                   r.standard_normal(n)), C=np.zeros(10))


def _fresh_cp():
    from repro.core import compile_program
    from repro.core.programs import ALL
    return compile_program(ALL["group_by"])


def scenarios():
    from repro.core import faults as F

    def clean():
        cp = _fresh_cp()
        cp.run(_inputs())
        return cp.explain_faults()

    def transient_retry():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "transient", nth=1)):
            cp.run(_inputs())
        return cp.explain_faults()

    def deterministic_descent():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "deterministic",
                                  nth=1)):
            cp.run(_inputs())
        return cp.explain_faults()

    def capacity_chunked():
        from repro.core import compile_program
        from repro.core.programs import ALL
        cp = compile_program(ALL["group_by"], op_select="force:scatter")
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.whole_trace", "capacity", nth=1,
                                  times=10 ** 4)):
            cp.run(_inputs())
        return cp.explain_faults() + "\n" + cp.explain_chunked()

    def interp_oracle():
        cp = _fresh_cp()
        cp.faults.sleep = lambda s: None
        with F.inject(F.FaultSpec("lower.node", "transient", nth=1,
                                  times=10 ** 4)):
            cp.run(_inputs())
        return cp.explain_faults()

    def serve_chaos():
        from repro.serve import PlanServer
        srv = PlanServer({"group_by": _fresh_cp()}, max_batch=8)
        srv.faults.sleep = lambda s: None
        srv.policy.backoff_s = 0.0
        specs = [F.FaultSpec("serve.batched_call", "transient", nth=1),
                 F.FaultSpec("serve.batched_call", "deterministic",
                             rid=3, times=10 ** 4),
                 F.FaultSpec("serve.stack", "poison", rid=5,
                             times=10 ** 4)]
        ts = [srv.submit("group_by", _inputs(i)) for i in range(8)]
        with F.inject(*specs):
            srv.drain()
        states = ",".join(t.state for t in ts)
        return (srv.explain_serving() + "\n" + srv.explain_faults()
                + f"\nticket states: {states}")

    def shard_loss_recovered():
        import jax
        from repro.core import compile_program
        from repro.core.distributed import compile_distributed
        from repro.core.programs import ALL
        from repro.launch.mesh import make_test_mesh
        ndev = len(jax.devices())
        if ndev < 4:                  # forced to 4 in __main__; imported
            return f"(skipped: {ndev} device(s), scenario needs 4)"
        mesh = make_test_mesh((4,), ("data",))
        cp = compile_program(ALL["pagerank"], round_fusion=False)
        cp.policy.backoff_s = 0.0
        cp.policy.max_backoff_s = 0.0
        cp.faults.sleep = lambda s: None
        dp = compile_distributed(cp, mesh)
        r = np.random.default_rng(7)
        nn = 16
        ins = dict(E=(r.integers(0, nn, 60).astype(np.float64),
                      r.integers(0, nn, 60).astype(np.float64)),
                   P=np.full(nn, 1.0 / nn), NP=np.zeros(nn),
                   C=np.zeros(nn), N=nn, num_steps=3.0, steps=0.0,
                   b=0.85)
        dp.run(ins)                   # warm traces: the golden is the
        #                               ledger, not compile-time retries
        with F.inject(F.FaultSpec("dist.shard_lost", kind="shard_lost",
                                  nth=7, shard=2)):
            dp.run(ins)
        return dp.explain_faults()

    def speculative_backup_win():
        class Clock:                  # deterministic injected time — the
            def __init__(self):      # golden must not depend on the wall
                self.t = 0.0

            def __call__(self):
                return self.t

            def advance(self, dt):
                self.t += dt

        from repro.serve import PlanServer
        clk = Clock()
        srv = PlanServer({"group_by": _fresh_cp()}, max_batch=1,
                         clock=clk)
        srv.faults.sleep = lambda s: None
        srv.policy.backoff_s = 0.0
        specs = [F.FaultSpec("serve.batched_call", "slow", nth=1,
                             times=5, delay_s=0.01),
                 F.FaultSpec("serve.batched_call", "slow", nth=6,
                             delay_s=1.0)]
        with F.inject(*specs, clock=clk):
            for i in range(6):
                srv.submit("group_by", _inputs(i, 20))
                srv.drain()
        return srv.explain_serving() + "\n" + srv.explain_faults()

    return [("clean run (no faults)", clean),
            ("transient at lower.whole_trace: retried in place",
             transient_retry),
            ("deterministic at lower.whole_trace: one descent to eager",
             deterministic_descent),
            ("capacity at lower.whole_trace: out-of-core chunked rung",
             capacity_chunked),
            ("persistent transient at lower.node: interpreter oracle",
             interp_oracle),
            ("serve chaos: retry + bisection + poisoned lane",
             serve_chaos),
            ("shard lost mid-loop: lineage recovery, no ladder descent",
             shard_loss_recovered),
            ("straggling flush: speculative backup copy wins",
             speculative_backup_win)]


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "FAULT_ledgers.txt"
    chunks = []
    for title, fn in scenarios():
        chunks.append(f"=== {title} ===\n{fn()}\n")
    text = "\n".join(chunks)
    with open(out, "w") as f:
        f.write(text)
    print(text)
    print(f"[fault_goldens] wrote {out}")


if __name__ == "__main__":
    import os
    # before jax loads: the shard-loss scenario needs a 4-way mesh
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    sys.path.insert(0, "src")
    main()

"""Serving-layer throughput: the mixed pagerank + group_by + kmeans
workload through one PlanServer at 1 / 8 / 64 simulated clients.

Closed-loop clients in lockstep rounds: every round, each client submits
one request (its program and bag length fixed per client id, ragged so
bucket padding is actually exercised) and blocks until the server answers
— so concurrency == client count exactly, and every request's latency is
measured submit→completion on the real clock.  At 1 client every request
is a solo dispatch; at 64 the shape buckets coalesce requests into
batched vmapped calls against the shared whole-program cache — the ≥3×
throughput gate (--check) is the serving layer earning its keep.

Emits BENCH_serve.json via benchmarks.run --sections serve.
"""
from __future__ import annotations

import time

import numpy as np

CLIENT_LEVELS = (1, 8, 64)
REQUESTS = 192          # per level: 192/24/3 rounds — same total work
MAX_BATCH = 16
FLUSH_MS = 1.0

# (program, bag rows): two ragged sizes per program — both of each pair
# round up to one shared bucket, so padding (not just stacking) is on the
# measured path
SPECS = (("pagerank", 256), ("group_by", 256), ("kmeans_step", 128),
         ("pagerank", 192), ("group_by", 192), ("kmeans_step", 96))

_CPS = {}


def _cps():
    from repro.core import programs as progs
    from repro.core.lower import compile_program
    if not _CPS:
        for name in ("pagerank", "group_by", "kmeans_step"):
            _CPS[name] = compile_program(getattr(progs, name))
    return _CPS


def make_inputs(name: str, m: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    if name == "pagerank":
        N = 64
        return dict(E=(rng.integers(0, N, m).astype(np.float64),
                       rng.integers(0, N, m).astype(np.float64)),
                    P=np.full(N, 1.0 / N), NP=np.zeros(N), C=np.zeros(N),
                    N=N, num_steps=3.0, steps=0.0, b=0.85)
    if name == "group_by":
        nv = 16
        return dict(S=(rng.integers(0, nv, m).astype(np.float64),
                       rng.standard_normal(m)), C=np.zeros(nv))
    if name == "kmeans_step":
        K = 4
        return dict(P=(rng.standard_normal(m) * 3,
                       rng.standard_normal(m) * 3),
                    CX=rng.standard_normal(K), CY=rng.standard_normal(K),
                    K=K, D=np.zeros((m, K)), MinD=np.full(m, 1e30),
                    Cl=np.zeros(m), SX=np.zeros(K), SY=np.zeros(K),
                    CN=np.zeros(K), NX=np.zeros(K), NY=np.zeros(K))
    raise KeyError(name)


def _measure(clients: int, requests: int) -> dict:
    """One closed-loop run.  The whole-program cache lives in the shared
    CompiledPrograms, so rows() runs each level once untimed first — the
    warmup absorbs every batch-signature trace and the timed run measures
    steady state."""
    from repro.serve import PlanServer
    srv = PlanServer(_cps(), max_batch=MAX_BATCH, flush_ms=FLUSH_MS)
    pool = [make_inputs(name, m, seed=i)
            for i, (name, m) in enumerate(SPECS)]
    t0 = time.monotonic()
    submitted = 0
    while submitted < requests:
        round_n = min(clients, requests - submitted)
        tickets = []
        for c in range(round_n):
            name, _ = SPECS[(submitted + c) % len(SPECS)]
            tickets.append(srv.submit(name,
                                      pool[(submitted + c) % len(SPECS)]))
        submitted += round_n
        srv.pump()              # full buckets flush as they filled
        srv.drain()             # closed loop: clients all block on results
        assert all(t.state == "done" for t in tickets)
    elapsed = time.monotonic() - t0
    s = srv.stats()
    assert s["completed"] == requests and s["failed"] == 0
    return {"clients": clients, "requests": requests,
            "rps": round(requests / elapsed, 1),
            "p50_ms": round(s["p50_ms"], 3), "p99_ms": round(s["p99_ms"], 3),
            "occupancy_pct": round(s["occupancy"], 1),
            "flushes": s["flushes"], "batch_traced": s["batch_traced"],
            "batch_hits": s["batch_hits"],
            "seq_fallbacks": s["seq_fallbacks"]}


def rows(levels=CLIENT_LEVELS, requests=REQUESTS) -> list:
    out = []
    for clients in levels:
        # warmup: at least one full spec cycle, and enough rounds to hit
        # the lane counts the timed run will see
        _measure(clients, min(requests, max(len(SPECS), 3 * clients)))
        out.append(_measure(clients, requests))
    return out


def print_rows(rws) -> None:
    print("clients,rps,p50_ms,p99_ms,occupancy_pct,batch_traced,batch_hits")
    for r in rws:
        print(f"{r['clients']},{r['rps']:.0f},{r['p50_ms']:.2f},"
              f"{r['p99_ms']:.2f},{r['occupancy_pct']:.0f},"
              f"{r['batch_traced']},{r['batch_hits']}")


def to_json(rws) -> dict:
    import jax
    return {"section": "serve", "unit": "requests_per_sec",
            "platform": jax.default_backend(),
            "max_batch": MAX_BATCH, "flush_ms": FLUSH_MS,
            "workload": [{"program": n, "bag_rows": m} for n, m in SPECS],
            "rows": rws}


def check_rows(rws, gate: float = 3.0) -> bool:
    """--check gate: 64-client throughput must be ≥ `gate`× the 1-client
    throughput on the same mixed workload.  A failing ratio is re-measured
    once before it fails the build (same idiom as the fig3 gates)."""
    by = {r["clients"]: r["rps"] for r in rws}
    lo, hi = min(by), max(by)
    if by[hi] >= gate * by[lo]:
        print(f"[serve] scaling gate OK ({hi} clients = "
              f"{by[hi] / by[lo]:.1f}x of {lo}-client throughput)")
        return False
    print(f"[serve] {hi}-client rps only {by[hi] / by[lo]:.2f}x of "
          f"{lo}-client; re-measuring to confirm")
    rerun = rows(levels=(lo, hi))
    by = {r["clients"]: r["rps"] for r in rerun}
    if by[hi] >= gate * by[lo]:
        print(f"[serve] scaling gate OK on re-measurement "
              f"({by[hi] / by[lo]:.1f}x)")
        return False
    print(f"[serve] SCALING GATE FAILED: {hi}-client throughput "
          f"{by[hi]:.0f} rps < {gate}x {lo}-client {by[lo]:.0f} rps "
          "(confirmed by re-measurement)")
    return True

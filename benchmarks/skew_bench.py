"""Skew section: uniform vs Zipf(1.5) key streams through the SAME
sharded programs, on a forced-host-device mesh.

Run standalone (forces 8 host devices before importing jax):

  python benchmarks/skew_bench.py [--check]

or as a section of the harness: python -m benchmarks.run --sections skew
[--check] (emits BENCH_skew.json, uploaded as a CI artifact).

What it measures: the group-by family (word_count, group_by) and the
scatter-fed pagerank loop with (a) uniformly distributed keys and (b) a
Zipf(1.5) stream — most rows hitting a handful of hot keys — through the
skew-aware distribution machinery (run-time hot-key probe + salting,
ONED_VAR rebalancing).  The artifact records both times, the ratio, and
whether the probe actually salted a round, per program.

--check is the skew regression gate (wired into the `distributed` CI
job): it FAILS (exit 1) when the Zipf stream runs more than 20% slower
than the uniform stream on any benchmarked program — i.e. when key skew
degrades a sharded program beyond the gate.  The executor's dense
partial-⊕ rounds are skew-oblivious by construction (every shard reduces
its local block into a dense [K] partial whatever the keys), so this
gate holds without salting on CPU; it exists to catch regressions that
re-introduce key-dependent work, and the artifact keeps the honest
numbers.  Flagged programs are re-measured before failing (host-device
collective timings are noisy).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

DEVICES = 8
ZIPF_A = 1.5


def _force_devices():
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")


def mesh_devices() -> int:
    """Devices actually used: respects a pre-set XLA_FLAGS (e.g. the CI
    matrix forcing 4) instead of assuming the default of 8."""
    import jax
    return min(DEVICES, len(jax.devices()))


def _keys(rng, nv: int, ne: int, skew: str):
    """A key column in [0, nv): uniform, or Zipf(1.5) folded into range
    (most rows land on a handful of hot keys; the hottest holds ~40%)."""
    import numpy as np
    if skew == "uniform":
        return rng.integers(0, nv, ne).astype(np.float64)
    return ((rng.zipf(ZIPF_A, ne) - 1) % nv).astype(np.float64)


def _cases(scale: int, skew: str):
    import numpy as np
    rng = np.random.default_rng(29)   # same seed both skews: values match
    nv, ne = 128 * scale, 1024 * scale
    return {
        "word_count": dict(W=_keys(rng, nv, ne, skew), C=np.zeros(nv)),
        "group_by": dict(S=(_keys(rng, nv, ne, skew),
                            rng.standard_normal(ne)), C=np.zeros(nv)),
        "pagerank": dict(E=(_keys(rng, nv, ne, skew),
                            _keys(rng, nv, ne, skew)),
                         P=np.full(nv, 1 / nv), NP=np.zeros(nv),
                         C=np.zeros(nv), N=nv, num_steps=2.0, steps=0.0,
                         b=0.85),
    }


def _time_pair(fn_a, fn_b, pairs=5, reps=2):
    """(min_a_ms, min_b_ms) over interleaved passes — the methodology of
    benchmarks/distributed.py: adjacent passes see the same machine
    conditions, the min absorbs host-collective spikes."""
    import numpy as np

    def one_pass(fn):
        t0 = time.perf_counter()
        for _ in range(reps):
            for v in fn().values():
                np.asarray(v)
        return (time.perf_counter() - t0) / reps * 1e3

    for fn in (fn_a, fn_b):                # warm-up / compile, synchronized
        for v in fn().values():
            np.asarray(v)
    ta, tb = [], []
    for i in range(pairs):
        if i % 2 == 0:
            ta.append(one_pass(fn_a))
            tb.append(one_pass(fn_b))
        else:
            tb.append(one_pass(fn_b))
            ta.append(one_pass(fn_a))
    return min(ta), min(tb)


def rows(scale: int = 1, only=None, pairs: int = 5):
    """[(name, uniform_ms, zipf_ms, salted)] on a forced host mesh.  Both
    skews run through the SAME DistributedProgram — the run-time probe
    keys the compile cache, so the Zipf stream traces its own (possibly
    salted) rounds.  `salted` reports whether any round of the Zipf run
    actually salted (the probe is data-driven; on CPU the cost model
    keeps S=1, so this is normally False here and True on TPU)."""
    _force_devices()
    from repro.core import compile_program
    from repro.core.distributed import compile_distributed
    from repro.core.programs import ALL
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((mesh_devices(),), ("data",))
    out = []
    for name in ("word_count", "group_by", "pagerank"):
        if only is not None and name not in only:
            continue
        uni = _cases(scale, "uniform")[name]
        zipf = _cases(scale, "zipf")[name]
        cp = compile_program(ALL[name])
        dp = compile_distributed(cp, mesh, ("data",), mode="shardmap")
        t_uni, t_zipf = _time_pair(lambda: dp.run(uni),
                                   lambda: dp.run(zipf), pairs=pairs)
        dp.run(zipf)      # strategy snapshot of the zipf rounds
        salted = "salt=" in dp.explain_rounds()
        out.append((name, t_uni, t_zipf, salted))
    return out


_SKEW_GATE = 1.20     # zipf >20% slower than uniform fails


def check_rows(measured, scale: int = 1) -> bool:
    """The skewed-vs-uniform regression gate.  True = FAILED.  A program
    is flagged when zipf > 1.2 × uniform; flagged programs are
    re-measured independently and only a reproduced slowdown fails."""
    def _bad(rws):
        return {n: (u, z) for n, u, z, _s in rws if z > u * _SKEW_GATE}
    bad = _bad(measured)
    if bad:
        print(f"[skew] {len(bad)} candidate slowdown(s): "
              f"{','.join(sorted(bad))}; re-measuring to confirm")
        rerun = rows(scale, only=frozenset(bad), pairs=11)
        bad = {n: v for n, v in _bad(rerun).items() if n in bad}
    if bad:
        print("[skew] SKEWED-KEY GATE FAILED (Zipf(1.5) >20% slower than "
              "uniform, confirmed by re-measurement):")
        for n, (u, z) in sorted(bad.items()):
            print(f"  {n}: zipf {z:.1f}ms vs uniform {u:.1f}ms "
                  f"({z / u:.2f}x)")
        return True
    print(f"[skew] skewed-key gate OK ({len(measured)} programs, "
          f"zipf <= {_SKEW_GATE:.2f}x uniform everywhere)")
    return False


def to_json(measured, scale: int) -> dict:
    return {
        "section": "skew",
        "unit": "ms_per_run",
        "devices": mesh_devices(),
        "scale": scale,
        "zipf_a": ZIPF_A,
        "gate": _SKEW_GATE,
        "rows": [dict(name=n, uniform_ms=round(u, 2), zipf_ms=round(z, 2),
                      ratio=round(z / u, 3) if u else None, salted=s)
                 for n, u, z, s in measured],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--json-out", default=None,
                    help="write BENCH_skew.json-style artifact here")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when zipf is >20%% slower than uniform "
                         "on any program (re-measured to confirm)")
    args = ap.parse_args()
    measured = rows(args.scale)
    print("name,uniform_ms,zipf_ms,ratio,salted")
    for name, u, z, s in measured:
        print(f"{name},{u:.1f},{z:.1f},{z / u:.2f},{int(s)}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(to_json(measured, args.scale), f, indent=1)
    if args.check and check_rows(measured, args.scale):
        sys.exit(1)


if __name__ == "__main__":
    main()

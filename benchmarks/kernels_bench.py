"""[kernels] section: per shape-class timings of every SegmentReduce
backend candidate (scatter / sort / onehot / pallas), plus what the
analytical cost model would pick for the class — so autotune decisions
are inspectable and a regression in one backend is attributable to that
backend rather than to the selection policy.

Run standalone:  python benchmarks/kernels_bench.py
or as a harness section:  python -m benchmarks.run --sections kernels
(emits BENCH_kernels.json).

The measurement reuses op_select's own autotune probes
(`_measure_segment`), so the numbers here are exactly what autotune mode
would record into `.repro_autotune.json` for the same classes.  `None`
means the candidate was skipped by the work caps (onehot materializes
N×K; Pallas interpret mode off-TPU is python-level).
"""
from __future__ import annotations

import math
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

# (n, k, d, op): the fig3 group-by family shapes (word_count/histogram/
# group_by and their per-shard blocks) plus small-K and wide-D classes
SHAPE_CLASSES = [
    (1024, 128, 1, "+"),        # distributed bench, whole bag
    (128, 128, 1, "+"),         # …its per-shard block (8 shards)
    (4096, 16, 1, "+"),         # small K: the one-hot dot regime
    (8192, 128, 1, "+"),
    (200_000, 1000, 1, "+"),    # fig3 word_count / group_by
    (200_000, 256, 1, "+"),     # fig3 histogram (per channel)
    (8192, 128, 8, "+"),        # wide values ([N, D] path)
    (8192, 128, 1, "min"),      # non-+ monoid (no onehot candidate)
]


def rows():
    from repro.core.op_select import (SEGMENT_CANDIDATES, OpSelector,
                                      _measure_segment)
    import jax.numpy as jnp

    sel = OpSelector(mode="cost", cache_path=None)
    out = []
    for n, k, d, op in SHAPE_CLASSES:
        cands = SEGMENT_CANDIDATES[op]
        us = {b: _measure_segment(b, n, k, d, op, jnp.float32)
              for b in cands}
        finite = {b: t for b, t in us.items() if math.isfinite(t)}
        best = min(finite, key=finite.get)
        model = sel.choose_segment(n=n, k=k, d=d, op=op, dtype="float32",
                                   dest_dist="ONED_ROW",
                                   candidates=cands).backend
        out.append({"n": n, "k": k, "d": d, "op": op,
                    "class": sel.segment_class(n, k, d, op, "float32",
                                               "ONED_ROW"),
                    "us": {b: (round(t, 1) if math.isfinite(t) else None)
                           for b, t in us.items()},
                    "measured_best": best, "cost_model": model})
    return out


def print_rows(krows) -> None:
    print("n,k,d,op,measured_best,cost_model,us_per_backend")
    for r in krows:
        us = " ".join(f"{b}={t}" for b, t in r["us"].items())
        print(f"{r['n']},{r['k']},{r['d']},{r['op']},"
              f"{r['measured_best']},{r['cost_model']},{us}")


def main():
    print_rows(rows())


if __name__ == "__main__":
    main()

"""[dispatch] section: dispatch overhead of the whole-program compilation
layer (DESIGN.md §9).

Two halves, one artifact (BENCH_dispatch.json):

* single-device (runs in the calling process): per-call time of
  CompiledProgram.run() in eager (one XLA dispatch per plan node) vs whole
  (ONE cached XLA computation per shape signature) mode, plus the
  warm-cache retrace counts — repeat calls with identical shapes must hit
  the compile cache (`traces` stays 1), which is the near-zero
  repeat-call dispatch overhead claim made observable.

* distributed (MUST run in a fresh process: forces host devices before jax
  loads — `python -m benchmarks.dispatch_bench --dist`, which prints one
  JSON line; benchmarks/run.py spawns it as a subprocess): per-run and
  per-iteration cost of pagerank and per-call cost of kmeans with round
  fusion on vs off.  Fused pagerank runs its whole loop as ONE shard_map
  program with an on-device lax.while_loop (0 host syncs); unfused is the
  PR-4 behaviour (one jit+shard_map dispatch per body node per iteration
  plus a blocking host sync on the condition).  Per-iteration time is the
  drift-immune difference quotient (t(S2) - t(S1)) / (S2 - S1) over
  interleaved pairs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))
sys.path.insert(0, _REPO)

_DIST_MARKER = "DISPATCH_DIST_JSON:"


def _time_call(fn, pairs=7, reps=3):
    """Min µs per call over `pairs` passes of `reps` calls."""
    import numpy as np
    for v in fn().values():              # warm-up / compile
        np.asarray(v)
    best = float("inf")
    for _ in range(pairs):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        for v in out.values():
            np.asarray(v)
        best = min(best, (time.perf_counter() - t0) / reps * 1e6)
    return best


# ---------------------------------------------------------------------------
# single-device: eager vs whole per-call overhead + retrace counts
# ---------------------------------------------------------------------------

def single_rows():
    import numpy as np
    from repro.core import compile_program
    from repro.core.programs import ALL

    rng = np.random.default_rng(3)
    nv = 64
    cases = {
        # small inputs: dispatch overhead dominates the arithmetic
        "word_count": dict(W=rng.integers(0, nv, 2048).astype(np.float64),
                           C=np.zeros(nv)),
        "pagerank": dict(E=(rng.integers(0, nv, 2048).astype(np.float64),
                            rng.integers(0, nv, 2048).astype(np.float64)),
                         P=np.full(nv, 1 / nv), NP=np.zeros(nv),
                         C=np.zeros(nv), N=nv, num_steps=2.0, steps=0.0,
                         b=0.85),
        "kmeans_step": dict(P=(rng.standard_normal(512) * 3,
                               rng.standard_normal(512) * 3),
                            CX=rng.standard_normal(8),
                            CY=rng.standard_normal(8), K=8,
                            D=np.zeros((512, 8)), MinD=np.full(512, 1e30),
                            Cl=np.zeros(512), SX=np.zeros(8),
                            SY=np.zeros(8), CN=np.zeros(8), NX=np.zeros(8),
                            NY=np.zeros(8)),
        "matrix_factorization_step": dict(
            R=rng.standard_normal((64, 48)),
            P=rng.standard_normal((64, 8)) * .1,
            Q=rng.standard_normal((8, 48)) * .1,
            Pp=rng.standard_normal((64, 8)) * .1,
            Qp=rng.standard_normal((8, 48)) * .1,
            pq=np.zeros((64, 48)), err=np.zeros((64, 48)),
            n=64, m=48, l=8, a=0.002, lam=0.02),
    }
    out = []
    calls = 10
    for name, ins in cases.items():
        eager = compile_program(ALL[name], compile_mode="eager")
        whole = compile_program(ALL[name])
        t_eager = _time_call(lambda: eager.run(ins))
        t_whole = _time_call(lambda: whole.run(ins))
        before = whole.trace_count
        for _ in range(calls):
            whole.run(ins)
        out.append({"name": name,
                    "eager_us": round(t_eager, 1),
                    "whole_us": round(t_whole, 1),
                    "speedup": round(t_eager / t_whole, 2),
                    "warm_retraces": whole.trace_count - before,
                    "cache_hits": whole.cache_hits})
    return out


def print_single(rows):
    print("name,eager_us,whole_us,speedup,warm_retraces")
    for r in rows:
        print(f"{r['name']},{r['eager_us']:.0f},{r['whole_us']:.0f},"
              f"{r['speedup']:.2f},{r['warm_retraces']}")


# ---------------------------------------------------------------------------
# distributed: round fusion on vs off (fresh process only)
# ---------------------------------------------------------------------------

def _force_devices():
    from benchmarks import distributed
    distributed._force_devices()


def dist_rows():
    import numpy as np
    from benchmarks.distributed import mesh_devices
    from repro.core import compile_program
    from repro.core.distributed import compile_distributed
    from repro.core.programs import ALL
    from repro.launch.mesh import make_test_mesh
    from benchmarks.distributed import _time_pair

    mesh = make_test_mesh((mesh_devices(),), ("data",))
    rng = np.random.default_rng(23)
    nv, ne, npts = 128, 1024, 512        # the BENCH_distributed case sizes

    def pr_ins(steps):
        return dict(E=(rng.integers(0, nv, ne).astype(np.float64),
                       rng.integers(0, nv, ne).astype(np.float64)),
                    P=np.full(nv, 1 / nv), NP=np.zeros(nv), C=np.zeros(nv),
                    N=nv, num_steps=float(steps), steps=0.0, b=0.85)

    out = {"devices": mesh_devices()}
    s1, s2 = 2, 6
    per_iter = {}
    per_run = {}
    for label, fuse in (("fused", True), ("unfused", False)):
        cp = compile_program(ALL["pagerank"], round_fusion=fuse)
        dp = compile_distributed(cp, mesh, ("data",))
        i1, i2 = pr_ins(s1), pr_ins(s2)
        t2, t1 = _time_pair(lambda: dp.run(i2), lambda: dp.run(i1))
        per_run[label] = round(t1, 2)            # num_steps=2: the
        per_iter[label] = round((t2 - t1) / (s2 - s1), 2)   # PR-4 shape
    out["pagerank_run_ms"] = per_run             # vs 30.4 ms PR-4 baseline
    out["pagerank_per_iteration_ms"] = per_iter

    km = dict(P=(rng.standard_normal(npts) * 3,
                 rng.standard_normal(npts) * 3),
              CX=rng.standard_normal(8), CY=rng.standard_normal(8), K=8,
              D=np.zeros((npts, 8)), MinD=np.full(npts, 1e30),
              Cl=np.zeros(npts), SX=np.zeros(8), SY=np.zeros(8),
              CN=np.zeros(8), NX=np.zeros(8), NY=np.zeros(8))
    dp_f = compile_distributed(
        compile_program(ALL["kmeans_step"]), mesh, ("data",))
    dp_u = compile_distributed(
        compile_program(ALL["kmeans_step"], round_fusion=False),
        mesh, ("data",))
    tf, tu = _time_pair(lambda: dp_f.run(km), lambda: dp_u.run(km))
    out["kmeans_per_call_ms"] = {"fused": round(tf, 2),
                                 "unfused": round(tu, 2)}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", action="store_true",
                    help="distributed half (fresh process: forces host "
                         "devices); prints one machine-readable JSON line")
    args = ap.parse_args()
    if args.dist:
        _force_devices()
        rows = dist_rows()
        print(_DIST_MARKER + json.dumps(rows))
        return
    rows = single_rows()
    print_single(rows)


if __name__ == "__main__":
    main()

"""Recovery-tier cost model (DESIGN.md §13): what does surgical recovery
actually cost, against the ladder it replaces?

Runs as a fresh subprocess spawned by ``benchmarks.run --sections
recovery`` (it must force host devices before importing jax); prints one
machine-readable JSON line behind ``_MARKER``.  Standalone:

  python -m benchmarks.recovery_bench --dist [--check]

Three measurements on a forced 8-host-device pagerank (per-member
rounds, so mid-loop rounds exist to lose):

* **Lineage recovery overhead** — fault-free wall time vs a run that
  loses one shard's output partition mid-loop and recovers it surgically
  (block-restricted recompute / cached-round replay, checksum-verified,
  ZERO ladder descents, bit-identical output — asserted).  Gate:
  faulted ≤ 1.5x fault-free.  A from-scratch restart would replay the
  whole program; lineage recovery re-executes 1/P of one round.

* **Restart ratio (informational)** — the same loss with lineage
  DISABLED: the pre-§13 ladder descends to REP-everything and re-runs
  the whole program on the surviving pool.  Reported as restart_x so
  the artifact prices what the recovery tier saves.

* **Speculative straggler re-execution** — on the injected clock: a
  1000ms straggling round against a 10ms baseline, with at most one
  backup copy (first finisher wins).  Effective completion = injected
  elapsed − spec_saved_s (the backup runs concurrently on a real pod;
  the saving is what concurrency buys back).  Gate: effective ≤ 2x the
  straggler-free run.  The speculation-off elapsed is reported as the
  informational no_spec_x.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

_MARKER = "RECOVERY_DIST_JSON:"
DEVICES = 8
STEPS = 32                 # pagerank iterations (97 per-member rounds):
#                            long enough that losing/recovering ONE round
#                            is measured against a realistic run, not a
#                            toy where fixed splice cost dominates
N, NE = 512, 4096          # ranks / edges
LOST_ROUND = 7             # a mid-SeqLoop round (iteration 2's store)
REPS = 3                   # min-of-REPS wall timings

RECOVERY_GATE = 1.5        # faulted run ≤ 1.5x fault-free
SPEC_GATE = 2.0            # effective straggled completion ≤ 2x clean


def _force_devices():
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")


def _inputs():
    import numpy as np
    rng = np.random.default_rng(17)
    return dict(E=(rng.integers(0, N, NE).astype(np.float64),
                   rng.integers(0, N, NE).astype(np.float64)),
                P=np.full(N, 1.0 / N), NP=np.zeros(N), C=np.zeros(N),
                N=N, num_steps=float(STEPS), steps=0.0, b=0.85)


def _mk(mesh, **kw):
    from repro.core import compile_program
    from repro.core.distributed import compile_distributed
    from repro.core.programs import ALL
    cp = compile_program(ALL["pagerank"], round_fusion=False, **kw)
    cp.policy.backoff_s = 0.0
    cp.policy.max_backoff_s = 0.0
    cp.faults.sleep = lambda s: None
    return compile_distributed(cp, mesh)


def _wall(fn) -> float:
    import numpy as np
    t0 = time.perf_counter()
    for v in fn().values():
        np.asarray(v)
    return (time.perf_counter() - t0) * 1e3


def dist_rows() -> dict:
    _force_devices()
    import numpy as np
    from repro.core import faults as F
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((DEVICES,), ("data",))
    ins = _inputs()

    # ---- lineage recovery vs fault-free (wall clock) ----
    # speculative=False: the watchdog would flag the recovered round as a
    # straggler and re-run a backup copy INSIDE the wall-timed run,
    # double-counting a feature this bench measures separately on the
    # injected clock
    dp = _mk(mesh, speculative=False)
    dp.policy.shard_loss_ttl_s = 0.0    # repeated same-shard loss here is
    #                                     the TIMING loop, not a flapping
    #                                     host — keep the TTL escalation
    #                                     out of the measurement
    ref = dp.run(ins)                               # warm every round trace
    t_clean = min(_wall(lambda: dp.run(ins)) for _ in range(REPS))

    def lose(shard=4):
        return F.inject(F.FaultSpec("dist.shard_lost", kind="shard_lost",
                                    nth=LOST_ROUND, shard=shard))
    with lose():
        out = dp.run(ins)               # warm the recompute-block trace
    assert all(np.array_equal(np.asarray(ref[k]), np.asarray(out[k]))
               for k in ref), "lineage recovery must be bit-identical"
    t_faulted = []
    for _ in range(REPS):
        with lose():
            t_faulted.append(_wall(lambda: dp.run(ins)))
    assert dp.faults.counters["descend"] == 0, "recovery must not descend"
    assert dp.faults.counters["recovered"] >= REPS + 1
    t_rec = min(t_faulted)

    # ---- restart ratio with lineage disabled (informational) ----
    dp_off = _mk(mesh, lineage=False, speculative=False)
    dp_off.run(ins)                                 # warm sharded rounds
    with F.inject(F.FaultSpec("dist.shard_lost", kind="shard_lost",
                              nth=LOST_ROUND, shard=2)):
        dp_off.run(ins)                             # warm the REP rung too
    t_restart = []
    for rep in range(REPS):
        with F.inject(F.FaultSpec("dist.shard_lost", kind="shard_lost",
                                  nth=LOST_ROUND, shard=3 + rep)):
            t_restart.append(_wall(lambda: dp_off.run(ins)))
    t_rst = min(t_restart)

    # ---- speculative straggler re-execution (injected clock) ----
    class Clock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

        def advance(self, dt):
            self.t += dt

    base = [F.FaultSpec("dist.round_exec", "slow", nth=1, times=5,
                        delay_s=0.01)]
    spike = F.FaultSpec("dist.round_exec", "slow", nth=6, delay_s=1.0)

    def injected_elapsed(dp_s, specs):
        clk = Clock()
        dp_s.faults.clock = clk
        dp_s.faults._times.clear()      # warm run's REAL wall samples
        #                                 would poison the fake-clock
        #                                 straggler window
        with F.inject(*specs, clock=clk):
            out_s = dp_s.run(ins)
        assert all(np.array_equal(np.asarray(ref[k]), np.asarray(out_s[k]))
                   for k in ref)
        return clk.t

    dp_c = _mk(mesh)
    dp_c.run(ins)
    s_clean = injected_elapsed(dp_c, base)          # no straggler

    dp_s = _mk(mesh)
    dp_s.run(ins)
    spec0 = dp_s.faults.counters["speculative"]
    saved0 = dp_s.faults.spec_saved_s
    s_strag = injected_elapsed(dp_s, base + [spike])
    saved = dp_s.faults.spec_saved_s - saved0
    assert dp_s.faults.counters["speculative"] - spec0 == 1
    s_eff = s_strag - saved                         # backup ran concurrently

    dp_n = _mk(mesh, speculative=False)
    dp_n.run(ins)
    s_nospec = injected_elapsed(dp_n, base + [spike])

    return {
        "devices": DEVICES, "ranks": N, "edges": NE, "steps": STEPS,
        "recovery": {
            "clean_ms": round(t_clean, 2),
            "faulted_ms": round(t_rec, 2),
            "overhead_x": round(t_rec / t_clean, 3) if t_clean else 0.0,
            "restart_ms": round(t_rst, 2),
            "restart_x": round(t_rst / t_clean, 3) if t_clean else 0.0,
            "descents": 0,
        },
        "speculation": {
            "clean_s": round(s_clean, 3),
            "straggler_nospec_s": round(s_nospec, 3),
            "spec_saved_s": round(saved, 3),
            "effective_s": round(s_eff, 3),
            "effective_x": round(s_eff / s_clean, 3) if s_clean else 0.0,
            "no_spec_x": round(s_nospec / s_clean, 3) if s_clean else 0.0,
        },
    }


def print_rows(rows: dict) -> None:
    r, s = rows["recovery"], rows["speculation"]
    print(f"recovery: clean={r['clean_ms']}ms faulted={r['faulted_ms']}ms "
          f"overhead={r['overhead_x']}x (gate {RECOVERY_GATE}x); "
          f"lineage-off restart={r['restart_ms']}ms = {r['restart_x']}x")
    print(f"speculation: clean={s['clean_s']}s "
          f"straggler(no spec)={s['straggler_nospec_s']}s "
          f"effective(with spec)={s['effective_s']}s "
          f"= {s['effective_x']}x (gate {SPEC_GATE}x)")


def to_json(rows: dict) -> dict:
    return {"section": "recovery", "unit": "wall ms / injected s",
            "gates": {"recovery_x": RECOVERY_GATE, "spec_x": SPEC_GATE},
            **rows}


def check_rows(rows: dict) -> bool:
    """--check gates: a surgically recovered run must cost ≤ 1.5x the
    fault-free run (it re-executes 1/P of ONE round plus checksums), and
    the effective completion of a straggled run with speculation must be
    ≤ 2x the straggler-free run (the backup copy hides the tail)."""
    bad = False
    ox = rows["recovery"]["overhead_x"]
    if ox > RECOVERY_GATE:
        print(f"[recovery] RECOVERY GATE FAILED: faulted run {ox}x "
              f"fault-free > {RECOVERY_GATE}x")
        bad = True
    else:
        print(f"[recovery] recovery gate OK ({ox}x of fault-free)")
    ex = rows["speculation"]["effective_x"]
    if ex > SPEC_GATE:
        print(f"[recovery] SPECULATION GATE FAILED: effective completion "
              f"{ex}x clean > {SPEC_GATE}x")
        bad = True
    else:
        print(f"[recovery] speculation gate OK ({ex}x of clean)")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", action="store_true",
                    help="measure (fresh process: forces host devices); "
                         "prints one machine-readable JSON line")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    rows = dist_rows()
    print_rows(rows)
    if args.dist:
        print(_MARKER + json.dumps(rows))
    if args.check and check_rows(rows):
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Paper §5: packed (tiled) matrices.  Compares matmul on (a) the fused
tiled path (block-sparse Pallas kernel on packed tiles), (b) unpack-then-
einsum, and (c) dense einsum, at several block sparsities."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _timeit(f, *args, reps=3):
    f(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    from repro.core.tiles import matmul_tiled, pack, unpack

    rng = np.random.default_rng(0)
    d = 512
    out = []
    for sparsity in (0.0, 0.5, 0.9):
        M = rng.standard_normal((d, d)).astype(np.float32)
        tiles_mask = rng.random((d // 128, d // 128)) < sparsity
        for i in range(d // 128):
            for j in range(d // 128):
                if tiles_mask[i, j]:
                    M[i * 128:(i + 1) * 128, j * 128:(j + 1) * 128] = 0
        N = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        tm = pack(jnp.asarray(M), 128, 128)

        fused = jax.jit(lambda nn, _tm=tm: matmul_tiled(_tm, nn,
                                                        interpret=True))
        unfused = jax.jit(lambda nn, _tm=tm: unpack(_tm) @ nn)
        dense = jax.jit(lambda nn, _m=jnp.asarray(M): _m @ nn)
        np.testing.assert_allclose(np.asarray(fused(N)),
                                   np.asarray(dense(N)), rtol=1e-3, atol=1e-2)
        density = float(tm.mask.mean())
        # NOTE: tiled_fused runs the Pallas kernel in INTERPRET mode (pure
        # python) on this CPU container — its us_per_call is NOT comparable
        # wall-clock; the TPU-relevant number is mxu_work = tile density
        # (fraction of dense MXU flops the block-sparse kernel issues).
        out.append((f"tiled_fused_sp{sparsity}_interp(mxu_work={density:.2f})",
                    _timeit(fused, N)))
        out.append((f"tiled_unpack_sp{sparsity}", _timeit(unfused, N)))
        out.append((f"dense_sp{sparsity}", _timeit(dense, N)))
    return out


def main():
    print("name,us_per_call")
    for name, t in rows():
        print(f"{name},{t:.0f}")


if __name__ == "__main__":
    main()

"""Out-of-core streaming cost model (DESIGN.md §12): what does the
capacity tier cost when it actually fires?

For pagerank (1M edges) and word_count (1M words) the bench compiles the
same program three ways — all-resident, and with a simulated device
budget the input bag overflows 2× and 10× — and times run() for each.
The budgeted runs admit through the memory estimator, stream the bag in
power-of-two tiles chosen from the budget, and must return the SAME
bits as the all-resident reference (asserted, not measured: stepwise for
looped programs, run() for loop-free ones — see test_outofcore.py for
why the jitted while_loop differs by an FMA).

Emitted as BENCH_outofcore.json via ``benchmarks.run --sections
outofcore``; --check gates the 10×-over-budget run at ≤ `gate` × the
all-resident wall time (re-measured once on failure — CPU timer noise,
not a real regression, is the common cause at these sizes).
"""
from __future__ import annotations

import time

import numpy as np

RATIOS = (2, 10)           # bag bytes = ratio × the simulated budget
REPEATS = 3
PR_N, PR_EDGES = 4096, 1 << 20
WC_KEYS, WC_WORDS = 4096, 1 << 20
PR_STEPS = 3.0


def _pr_inputs():
    r = np.random.default_rng(0)
    return dict(E=(r.integers(0, PR_N, PR_EDGES).astype(np.int32),
                   r.integers(0, PR_N, PR_EDGES).astype(np.int32)),
                P=np.full(PR_N, 1.0 / PR_N, np.float32),
                NP=np.zeros(PR_N, np.float32),
                C=np.zeros(PR_N, np.float32),
                N=PR_N, num_steps=PR_STEPS, steps=0.0, b=0.85)


def _wc_inputs():
    r = np.random.default_rng(1)
    return dict(W=(r.integers(0, WC_KEYS, WC_WORDS).astype(np.int32),),
                C=np.zeros(WC_KEYS, np.float32))


def _best(f, repeats=REPEATS) -> float:
    f()                                       # warmup: traces + caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        f()
        best = min(best, time.monotonic() - t0)
    return best


def _bitident(a, b) -> bool:
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


def _measure(name: str, inputs: dict, bag: str, looped: bool) -> dict:
    from repro.core import compile_program
    from repro.core.programs import ALL
    cp = compile_program(ALL[name], op_select="force:scatter")
    est = cp.estimate_memory(inputs)
    t_res = _best(lambda: cp.run(dict(inputs)))
    # the bit-identity reference: host-driven stepwise for looped
    # programs (the chunked executor's contract), run() otherwise
    ref = cp.run_stepwise(dict(inputs)) if looped else cp.run(dict(inputs))
    row = {"program": name, "bag_rows": est._bag_rows[bag],
           "bag_bytes": est.bag_bytes[bag],
           "est_peak_bytes": est.peak_bytes,
           "all_resident_s": round(t_res, 4), "budgets": []}
    for ratio in RATIOS:
        budget = est.fixed_bytes + est.bag_bytes[bag] // ratio
        cc = compile_program(ALL[name], op_select="force:scatter",
                             memory_budget=budget)
        rows = cc._initial_chunk_rows(inputs)
        out = cc.run(dict(inputs))
        assert _bitident(ref, out), f"{name} at {ratio}x not bit-identical"
        assert cc.faults.counters["admission"] >= 1
        t_chunk = _best(lambda: cc.run(dict(inputs)))
        row["budgets"].append({
            "over_budget_x": ratio, "budget_bytes": budget,
            "chunk_rows": rows,
            "n_chunks": -(-est._bag_rows[bag] // rows),
            "chunked_s": round(t_chunk, 4),
            "slowdown_x": round(t_chunk / t_res, 3) if t_res > 0 else 0.0})
    return row


def rows() -> list:
    return [_measure("pagerank", _pr_inputs(), "E", looped=True),
            _measure("word_count", _wc_inputs(), "W", looped=False)]


def print_rows(rws) -> None:
    print("program,over_budget_x,chunk_rows,n_chunks,"
          "all_resident_s,chunked_s,slowdown_x")
    for r in rws:
        for b in r["budgets"]:
            print(f"{r['program']},{b['over_budget_x']},"
                  f"{b['chunk_rows']},{b['n_chunks']},"
                  f"{r['all_resident_s']},{b['chunked_s']},"
                  f"{b['slowdown_x']}")


def to_json(rws) -> dict:
    import jax
    return {"section": "outofcore", "unit": "seconds",
            "platform": jax.default_backend(),
            "ratios": list(RATIOS), "repeats": REPEATS,
            "programs": rws}


def check_rows(rws, gate: float = 2.5) -> bool:
    """--check gate: streaming a bag 10× over budget must cost ≤ `gate` ×
    the all-resident run (the tile amortizes per-chunk dispatch at these
    sizes; worse means prefetch overlap or the tile choice regressed).
    A failing program is re-measured once before judging — single-shot
    wall times on shared CI runners are noisy."""
    bad = False
    for r in rws:
        worst = max(r["budgets"], key=lambda b: b["slowdown_x"])
        slow = worst["slowdown_x"]
        if slow > gate:
            fresh = _measure(r["program"],
                             _pr_inputs() if r["program"] == "pagerank"
                             else _wc_inputs(),
                             "E" if r["program"] == "pagerank" else "W",
                             looped=r["program"] == "pagerank")
            slow = max(b["slowdown_x"] for b in fresh["budgets"])
        if slow > gate:
            print(f"[outofcore] GATE FAILED: {r['program']} chunked "
                  f"{slow}x all-resident > {gate}x")
            bad = True
        else:
            print(f"[outofcore] {r['program']} OK "
                  f"({slow}x all-resident at "
                  f"{worst['over_budget_x']}x over budget)")
    return bad

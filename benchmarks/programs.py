"""Paper Figure 3: generated code vs hand-written JAX on the 12 benchmark
programs.  The paper's claim: DIABLO-generated Spark is comparable to
hand-written Spark (except KMeans/MF, which were slower).  Here both sides
are jitted JAX on CPU; we report best-of-N microseconds per call (plus the
median pass) and the MEDIAN of interleaved per-pair ratios (generated /
hand-written, see _timeit_pair) — the drift-immune estimator the CI
regression gate (benchmarks.run --check) compares.  Correctness is
asserted on every pair.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _reps_for(f, args):
    """Per-pass rep count targeting ~50ms per pass regardless of size."""
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    jax.block_until_ready(f(*args))
    pilot = time.perf_counter() - t0
    return max(3, min(200, int(0.05 / max(pilot, 1e-7))))


def _timeit_pair(gen, gen_args, hand, hand_args, repeats=5):
    """(gen_min, hand_min, gen_median, hand_median, ratio) µs per call,
    measured as `repeats` INTERLEAVED pass pairs: adjacent generated/
    hand-written passes see the same machine conditions, so background-
    load drift is common-mode within a pair.  The reported ratio is the
    MEDIAN of per-pair ratios — the drift-immune estimator (single-pass
    ratios historically swung ±40% at sub-millisecond scales; independent
    min-based ratios still absorb whichever side caught the quiet
    window).  Mins and medians of each side are recorded alongside."""
    rg = _reps_for(gen, gen_args)
    rh = _reps_for(hand, hand_args)

    def one_pass(f, args, reps):
        t0 = time.perf_counter()
        for _ in range(reps):
            r = f(*args)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / reps * 1e6

    gs, hs, ratios = [], [], []
    for i in range(max(1, repeats)):
        # alternate which side runs first: periodic interference otherwise
        # lands disproportionately on the second position of every pair
        if i % 2 == 0:
            g = one_pass(gen, gen_args, rg)
            h = one_pass(hand, hand_args, rh)
        else:
            h = one_pass(hand, hand_args, rh)
            g = one_pass(gen, gen_args, rg)
        gs.append(g)
        hs.append(h)
        ratios.append(g / h)
    gs.sort()
    hs.sort()
    ratios.sort()
    return (gs[0], hs[0], gs[len(gs) // 2], hs[len(hs) // 2],
            ratios[len(ratios) // 2])


def _close(a, b, tol=1e-3):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    assert np.max(np.abs(a - b) / (np.abs(b) + 1.0)) < tol, (a, b)


def rows(scale: int = 1, repeats: int = 5, only=None):
    """Per program: (name, gen_min_us, hand_min_us, ratio, gen_median_us,
    hand_median_us) — ratio is the median of interleaved per-pair ratios
    (see _timeit_pair).  `only` restricts measurement to a set of program
    names (used by the --check gate to re-measure regression candidates
    before failing)."""
    from repro.core import compile_program
    from repro.core.programs import ALL

    rng = np.random.default_rng(0)
    out = []

    def add(name, gen_fn, hand_fn, gen_args, hand_args, check=True):
        if only is not None and name not in only:
            return
        g = gen_fn(*gen_args)
        h = hand_fn(*hand_args)
        if check:
            _close(g, h)
        tg, th, tg_med, th_med, ratio = _timeit_pair(
            gen_fn, gen_args, hand_fn, hand_args, repeats)
        out.append((name, tg, th, ratio, tg_med, th_med))

    n_big = 200_000 * scale

    # ---- conditional sum ----
    v = jnp.asarray(rng.standard_normal(n_big), jnp.float32)
    cp = compile_program(ALL["conditional_sum"])
    gen = jax.jit(lambda v: cp.run(dict(V=(v,), s=jnp.float32(0), limit=jnp.float32(0.3)))["s"])
    hand = jax.jit(lambda v: jnp.where(v < 0.3, v, 0.0).sum())
    add("conditional_sum", gen, hand, (v,), (v,))

    # ---- equal ----
    w = jnp.asarray(rng.integers(0, 3, n_big), jnp.float32)
    cp = compile_program(ALL["equal"])
    gen = jax.jit(lambda w: cp.run(dict(W=(w,), first=w[0], diffs=jnp.float32(0)))["diffs"])
    hand = jax.jit(lambda w: jnp.sum(jnp.where(w != w[0], 1.0, 0.0)))
    add("equal", gen, hand, (w,), (w,))

    # ---- string match ----
    cp = compile_program(ALL["string_match"])
    gen = jax.jit(lambda w: cp.run(dict(W=(w,), k1=jnp.float32(1), k2=jnp.float32(5),
                                        k3=jnp.float32(7), found=jnp.zeros(3)))["found"])
    hand = jax.jit(lambda w: jnp.stack([(w == 1).any(), (w == 5).any(),
                                        (w == 7).any()]).astype(jnp.float32))
    add("string_match", gen, hand, (w,), (w,))

    # ---- word count ----
    nv = 1000
    toks = jnp.asarray(rng.integers(0, nv, n_big), jnp.float32)
    cp = compile_program(ALL["word_count"])
    gen = jax.jit(lambda t: cp.run(dict(W=(t,), C=jnp.zeros(nv)))["C"])
    hand = jax.jit(lambda t: jnp.zeros(nv).at[t.astype(jnp.int32)].add(1.0))
    add("word_count", gen, hand, (toks,), (toks,))

    # ---- histogram ----
    p3 = tuple(jnp.asarray(rng.integers(0, 256, n_big), jnp.float32)
               for _ in range(3))
    cp = compile_program(ALL["histogram"])
    gen = jax.jit(lambda a, b, c: cp.run(dict(
        P=(a, b, c), R=jnp.zeros(256), G=jnp.zeros(256),
        B=jnp.zeros(256)))["R"])
    hand = jax.jit(lambda a, b, c: jnp.zeros(256).at[a.astype(jnp.int32)].add(1.0))
    add("histogram", gen, hand, p3, p3)

    # ---- linear regression ----
    x = jnp.asarray(rng.standard_normal(n_big), jnp.float32)
    y = 2 * x + 1
    cp = compile_program(ALL["linear_regression"])

    def gen_lr(x, y):
        r = cp.run(dict(P=(x, y), n=x.shape[0], sum_x=jnp.float32(0),
                        sum_y=jnp.float32(0), x_bar=jnp.float32(0),
                        y_bar=jnp.float32(0), xx_bar=jnp.float32(0),
                        xy_bar=jnp.float32(0), slope=jnp.float32(0),
                        intercept=jnp.float32(0)))
        return r["slope"]

    def hand_lr(x, y):
        xb, yb = x.mean(), y.mean()
        return ((x - xb) * (y - yb)).sum() / ((x - xb) ** 2).sum()
    add("linear_regression", jax.jit(gen_lr), jax.jit(hand_lr), (x, y), (x, y))

    # ---- group by ----
    keys = jnp.asarray(rng.integers(0, nv, n_big), jnp.float32)
    vals = jnp.asarray(rng.standard_normal(n_big), jnp.float32)
    cp = compile_program(ALL["group_by"])
    gen = jax.jit(lambda k, v: cp.run(dict(S=(k, v), C=jnp.zeros(nv)))["C"])
    hand = jax.jit(lambda k, v: jnp.zeros(nv).at[k.astype(jnp.int32)].add(v))
    add("group_by", gen, hand, (keys, vals), (keys, vals))

    # ---- matrix addition ----
    d = 600 * max(1, scale // 2)
    M = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    N = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    cp = compile_program(ALL["matrix_addition"])
    gen = jax.jit(lambda M, N: cp.run(dict(M=M, N=N, R=jnp.zeros((d, d)),
                                           n=d, m=d))["R"])
    hand = jax.jit(lambda M, N: M + N)
    add("matrix_addition", gen, hand, (M, N), (M, N))

    # ---- matrix multiplication (einsum-recognized) ----
    dm = 256 * max(1, scale // 2)
    A = jnp.asarray(rng.standard_normal((dm, dm)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((dm, dm)), jnp.float32)
    cp = compile_program(ALL["matrix_multiplication"])
    gen = jax.jit(lambda A, B: cp.run(dict(M=A, N=B, R=jnp.zeros((dm, dm)),
                                           n=dm, m=dm, l=dm))["R"])
    hand = jax.jit(lambda A, B: A @ B)
    add("matrix_multiplication", gen, hand, (A, B), (A, B))

    # ---- matmul, paper-faithful plan (no contraction recognition) ----
    dsm = 64
    A2 = jnp.asarray(rng.standard_normal((dsm, dsm)), jnp.float32)
    B2 = jnp.asarray(rng.standard_normal((dsm, dsm)), jnp.float32)
    cpf = compile_program(ALL["matrix_multiplication"],
                          optimize_contractions=False)
    genf = jax.jit(lambda A, B: cpf.run(dict(M=A, N=B, R=jnp.zeros((dsm, dsm)),
                                             n=dsm, m=dsm, l=dsm))["R"])
    handf = jax.jit(lambda A, B: A @ B)
    add("matmul_paper_faithful_64", genf, handf, (A2, B2), (A2, B2))

    # ---- pagerank (1 step) ----
    nvert, nedge = 2000, 20000 * scale
    E = (jnp.asarray(rng.integers(0, nvert, nedge), jnp.float32),
         jnp.asarray(rng.integers(0, nvert, nedge), jnp.float32))
    cp = compile_program(ALL["pagerank"])

    def gen_pr(e0, e1):
        return cp.run(dict(E=(e0, e1), P=jnp.full(nvert, 1 / nvert),
                           NP=jnp.zeros(nvert), C=jnp.zeros(nvert), N=nvert,
                           num_steps=jnp.float32(1), steps=jnp.float32(0),
                           b=jnp.float32(0.85)))["P"]

    def hand_pr(e0, e1):
        s, ddst = e0.astype(jnp.int32), e1.astype(jnp.int32)
        C = jnp.zeros(nvert).at[s].add(1.0)
        P = jnp.full(nvert, 1 / nvert)
        NP = jnp.zeros(nvert).at[ddst].add(P[s] / C[s])
        return (1 - 0.85) / nvert + 0.85 * NP
    add("pagerank", jax.jit(gen_pr), jax.jit(hand_pr), E, E)

    # ---- kmeans (1 step) ----
    npts, K = 20000 * scale, 16
    px = jnp.asarray(rng.standard_normal(npts) * 3, jnp.float32)
    py = jnp.asarray(rng.standard_normal(npts) * 3, jnp.float32)
    cx = jnp.asarray(rng.standard_normal(K), jnp.float32)
    cy = jnp.asarray(rng.standard_normal(K), jnp.float32)
    cp = compile_program(ALL["kmeans_step"])

    def gen_km(px, py, cx, cy):
        r = cp.run(dict(P=(px, py), CX=cx, CY=cy, K=K,
                        D=jnp.zeros((npts, K)), MinD=jnp.full(npts, 1e30),
                        Cl=jnp.zeros(npts), SX=jnp.zeros(K), SY=jnp.zeros(K),
                        CN=jnp.zeros(K), NX=jnp.zeros(K), NY=jnp.zeros(K)))
        return r["NX"]

    def hand_km(px, py, cx, cy):
        d2 = (px[:, None] - cx[None]) ** 2 + (py[:, None] - cy[None]) ** 2
        cl = jnp.argmax((d2 == d2.min(1, keepdims=True)) *
                        jnp.arange(K)[None], axis=1)
        sx = jnp.zeros(K).at[cl].add(px)
        cn = jnp.zeros(K).at[cl].add(1.0)
        return sx / jnp.maximum(cn, 1.0)
    add("kmeans", jax.jit(gen_km), jax.jit(hand_km), (px, py, cx, cy),
        (px, py, cx, cy))

    # ---- matrix factorization (1 step) ----
    nmf, mmf, lmf = 200, 200, 8
    R = jnp.asarray(rng.standard_normal((nmf, mmf)), jnp.float32)
    P0 = jnp.asarray(rng.standard_normal((nmf, lmf)) * .1, jnp.float32)
    Q0 = jnp.asarray(rng.standard_normal((lmf, mmf)) * .1, jnp.float32)
    cp = compile_program(ALL["matrix_factorization_step"])

    def gen_mf(R, P0, Q0):
        r = cp.run(dict(R=R, P=P0, Q=Q0, Pp=P0, Qp=Q0,
                        pq=jnp.zeros((nmf, mmf)), err=jnp.zeros((nmf, mmf)),
                        n=nmf, m=mmf, l=lmf, a=jnp.float32(0.002),
                        lam=jnp.float32(0.02)))
        return r["P"]

    def hand_mf(R, P0, Q0):
        # per-(i,j,k) update summed over j == matrix form:
        err = R - P0 @ Q0
        return P0 + 0.002 * (2 * err @ Q0.T - 0.02 * mmf * P0)
    add("matrix_factorization", jax.jit(gen_mf), jax.jit(hand_mf),
        (R, P0, Q0), (R, P0, Q0))

    return out


def main(scale: int = 1):
    print("name,generated_us,handwritten_us,ratio,gen_median_us,hand_median_us")
    for name, tg, th, r, tgm, thm in rows(scale):
        print(f"{name},{tg:.0f},{th:.0f},{r:.2f},{tgm:.0f},{thm:.0f}")


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 1]

Sections:
  [table1]  translation time per program (paper Table 1)
  [fig3]    generated vs hand-written JAX per program (paper Figure 3)
  [sec5]    packed/tiled matrices (paper §5)
  [dist]    shardmap (inferred shardings) vs replicated per program on a
            forced 8-host-device mesh (DESIGN.md §6); run this section in
            a FRESH process (it forces XLA_FLAGS before importing jax)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1,
                    help="dataset scale multiplier for fig3")
    ap.add_argument("--sections", default="table1,fig3,sec5")
    ap.add_argument("--json-out", default=os.path.join(
        _REPO, "BENCH_programs.json"),
        help="fig3 artifact path for the perf trajectory ('' disables)")
    ap.add_argument("--dist-json-out", default=os.path.join(
        _REPO, "BENCH_distributed.json"),
        help="dist artifact path ('' disables)")
    args = ap.parse_args()
    sections = args.sections.split(",")

    if "dist" in sections:
        if sections != ["dist"]:
            # forcing host devices would skew every other section's
            # timings (and the BENCH_programs.json perf trajectory)
            ap.error("--sections dist must run alone (fresh process): "
                     "it forces XLA host device count before jax loads")
        # must run before anything imports jax: forces host device count
        from benchmarks import distributed
        distributed._force_devices()

    if "table1" in sections:
        from benchmarks import translation_time
        print("[table1] translation time (paper Table 1; "
              "paper: DIABLO 5-14.5s, MOLD 11-340s, CASPER 10s-19h)")
        print("name,translate_ms,first_run_ms")
        for name, a, b in translation_time.rows():
            print(f"{name},{a:.2f},{b:.1f}")
        print()

    if "fig3" in sections:
        from benchmarks import programs
        print("[fig3] generated vs hand-written (paper Figure 3)")
        print("name,generated_us,handwritten_us,ratio")
        rows = programs.rows(args.scale)
        for name, tg, th, r in rows:
            print(f"{name},{tg:.0f},{th:.0f},{r:.2f}")
        print()
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump({"section": "fig3", "scale": args.scale,
                           "unit": "us_per_call",
                           "rows": [{"name": n, "generated_us": round(tg, 1),
                                     "handwritten_us": round(th, 1),
                                     "ratio": round(r, 3)}
                                    for n, tg, th, r in rows]}, f, indent=1)
            print(f"[fig3] wrote {args.json_out}")

    if "sec5" in sections:
        from benchmarks import tiled
        print("[sec5] packed/tiled matrices (paper §5)")
        print("name,us_per_call")
        for name, t in tiled.rows():
            print(f"{name},{t:.0f}")
        print()

    if "dist" in sections:
        from benchmarks import distributed
        print("[dist] shardmap (inferred shardings) vs replicated "
              f"({distributed.mesh_devices()} forced host devices)")
        print("name,shardmap_ms,replicated_ms,sharded_dense_arrays")
        rows = distributed.rows(args.scale)
        for name, a, b, k in rows:
            print(f"{name},{a:.1f},{b:.1f},{k}")
        print()
        if args.dist_json_out:
            with open(args.dist_json_out, "w") as f:
                json.dump({"section": "dist", "scale": args.scale,
                           "devices": distributed.mesh_devices(),
                           "unit": "ms_per_run",
                           "rows": [{"name": n,
                                     "shardmap_ms": round(a, 2),
                                     "replicated_ms": round(b, 2),
                                     "sharded_dense_arrays": k}
                                    for n, a, b, k in rows]}, f, indent=1)
            print(f"[dist] wrote {args.dist_json_out}")


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 1]

Sections:
  [table1]  translation time per program (paper Table 1)
  [fig3]    generated vs hand-written JAX per program (paper Figure 3);
            --repeats N controls the best-of-N/median timing, --check
            gates >15% ratio regressions against the committed
            BENCH_programs.json (exit 1 — wired into CI)
  [sec5]    packed/tiled matrices (paper §5)
  [kernels] per shape-class timing of every SegmentReduce backend
            candidate vs the cost model's pick (DESIGN.md §8); emits
            BENCH_kernels.json so autotune decisions are inspectable
  [dispatch] whole-program compilation overhead (DESIGN.md §9): per-call
            eager vs whole run() time + warm-cache retrace counts, and —
            via a fresh subprocess that forces host devices — distributed
            pagerank/kmeans with round fusion on vs off; emits
            BENCH_dispatch.json
  [dist]    shardmap (inferred shardings) vs replicated per program on a
            forced 8-host-device mesh (DESIGN.md §6); run this section in
            a FRESH process (it forces XLA_FLAGS before importing jax);
            --check fails when shardmap is >10% slower than replicated
            on any benchmarked program (wired into CI)
  [skew]    uniform vs Zipf(1.5) key streams through the same sharded
            programs (skew-aware distribution, DESIGN.md §6) on a forced
            host-device mesh; also a FRESH-process section; emits
            BENCH_skew.json; --check fails when the Zipf stream is >20%
            slower than uniform on any program (wired into CI)
  [serve]   PlanServer throughput on the mixed pagerank + group_by +
            kmeans workload at 1/8/64 simulated clients (DESIGN.md §10);
            emits BENCH_serve.json; --check fails when 64-client
            throughput is < 3x 1-client (wired into CI)
  [faults]  robustness cost (DESIGN.md §11): serve goodput at 0/5/20%
            injected transient faults plus mid-loop checkpoint/resume
            overhead on pagerank; emits BENCH_faults.json; --check fails
            when goodput under 20%% faults drops below 0.5x fault-free
            or resume costs > 2x the uninterrupted run (chaos CI)
  [outofcore] capacity-tier cost (DESIGN.md §12): pagerank + word_count
            all-resident vs chunked streaming at 2x/10x over a simulated
            device budget (bit-identity asserted); emits
            BENCH_outofcore.json; --check fails when the 10x-over-budget
            run costs > 2.5x the all-resident run (chaos CI)
  [recovery] recovery-tier cost (DESIGN.md §13): mid-loop shard loss
            recovered by lineage recompute vs fault-free vs lineage-off
            ladder restart, plus speculative straggler re-execution on
            the injected clock — measured in a fresh subprocess that
            forces 8 host devices; emits BENCH_recovery.json; --check
            fails when the recovered run costs > 1.5x fault-free or the
            speculated straggler's effective completion is > 2x the
            straggler-free run (chaos CI)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _aggregate_rows(runs):
    """Merge N fig3 measurement runs into per-program MEDIANS of every
    column — how the committed baseline is produced (--aggregate 3): a
    single run's ratio can sit at the noise-lucky edge of its spread,
    which would make an honest future run trip the --check gate."""
    if len(runs) == 1:
        return runs[0]
    acc: dict = {}
    order = []
    for run in runs:
        for row in run:
            if row[0] not in acc:
                order.append(row[0])
            acc.setdefault(row[0], []).append(row[1:])

    def med(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    return [(n,) + tuple(med([s[i] for s in acc[n]]) for i in range(5))
            for n in order]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1,
                    help="dataset scale multiplier for fig3")
    ap.add_argument("--repeats", type=int, default=5,
                    help="fig3 interleaved timing pass pairs per program; "
                         "the gated ratio is the MEDIAN of per-pair "
                         "ratios (drift-immune), with best-of-N and "
                         "median times recorded alongside")
    ap.add_argument("--aggregate", type=int, default=1,
                    help="fig3 measurement runs; per-program MEDIANS "
                         "across runs are reported and written (the "
                         "committed baseline uses 3, see README)")
    ap.add_argument("--check", action="store_true",
                    help="regression gates: fig3 ratios vs the committed "
                         "BENCH_programs.json (>15%% worse fails), and "
                         "dist shardmap vs replicated (>10%% slower "
                         "fails); exit non-zero on either")
    ap.add_argument("--sections", default="table1,fig3,sec5")
    ap.add_argument("--json-out", default=os.path.join(
        _REPO, "BENCH_programs.json"),
        help="fig3 artifact path for the perf trajectory ('' disables)")
    ap.add_argument("--kernels-json-out", default=os.path.join(
        _REPO, "BENCH_kernels.json"),
        help="kernels artifact path ('' disables)")
    ap.add_argument("--dispatch-json-out", default=os.path.join(
        _REPO, "BENCH_dispatch.json"),
        help="dispatch artifact path ('' disables)")
    ap.add_argument("--dist-json-out", default=os.path.join(
        _REPO, "BENCH_distributed.json"),
        help="dist artifact path ('' disables)")
    ap.add_argument("--skew-json-out", default=os.path.join(
        _REPO, "BENCH_skew.json"),
        help="skew artifact path ('' disables)")
    ap.add_argument("--serve-json-out", default=os.path.join(
        _REPO, "BENCH_serve.json"),
        help="serve artifact path ('' disables)")
    ap.add_argument("--faults-json-out", default=os.path.join(
        _REPO, "BENCH_faults.json"),
        help="faults artifact path ('' disables)")
    ap.add_argument("--outofcore-json-out", default=os.path.join(
        _REPO, "BENCH_outofcore.json"),
        help="outofcore artifact path ('' disables)")
    ap.add_argument("--recovery-json-out", default=os.path.join(
        _REPO, "BENCH_recovery.json"),
        help="recovery artifact path ('' disables)")
    args = ap.parse_args()
    sections = args.sections.split(",")
    if args.check and not {"fig3", "dist", "skew", "serve",
                           "faults", "outofcore",
                           "recovery"} & set(sections):
        ap.error("--check gates fig3, dist, skew, serve, faults, "
                 "outofcore, and/or recovery: "
                 "include one in --sections")

    if {"dist", "skew"} & set(sections):
        if len(sections) != 1:
            # forcing host devices would skew every other section's
            # timings (and the BENCH_programs.json perf trajectory)
            ap.error(f"--sections {sections[0]} must run alone (fresh "
                     "process): it forces XLA host device count before "
                     "jax loads")
        # must run before anything imports jax: forces host device count
        if "dist" in sections:
            from benchmarks import distributed
            distributed._force_devices()
        else:
            from benchmarks import skew_bench
            skew_bench._force_devices()

    if "table1" in sections:
        from benchmarks import translation_time
        print("[table1] translation time (paper Table 1; "
              "paper: DIABLO 5-14.5s, MOLD 11-340s, CASPER 10s-19h)")
        print("name,translate_ms,first_run_ms")
        for name, a, b in translation_time.rows():
            print(f"{name},{a:.2f},{b:.1f}")
        print()

    check_failed = False
    if "fig3" in sections:
        from benchmarks import programs
        baseline = None
        if args.check:
            base_path = args.json_out or os.path.join(
                _REPO, "BENCH_programs.json")
            with open(base_path) as f:     # committed ratios, read BEFORE
                baseline = {r["name"]:     # they are rewritten
                            (r["ratio"],   # median-paired estimator
                             r["generated_us"] / r["handwritten_us"])
                            for r in json.load(f)["rows"]}
        print(f"[fig3] generated vs hand-written (paper Figure 3; "
              f"best of {args.repeats}"
              + (f", median of {args.aggregate} runs" if args.aggregate > 1
                 else "") + ")")
        print("name,generated_us,handwritten_us,ratio,"
              "gen_median_us,hand_median_us")
        rows = _aggregate_rows(
            [programs.rows(args.scale, repeats=args.repeats)
             for _ in range(max(1, args.aggregate))])
        for name, tg, th, r, tgm, thm in rows:
            print(f"{name},{tg:.0f},{th:.0f},{r:.2f},{tgm:.0f},{thm:.0f}")
        print()
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump({"section": "fig3", "scale": args.scale,
                           "unit": "us_per_call", "repeats": args.repeats,
                           "aggregated_runs": max(1, args.aggregate),
                           "rows": [{"name": n, "generated_us": round(tg, 1),
                                     "handwritten_us": round(th, 1),
                                     "ratio": round(r, 3),
                                     "generated_median_us": round(tgm, 1),
                                     "handwritten_median_us": round(thm, 1)}
                                    for n, tg, th, r, tgm, thm in rows]},
                          f, indent=1)
            print(f"[fig3] wrote {args.json_out}")
        if baseline is not None:
            # a program regresses only when BOTH estimators agree — the
            # median-of-pairs ratio AND the best-of-N ratio each >15%
            # worse than the SAME estimator's committed baseline (either
            # one alone flips on machine noise, and each must be held to
            # its own bar) — AND the regression reproduces on an
            # independent re-measurement of just the flagged programs.
            def _regressions(rws):
                return {n: (baseline[n][0], r, tg / th)
                        for n, tg, th, r, _m1, _m2 in rws
                        if n in baseline
                        and r > baseline[n][0] * 1.15
                        and tg / th > baseline[n][1] * 1.15}
            bad = _regressions(rows)
            if bad:
                print(f"[fig3] {len(bad)} candidate regression(s): "
                      f"{','.join(sorted(bad))}; re-measuring to confirm")
                rerun = programs.rows(args.scale, repeats=args.repeats,
                                      only=frozenset(bad))
                bad = {n: v for n, v in _regressions(rerun).items()
                       if n in bad}
            if bad:
                check_failed = True
                print("[fig3] REGRESSION GATE FAILED (median-paired AND "
                      "best-of-N ratios >15% worse than baseline, "
                      "confirmed by re-measurement):")
                for n, (old, new, new_min) in sorted(bad.items()):
                    print(f"  {n}: {old:.3f} -> {new:.3f} "
                          f"(best-of-N {new_min:.3f})")
            else:
                print(f"[fig3] regression gate OK "
                      f"({len(baseline)} baselines, none >15% worse)")
            # absolute pagerank gate (ISSUE 5): the iterative flagship
            # must stay within 1.15x of hand-written on BOTH estimators
            # (whole-program compilation holds it near parity; before the
            # fill-gather + loop-body work it sat at 1.233)
            _PR_GATE = 1.15

            def _pr_bad(rws):
                return {n: (r, tg / th) for n, tg, th, r, _m1, _m2 in rws
                        if n == "pagerank" and r > _PR_GATE
                        and tg / th > _PR_GATE}
            prb = _pr_bad(rows)
            if prb:
                print(f"[fig3] pagerank over the {_PR_GATE:.2f} absolute "
                      "gate; re-measuring to confirm")
                prb = _pr_bad(programs.rows(args.scale,
                                            repeats=args.repeats,
                                            only=frozenset(["pagerank"])))
            if prb:
                check_failed = True
                r, rmin = prb["pagerank"]
                print(f"[fig3] PAGERANK GATE FAILED (ratio {r:.3f} / "
                      f"best-of-N {rmin:.3f} > {_PR_GATE:.2f} on both "
                      "estimators, confirmed by re-measurement)")
            else:
                print(f"[fig3] pagerank gate OK (<= {_PR_GATE:.2f})")
        print()

    if "sec5" in sections:
        from benchmarks import tiled
        print("[sec5] packed/tiled matrices (paper §5)")
        print("name,us_per_call")
        for name, t in tiled.rows():
            print(f"{name},{t:.0f}")
        print()

    if "kernels" in sections:
        from benchmarks import kernels_bench
        print("[kernels] SegmentReduce backend candidates per shape class "
              "(DESIGN.md §8; None = skipped by work cap)")
        krows = kernels_bench.rows()
        kernels_bench.print_rows(krows)
        print()
        if args.kernels_json_out:
            import jax
            with open(args.kernels_json_out, "w") as f:
                json.dump({"section": "kernels", "unit": "us_per_call",
                           "platform": jax.default_backend(),
                           "rows": krows}, f, indent=1)
            print(f"[kernels] wrote {args.kernels_json_out}")
        print()

    if "dispatch" in sections:
        import subprocess
        from benchmarks import dispatch_bench
        print("[dispatch] run() per-call overhead, eager vs whole-program "
              "(DESIGN.md §9)")
        srows = dispatch_bench.single_rows()
        dispatch_bench.print_single(srows)
        print()
        print("[dispatch] distributed round fusion on vs off "
              "(fresh subprocess, forced host devices)")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.dispatch_bench", "--dist"],
            capture_output=True, text=True, cwd=_REPO, timeout=1800)
        drows = None
        for line in r.stdout.splitlines():
            if line.startswith(dispatch_bench._DIST_MARKER):
                drows = json.loads(line[len(dispatch_bench._DIST_MARKER):])
        if drows is None:
            print("[dispatch] distributed half FAILED:\n"
                  + r.stdout[-2000:] + r.stderr[-2000:])
            check_failed = True
        else:
            print(json.dumps(drows, indent=1))
        print()
        if args.dispatch_json_out and drows is not None:
            with open(args.dispatch_json_out, "w") as f:
                json.dump({"section": "dispatch", "unit": "us/ms per call",
                           "single_device": srows, "distributed": drows},
                          f, indent=1)
            print(f"[dispatch] wrote {args.dispatch_json_out}")
        print()

    if "dist" in sections:
        from benchmarks import distributed
        print("[dist] shardmap (inferred shardings) vs replicated "
              f"({distributed.mesh_devices()} forced host devices)")
        print("name,shardmap_ms,replicated_ms,sharded_dense_arrays")
        rows = distributed.rows(args.scale)
        for name, a, b, k in rows:
            print(f"{name},{a:.1f},{b:.1f},{k}")
        print()
        if args.dist_json_out:
            with open(args.dist_json_out, "w") as f:
                json.dump({"section": "dist", "scale": args.scale,
                           "devices": distributed.mesh_devices(),
                           "unit": "ms_per_run",
                           "rows": [{"name": n,
                                     "shardmap_ms": round(a, 2),
                                     "replicated_ms": round(b, 2),
                                     "sharded_dense_arrays": k}
                                    for n, a, b, k in rows]}, f, indent=1)
            print(f"[dist] wrote {args.dist_json_out}")
        if args.check and distributed.check_rows(rows, args.scale):
            check_failed = True

    if "skew" in sections:
        from benchmarks import skew_bench
        print("[skew] uniform vs Zipf(1.5) key streams, shardmap "
              f"({skew_bench.mesh_devices()} forced host devices)")
        print("name,uniform_ms,zipf_ms,ratio,salted")
        rows = skew_bench.rows(args.scale)
        for name, u, z, s in rows:
            print(f"{name},{u:.1f},{z:.1f},{z / u:.2f},{int(s)}")
        print()
        if args.skew_json_out:
            with open(args.skew_json_out, "w") as f:
                json.dump(skew_bench.to_json(rows, args.scale), f, indent=1)
            print(f"[skew] wrote {args.skew_json_out}")
        if args.check and skew_bench.check_rows(rows, args.scale):
            check_failed = True

    if "serve" in sections:
        from benchmarks import serve_bench
        print("[serve] PlanServer, mixed pagerank+group_by+kmeans "
              "workload, closed-loop clients (DESIGN.md §10)")
        rows = serve_bench.rows()
        serve_bench.print_rows(rows)
        print()
        if args.serve_json_out:
            with open(args.serve_json_out, "w") as f:
                json.dump(serve_bench.to_json(rows), f, indent=1)
            print(f"[serve] wrote {args.serve_json_out}")
        if args.check and serve_bench.check_rows(rows):
            check_failed = True

    if "faults" in sections:
        from benchmarks import faults_bench
        print("[faults] serve goodput under injected transients + "
              "mid-loop resume overhead (DESIGN.md §11)")
        rows = faults_bench.rows()
        faults_bench.print_rows(rows)
        print()
        if args.faults_json_out:
            with open(args.faults_json_out, "w") as f:
                json.dump(faults_bench.to_json(rows), f, indent=1)
            print(f"[faults] wrote {args.faults_json_out}")
        if args.check and faults_bench.check_rows(rows):
            check_failed = True

    if "outofcore" in sections:
        from benchmarks import outofcore_bench
        print("[outofcore] all-resident vs chunked streaming at 2x/10x "
              "over a simulated device budget (DESIGN.md §12)")
        rows = outofcore_bench.rows()
        outofcore_bench.print_rows(rows)
        print()
        if args.outofcore_json_out:
            with open(args.outofcore_json_out, "w") as f:
                json.dump(outofcore_bench.to_json(rows), f, indent=1)
            print(f"[outofcore] wrote {args.outofcore_json_out}")
        if args.check and outofcore_bench.check_rows(rows):
            check_failed = True

    if "recovery" in sections:
        import subprocess
        from benchmarks import recovery_bench
        print("[recovery] lineage shard recovery vs fault-free vs "
              "lineage-off restart, + speculative stragglers "
              "(DESIGN.md §13; fresh subprocess, forced host devices)")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.recovery_bench", "--dist"],
            capture_output=True, text=True, cwd=_REPO, timeout=1800)
        rrows = None
        for line in r.stdout.splitlines():
            if line.startswith(recovery_bench._MARKER):
                rrows = json.loads(line[len(recovery_bench._MARKER):])
        if rrows is None:
            print("[recovery] measurement subprocess FAILED:\n"
                  + r.stdout[-2000:] + r.stderr[-2000:])
            check_failed = True
        else:
            recovery_bench.print_rows(rrows)
            print()
            if args.recovery_json_out:
                with open(args.recovery_json_out, "w") as f:
                    json.dump(recovery_bench.to_json(rrows), f, indent=1)
                print(f"[recovery] wrote {args.recovery_json_out}")
            if args.check and recovery_bench.check_rows(rrows):
                check_failed = True

    if check_failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Robustness cost model (DESIGN.md §11): what do faults actually cost?

Two measurements, emitted as BENCH_faults.json via
``benchmarks.run --sections faults``:

* **Serve goodput under transient faults** — the mixed serve workload
  (reusing serve_bench's programs/shapes) at 64 closed-loop clients with
  0% / 5% / 20% of batched calls raising a scripted transient on first
  attempt.  Transients retry with the batch intact, so the gate is
  goodput (completed requests/sec) ≥ `gate` of the fault-free run —
  recovery overhead, not correctness, is what's being priced.

* **Mid-loop resume overhead** — an uninterrupted stepwise pagerank run
  vs kill-at-iteration-k + resume-from-snapshot (runtime/ft.LoopRunner
  through checkpoint/manager.py).  Reported as the resumed wall time
  (re-executes pre-loop nodes + the tail iterations) over the
  uninterrupted wall time; the bit-identity of the recovered ranks is
  asserted, not measured.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.serve_bench import MAX_BATCH, SPECS, _cps, make_inputs

CLIENTS = 64
REQUESTS = 192
FAULT_RATES = (0.0, 0.05, 0.20)
RESUME_STEPS = 12          # pagerank iterations for the resume measurement
KILL_AT = 8                # killed at this loop iteration (1-based hit)


def _transient_specs(rate: float, horizon: int = 10 ** 4):
    """Scripted transients on `rate` of the first `horizon` batched
    calls, evenly spaced — deterministic, replayable schedules."""
    from repro.core import faults as F
    if rate <= 0:
        return []
    stride = max(1, round(1.0 / rate))
    return [F.FaultSpec("serve.batched_call", "transient", nth=n)
            for n in range(1, horizon, stride)]


def _measure_goodput(rate: float, requests: int = REQUESTS) -> dict:
    from repro.core import faults as F
    from repro.serve import PlanServer
    srv = PlanServer(_cps(), max_batch=MAX_BATCH, flush_ms=1.0)
    srv.policy.backoff_s = 1e-4          # price retries, not sleeps
    pool = [make_inputs(name, m, seed=i)
            for i, (name, m) in enumerate(SPECS)]
    t0 = time.monotonic()
    submitted = 0
    with F.inject(*_transient_specs(rate)):
        while submitted < requests:
            round_n = min(CLIENTS, requests - submitted)
            tickets = []
            for c in range(round_n):
                name, _ = SPECS[(submitted + c) % len(SPECS)]
                tickets.append(srv.submit(
                    name, pool[(submitted + c) % len(SPECS)]))
            submitted += round_n
            srv.pump()
            srv.drain()
            assert all(t.state == "done" for t in tickets)
    elapsed = time.monotonic() - t0
    s = srv.stats()
    assert s["completed"] == requests and s["failed"] == 0
    return {"fault_rate_pct": round(100 * rate, 1),
            "goodput_rps": round(requests / elapsed, 1),
            "retries": s["retries"],
            "failed_flushes": s["failed_flushes"],
            "bisections": s["bisections"]}


def _pagerank_inputs(steps: int) -> dict:
    rng = np.random.default_rng(7)
    N, ne = 64, 512
    return dict(E=(rng.integers(0, N, ne).astype(np.float64),
                   rng.integers(0, N, ne).astype(np.float64)),
                P=np.full(N, 1.0 / N), NP=np.zeros(N), C=np.zeros(N),
                N=N, num_steps=float(steps), steps=0.0, b=0.85)


def _measure_resume() -> dict:
    from repro.core import faults as F
    from repro.core.lower import compile_program
    from repro.core.programs import pagerank
    from repro.runtime import LoopRunner
    cp = compile_program(pagerank)
    ins = _pagerank_inputs(RESUME_STEPS)
    cp.run_stepwise(dict(ins))                      # warmup (traces)
    t0 = time.monotonic()
    ref = cp.run_stepwise(dict(ins))
    t_plain = time.monotonic() - t0
    with tempfile.TemporaryDirectory() as d:
        runner = LoopRunner(cp, d, every=1)
        t0 = time.monotonic()
        try:
            with F.inject(F.FaultSpec("lower.loop_iter", "deterministic",
                                      nth=KILL_AT, message="kill")):
                runner.run(dict(ins), resume=False)
            raise AssertionError("kill never fired")
        except F.DeterministicFault:
            pass
        t_to_kill = time.monotonic() - t0
        resumed = LoopRunner(cp, d, every=1)
        t0 = time.monotonic()
        out = resumed.run(dict(ins), resume=True)
        t_resume = time.monotonic() - t0
    assert all(np.array_equal(np.asarray(out[k]), np.asarray(ref[k]))
               for k in ref), "resume must be bit-identical"
    return {"steps": RESUME_STEPS, "killed_at": KILL_AT,
            "uninterrupted_s": round(t_plain, 4),
            "run_to_kill_s": round(t_to_kill, 4),
            "resume_s": round(t_resume, 4),
            "resume_overhead_x": round(t_resume / t_plain, 3)
            if t_plain > 0 else 0.0,
            "snapshots": runner.saves,
            "resumed_from_step": resumed.resumed_from}


def rows() -> dict:
    _measure_goodput(0.0, requests=max(len(SPECS), CLIENTS))  # warmup
    return {"goodput": [_measure_goodput(r) for r in FAULT_RATES],
            "resume": _measure_resume()}


def print_rows(rws) -> None:
    print("fault_rate_pct,goodput_rps,retries,failed_flushes")
    for r in rws["goodput"]:
        print(f"{r['fault_rate_pct']},{r['goodput_rps']:.0f},"
              f"{r['retries']},{r['failed_flushes']}")
    rs = rws["resume"]
    print(f"resume: uninterrupted={rs['uninterrupted_s']}s "
          f"resume={rs['resume_s']}s "
          f"overhead={rs['resume_overhead_x']}x "
          f"(killed at {rs['killed_at']}/{rs['steps']})")


def to_json(rws) -> dict:
    import jax
    return {"section": "faults", "unit": "requests_per_sec",
            "platform": jax.default_backend(),
            "clients": CLIENTS, "max_batch": MAX_BATCH,
            "fault_rates": list(FAULT_RATES), **rws}


def check_rows(rws, gate: float = 0.5) -> bool:
    """--check gate: goodput at 20% injected transients must stay ≥
    `gate` of the fault-free goodput (each transient costs one extra
    batched call plus a tiny backoff — losing more than half means the
    retry path regressed), and resume must not cost more than the
    uninterrupted run plus the re-executed prefix (≤ 2× is generous on
    CPU timer noise)."""
    by = {r["fault_rate_pct"]: r["goodput_rps"] for r in rws["goodput"]}
    worst, clean = by[max(by)], by[0.0]
    bad = False
    if worst < gate * clean:
        print(f"[faults] GOODPUT GATE FAILED: {worst:.0f} rps at "
              f"{max(by)}% faults < {gate}x fault-free {clean:.0f} rps")
        bad = True
    else:
        print(f"[faults] goodput gate OK ({worst / clean:.2f}x of "
              "fault-free under 20% transients)")
    ov = rws["resume"]["resume_overhead_x"]
    if ov > 2.0:
        print(f"[faults] RESUME GATE FAILED: overhead {ov}x > 2.0x")
        bad = True
    else:
        print(f"[faults] resume overhead OK ({ov}x of uninterrupted)")
    return bad

"""Paper Table 1: translation (compile) time per benchmark program.

The paper reports DIABLO at 5–14.5 s (scalac-based), MOLD at 11–340 s and
CASPER at 10 s–19 h (program synthesis).  Our compositional translator runs
in milliseconds per program because it is rule-driven (no template search,
no synthesis) — validating the paper's central efficiency claim, and then
some.  Columns: name, translate_ms (frontend+check+Fig.2 rules+plan
pipeline, i.e. the full `compile_program` path to an executable
CompiledProgram), first_run_ms (includes XLA jit of the bulk plan).

Runnable standalone:  python benchmarks/translation_time.py
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def rows():
    from repro.core import compile_program
    from repro.core.programs import ALL
    from tests.test_core_programs import data_for  # reuse dataset builders

    out = []
    for name, fn in sorted(ALL.items()):
        t0 = time.perf_counter()
        for _ in range(5):
            cp = compile_program(fn)
        t_translate = (time.perf_counter() - t0) / 5 * 1e3
        ins = data_for(name)
        t1 = time.perf_counter()
        res = cp.run(ins)
        for v in res.values():
            np.asarray(v)
        t_first = (time.perf_counter() - t1) * 1e3
        out.append((name, t_translate, t_first))
    return out


def main():
    print("name,translate_ms,first_run_ms")
    for name, a, b in rows():
        print(f"{name},{a:.2f},{b:.1f}")


if __name__ == "__main__":
    main()

"""Distributed section: shardmap (inferred shardings) vs REP-everything
replicated execution per program, on a forced-host-device mesh.

Run standalone (forces 8 host devices before importing jax):

  python benchmarks/distributed.py

or as a section of the harness: python -m benchmarks.run --sections dist
(emits BENCH_distributed.json, uploaded as a CI artifact).
"""
from __future__ import annotations

import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

DEVICES = 8


def _force_devices():
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")


def mesh_devices() -> int:
    """Devices actually used: respects a pre-set XLA_FLAGS (e.g. the CI
    matrix forcing 4) instead of assuming the default of 8."""
    import jax
    return min(DEVICES, len(jax.devices()))


def _cases(scale: int):
    # sized for forced host devices on a CI CPU: the point is placement
    # coverage (every strategy exercised), not saturating an accelerator
    import numpy as np
    rng = np.random.default_rng(23)
    nv, ne, npts = 128 * scale, 1024 * scale, 512 * scale
    n, m, l = 32 * scale, 24 * scale, 8
    return {
        "word_count": dict(W=rng.integers(0, nv, ne).astype(np.float64),
                           C=np.zeros(nv)),
        "group_by": dict(S=(rng.integers(0, nv, ne).astype(np.float64),
                            rng.standard_normal(ne)), C=np.zeros(nv)),
        "pagerank": dict(E=(rng.integers(0, nv, ne).astype(np.float64),
                            rng.integers(0, nv, ne).astype(np.float64)),
                         P=np.full(nv, 1 / nv), NP=np.zeros(nv),
                         C=np.zeros(nv), N=nv, num_steps=2.0, steps=0.0,
                         b=0.85),
        "kmeans_step": dict(P=(rng.standard_normal(npts) * 3,
                               rng.standard_normal(npts) * 3),
                            CX=rng.standard_normal(8),
                            CY=rng.standard_normal(8), K=8,
                            D=np.zeros((npts, 8)), MinD=np.full(npts, 1e30),
                            Cl=np.zeros(npts), SX=np.zeros(8),
                            SY=np.zeros(8), CN=np.zeros(8), NX=np.zeros(8),
                            NY=np.zeros(8)),
        "matrix_factorization_step": dict(
            R=rng.standard_normal((n, m)),
            P=rng.standard_normal((n, l)) * 0.1,
            Q=rng.standard_normal((l, m)) * 0.1,
            Pp=rng.standard_normal((n, l)) * 0.1,
            Qp=rng.standard_normal((l, m)) * 0.1,
            pq=np.zeros((n, m)), err=np.zeros((n, m)),
            n=n, m=m, l=l, a=0.01, lam=0.1),
    }


def _time(fn, reps=2):
    import numpy as np
    for v in fn().values():                # warm-up / compile, synchronized
        np.asarray(v)
    t0 = time.perf_counter()
    for _ in range(reps):
        for v in fn().values():
            np.asarray(v)
    return (time.perf_counter() - t0) / reps * 1e3


def rows(scale: int = 1):
    """[(name, shardmap_ms, replicated_ms, sharded_arrays)] on a forced
    host mesh — placement quality, not absolute speed (CPU psum is the
    bottleneck; the point is that both paths stay correct and the sharded
    path is exercised end to end)."""
    _force_devices()
    from repro.core import compile_program
    from repro.core.dist_analysis import Dist
    from repro.core.distributed import compile_distributed
    from repro.core.programs import ALL
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((mesh_devices(),), ("data",))
    out = []
    for name, ins in _cases(scale).items():
        cp = compile_program(ALL[name])
        sharded = sum(d >= Dist.ONED_ROW for d in cp.dists.values())
        dp = compile_distributed(cp, mesh, ("data",), mode="shardmap")
        rep = compile_distributed(cp, mesh, ("data",), mode="shardmap",
                                  shard_dense=False)
        t_shard = _time(lambda: dp.run(ins))
        t_rep = _time(lambda: rep.run(ins))
        out.append((name, t_shard, t_rep, sharded))
    return out


def main():
    print("name,shardmap_ms,replicated_ms,sharded_dense_arrays")
    for name, a, b, k in rows():
        print(f"{name},{a:.1f},{b:.1f},{k}")


if __name__ == "__main__":
    main()

"""Distributed section: shardmap (inferred shardings) vs REP-everything
replicated execution per program, on a forced-host-device mesh.

Run standalone (forces 8 host devices before importing jax):

  python benchmarks/distributed.py [--check]

or as a section of the harness: python -m benchmarks.run --sections dist
[--check] (emits BENCH_distributed.json, uploaded as a CI artifact).

--check is the sharded-group-by regression gate (wired into the
`distributed` CI job): it FAILS (exit 1) when shardmap is more than 10%
slower than replicated on any benchmarked program — i.e. when inferred
placement makes a program worse than replicating everything.  The
group-by family (word_count, group_by) is exactly where this used to
fail before the operator-selection subsystem (DESIGN.md §8); the gate
keeps it green.  A candidate regression is confirmed by an independent
re-measurement of just the flagged programs before failing (host-device
collective timings are noisy).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

DEVICES = 8


def _force_devices():
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={DEVICES}")


def mesh_devices() -> int:
    """Devices actually used: respects a pre-set XLA_FLAGS (e.g. the CI
    matrix forcing 4) instead of assuming the default of 8."""
    import jax
    return min(DEVICES, len(jax.devices()))


def _cases(scale: int):
    # sized for forced host devices on a CI CPU: the point is placement
    # coverage (every strategy exercised), not saturating an accelerator
    import numpy as np
    rng = np.random.default_rng(23)
    nv, ne, npts = 128 * scale, 1024 * scale, 512 * scale
    n, m, l = 32 * scale, 24 * scale, 8
    return {
        "word_count": dict(W=rng.integers(0, nv, ne).astype(np.float64),
                           C=np.zeros(nv)),
        "group_by": dict(S=(rng.integers(0, nv, ne).astype(np.float64),
                            rng.standard_normal(ne)), C=np.zeros(nv)),
        "pagerank": dict(E=(rng.integers(0, nv, ne).astype(np.float64),
                            rng.integers(0, nv, ne).astype(np.float64)),
                         P=np.full(nv, 1 / nv), NP=np.zeros(nv),
                         C=np.zeros(nv), N=nv, num_steps=2.0, steps=0.0,
                         b=0.85),
        "kmeans_step": dict(P=(rng.standard_normal(npts) * 3,
                               rng.standard_normal(npts) * 3),
                            CX=rng.standard_normal(8),
                            CY=rng.standard_normal(8), K=8,
                            D=np.zeros((npts, 8)), MinD=np.full(npts, 1e30),
                            Cl=np.zeros(npts), SX=np.zeros(8),
                            SY=np.zeros(8), CN=np.zeros(8), NX=np.zeros(8),
                            NY=np.zeros(8)),
        "matrix_factorization_step": dict(
            R=rng.standard_normal((n, m)),
            P=rng.standard_normal((n, l)) * 0.1,
            Q=rng.standard_normal((l, m)) * 0.1,
            Pp=rng.standard_normal((n, l)) * 0.1,
            Qp=rng.standard_normal((l, m)) * 0.1,
            pq=np.zeros((n, m)), err=np.zeros((n, m)),
            n=n, m=m, l=l, a=0.01, lam=0.1),
    }


def _time_pair(fn_a, fn_b, pairs=5, reps=2):
    """(min_a_ms, min_b_ms) over `pairs` INTERLEAVED passes — the fig3
    methodology (benchmarks/programs.py): adjacent a/b passes see the
    same machine conditions, so background-load drift is common-mode
    within a pair, and the min absorbs collective-timing spikes (host
    psum/psum_scatter swing ±50% on a loaded CI box)."""
    import numpy as np

    def one_pass(fn):
        t0 = time.perf_counter()
        for _ in range(reps):
            for v in fn().values():
                np.asarray(v)
        return (time.perf_counter() - t0) / reps * 1e3

    for fn in (fn_a, fn_b):                # warm-up / compile, synchronized
        for v in fn().values():
            np.asarray(v)
    ta, tb = [], []
    for i in range(pairs):
        # alternate which side runs first: periodic interference otherwise
        # lands disproportionately on the second position of every pair
        if i % 2 == 0:
            ta.append(one_pass(fn_a))
            tb.append(one_pass(fn_b))
        else:
            tb.append(one_pass(fn_b))
            ta.append(one_pass(fn_a))
    return min(ta), min(tb)


def rows(scale: int = 1, only=None, pairs: int = 5):
    """[(name, shardmap_ms, replicated_ms, sharded_arrays)] on a forced
    host mesh — placement quality, not absolute speed (CPU psum is the
    bottleneck; the point is that both paths stay correct and the sharded
    path is exercised end to end).  `only` restricts measurement to a set
    of program names (the --check gate re-measures flagged programs
    before failing)."""
    _force_devices()
    from repro.core import compile_program
    from repro.core.dist_analysis import Dist
    from repro.core.distributed import compile_distributed
    from repro.core.programs import ALL
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((mesh_devices(),), ("data",))
    out = []
    for name, ins in _cases(scale).items():
        if only is not None and name not in only:
            continue
        cp = compile_program(ALL[name])
        sharded = sum(d >= Dist.ONED_ROW for d in cp.dists.values())
        dp = compile_distributed(cp, mesh, ("data",), mode="shardmap")
        rep = compile_distributed(cp, mesh, ("data",), mode="shardmap",
                                  shard_dense=False)
        t_shard, t_rep = _time_pair(lambda: dp.run(ins),
                                    lambda: rep.run(ins), pairs=pairs)
        out.append((name, t_shard, t_rep, sharded))
    return out


_SLOWDOWN_GATE = 1.10     # shardmap >10% slower than replicated fails


def check_rows(measured, scale: int = 1) -> bool:
    """The sharded-vs-replicated regression gate.  True = FAILED.  A
    program is flagged when shardmap > 1.1 × replicated; every flagged
    program is re-measured independently and only a reproduced slowdown
    fails the gate (single-pass host-collective timings flip on noise)."""
    def _bad(rws):
        return {n: (a, b) for n, a, b, _k in rws
                if a > b * _SLOWDOWN_GATE}
    bad = _bad(measured)
    if bad:
        print(f"[dist] {len(bad)} candidate slowdown(s): "
              f"{','.join(sorted(bad))}; re-measuring to confirm")
        # confirmation pass at higher depth: interleaved mins at 11 pairs
        # push the noise floor below the 10% gate on a loaded CI box
        rerun = rows(scale, only=frozenset(bad), pairs=11)
        bad = {n: v for n, v in _bad(rerun).items() if n in bad}
    if bad:
        print("[dist] SHARDED-GROUP-BY GATE FAILED (shardmap >10% slower "
              "than replicated, confirmed by re-measurement):")
        for n, (a, b) in sorted(bad.items()):
            print(f"  {n}: shardmap {a:.1f}ms vs replicated {b:.1f}ms "
                  f"({a / b:.2f}x)")
        return True
    print(f"[dist] sharded-group-by gate OK ({len(measured)} programs, "
          f"shardmap <= {_SLOWDOWN_GATE:.2f}x replicated everywhere)")
    return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when shardmap is >10%% slower than "
                         "replicated on any program (re-measured to "
                         "confirm)")
    args = ap.parse_args()
    measured = rows(args.scale)
    print("name,shardmap_ms,replicated_ms,sharded_dense_arrays")
    for name, a, b, k in measured:
        print(f"{name},{a:.1f},{b:.1f},{k}")
    if args.check and check_rows(measured, args.scale):
        sys.exit(1)


if __name__ == "__main__":
    main()

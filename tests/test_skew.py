"""Skew-aware distribution (DESIGN.md §6): hot-key salting decision
goldens under the cost model, probe goldens, cache overrides, degenerate
key streams (all-one-key, Zipf(1.5), negative/out-of-range, empty after
filter) equivalent across every segment backend × salting mode, and the
salted explain()/explain_rounds() observable contract."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import bag, compile_program, loop_program, map_, matrix
from repro.core.op_select import OpSelector, probe_hot_fraction
from repro.core.programs import ALL

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# degenerate programs defined here (not part of the paper's Fig. 3 set)
# ---------------------------------------------------------------------------

@loop_program
def filtered_sum(S: bag[2], C: map_):
    for k, v in S:
        if v > 0.0:
            C[k] += v


@loop_program
def pair_hist(S: bag[2], C: matrix):
    for i, j in S:
        C[i, j] += 1.0


# ---------------------------------------------------------------------------
# decision-table goldens: choose_salt is a deterministic function of the
# (n, k, op, nshards, hot-bucket) class and platform
# ---------------------------------------------------------------------------

def test_salt_decision_table_cpu_never_salts():
    # the CPU scatter loop is sequential whether keys collide or not
    # (dup_row=0): cost mode must keep S=1 even on a one-key column
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    for hot in (0.0, 0.02, 0.4, 1.0):
        dec = sel.choose_salt(n=1 << 16, k=1024, op="+", nshards=8,
                              hot_frac=hot)
        assert dec.backend == "none", (hot, dec)


def test_salt_decision_table_tpu():
    # hardware scatters serialize colliding updates (dup_row=1): a hot
    # key pays, a uniform stream must NOT be salted (fold cost only)
    sel = OpSelector(mode="cost", cache_path=None, platform="tpu")
    hot = sel.choose_salt(n=1 << 16, k=1024, op="+", nshards=8,
                          hot_frac=0.4)
    assert hot.backend == "salt:16", hot
    one_key = sel.choose_salt(n=1 << 16, k=1024, op="+", nshards=8,
                              hot_frac=1.0)
    assert one_key.backend == "salt:16", one_key
    uniform = sel.choose_salt(n=1 << 16, k=1024, op="+", nshards=8,
                              hot_frac=1.0 / 1024)
    assert uniform.backend == "none", uniform
    assert hot.source == "cost"


def test_salt_skew_guard_fair_share():
    # a key holding less than ~4x its fair 1/K share is not "hot": the
    # collision chain is the inherent n/K every group-by pays, so the
    # guard declines before the cost comparison even on TPU
    sel = OpSelector(mode="cost", cache_path=None, platform="tpu")
    dec = sel.choose_salt(n=1 << 16, k=1024, op="+", nshards=8,
                          hot_frac=3.9 / 1024)
    assert dec.backend == "none"
    dec = sel.choose_salt(n=1 << 16, k=4, op="+", nshards=8,
                          hot_frac=0.9)  # k=4: 0.9 < 4 * 0.25 fair share
    assert dec.backend == "none"


def test_salt_cache_entry_overrides_cost(tmp_path):
    # the autotune cache is the override channel in every mode: a pinned
    # salt class must be honored by cost mode (source "cache") — this is
    # also how mesh-owning benchmarks teach CPU runs to salt
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    cls = sel.salt_class(512, 32, "+", 1, 1.0)
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "version": 1, "platform": "cpu",
        "decisions": {cls: {"backend": "salt:8"}}}))
    pinned = OpSelector(mode="cost", cache_path=str(path), platform="cpu")
    dec = pinned.choose_salt(n=512, k=32, op="+", nshards=1, hot_frac=1.0)
    assert (dec.backend, dec.source) == ("salt:8", "cache")
    # a different skew bucket is a different class: the pin must not fire
    miss = pinned.choose_salt(n=512, k=32, op="+", nshards=1,
                              hot_frac=1.0 / 32)
    assert miss.backend == "none"


def test_probe_hot_fraction():
    assert probe_hot_fraction(np.zeros(100)) == 1.0
    assert probe_hot_fraction(np.array([])) == 0.0
    assert probe_hot_fraction(np.arange(64.0)) == 1.0 / 64
    # the probe reads a bounded prefix: O(1) host work per trace
    big = np.arange(1 << 20, dtype=np.float64)
    assert probe_hot_fraction(big, cap=4096) == 1.0 / 4096


# ---------------------------------------------------------------------------
# degenerate key streams × segment backend × salting mode: every
# combination must agree with the unsalted scatter reference
# ---------------------------------------------------------------------------

_NV, _NE = 32, 512


def _streams():
    rng = np.random.default_rng(41)
    vals = rng.standard_normal(_NE)
    yield "one_key", np.zeros(_NE), vals
    yield "zipf", ((rng.zipf(1.5, _NE) - 1) % _NV).astype(np.float64), vals
    yield "neg_oob", rng.integers(-_NV, 2 * _NV, _NE).astype(np.float64), \
        vals
    # empty-after-filter: no row survives `v > 0` in filtered_sum
    yield "all_filtered", rng.integers(0, _NV, _NE).astype(np.float64), \
        -np.abs(vals) - 1.0


def _cases(keys, vals):
    return [
        ("word_count", dict(W=keys.copy(), C=np.zeros(_NV))),
        ("group_by", dict(S=(keys.copy(), vals.copy()), C=np.zeros(_NV))),
        (filtered_sum, dict(S=(keys.copy(), vals.copy()),
                            C=np.zeros(_NV))),
    ]


def _reference(prog, ins):
    cp = compile_program(ALL[prog] if isinstance(prog, str) else prog,
                         op_select="force:scatter", skew_salting="off")
    return np.asarray(cp.run(ins)["C"], np.float64)


@pytest.mark.parametrize("backend", ["scatter", "sort", "onehot",
                                     "pallas"])
@pytest.mark.parametrize("salting", ["off", "force:4"])
def test_degenerate_streams_equivalent(backend, salting):
    for stream, keys, vals in _streams():
        for (prog, ins), (_, ref_ins) in zip(_cases(keys, vals),
                                             _cases(keys, vals)):
            ref = _reference(prog, ref_ins)
            cp = compile_program(
                ALL[prog] if isinstance(prog, str) else prog,
                op_select=f"force:{backend}", skew_salting=salting)
            got = np.asarray(cp.run(ins)["C"], np.float64)
            err = np.abs(got - ref).max()
            name = prog if isinstance(prog, str) else "filtered_sum"
            assert err < 1e-4, (name, stream, backend, salting, err)


def test_empty_filter_stays_zero():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, _NV, _NE).astype(np.float64)
    ins = dict(S=(keys, -np.ones(_NE)), C=np.zeros(_NV))
    cp = compile_program(filtered_sum, skew_salting="force:4")
    assert np.abs(np.asarray(cp.run(ins)["C"])).max() == 0.0


# ---------------------------------------------------------------------------
# the observable contract: static hints and run-time probes show up in
# explain(); shapes salting cannot express are skipped, not broken
# ---------------------------------------------------------------------------

def test_forced_salt_is_visible_in_explain():
    rng = np.random.default_rng(5)
    ins = dict(W=rng.integers(0, _NV, _NE).astype(np.float64),
               C=np.zeros(_NV))
    cp = compile_program(ALL["word_count"], skew_salting="force:4")
    cp.run(ins)
    assert "salt=4x[hint]" in cp.explain(), cp.explain()


def test_probe_salts_only_the_skewed_stream(tmp_path):
    # "auto" mode: the run-time probe keys both the decision and the
    # compile cache.  A cache entry pinned at the one-key skew bucket
    # (dup_row=0 on CPU means cost alone never salts here) fires for the
    # all-one-key stream and must NOT fire for a uniform stream through
    # the SAME CompiledProgram.
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    cls = sel.salt_class(_NE, _NV, "+", 1, 1.0)
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "version": 1, "platform": "cpu",
        "decisions": {cls: {"backend": "salt:8"}}}))
    cp = compile_program(ALL["word_count"], autotune_cache=str(path),
                         skew_salting="auto")
    uniform = np.arange(_NE, dtype=np.float64) % _NV
    cp.run(dict(W=uniform.copy(), C=np.zeros(_NV)))
    assert "salt=" not in cp.explain(), cp.explain()
    skewed = np.zeros(_NE)
    out = cp.run(dict(W=skewed, C=np.zeros(_NV)))
    assert "salt=8x[probe]" in cp.explain(), cp.explain()
    want = np.zeros(_NV)
    want[0] = _NE
    assert np.abs(np.asarray(out["C"]) - want).max() < 1e-4


def test_multikey_2d_dest_skips_salting():
    # C[i, j] has two key columns: the salted rewrite only covers the
    # single-key 1-D map form, so a force:<S> pin must be a no-op here
    rng = np.random.default_rng(3)
    k1 = rng.integers(0, 8, 256).astype(np.float64)
    k2 = rng.integers(0, 8, 256).astype(np.float64)
    cp = compile_program(pair_hist, skew_salting="force:4")
    out = cp.run(dict(S=(k1.copy(), k2.copy()), C=np.zeros((8, 8))))
    ref = np.zeros((8, 8))
    np.add.at(ref, (k1.astype(int), k2.astype(int)), 1.0)
    assert np.abs(np.asarray(out["C"]) - ref).max() < 1e-4
    assert "salt=" not in cp.explain()


# ---------------------------------------------------------------------------
# distributed: degenerate streams through both exchanges × salting on a
# forced 8-device host mesh, equivalent to single-device; the salted
# round is visible in explain_rounds()
# ---------------------------------------------------------------------------

_DIST_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(13)

def streams(nv, ne):
    return {
        "one_key": np.zeros(ne),
        "zipf": ((rng.zipf(1.5, ne) - 1) % nv).astype(np.float64),
        "neg_oob": rng.integers(-nv, 2 * nv, ne).astype(np.float64),
    }

def run_case(nv, ne, op_select, salting, want, forbid=()):
    for stream, keys in streams(nv, ne).items():
        vals = rng.standard_normal(ne)
        cp = compile_program(ALL["group_by"], op_select=op_select,
                             skew_salting=salting)
        dp = compile_distributed(cp, mesh, ("data",), mode="shardmap")
        out = dp.run(dict(S=(keys.copy(), vals.copy()), C=np.zeros(nv)))
        single = compile_program(ALL["group_by"]).run(
            dict(S=(keys.copy(), vals.copy()), C=np.zeros(nv)))
        err = np.abs(np.asarray(out["C"], np.float64)
                     - np.asarray(single["C"], np.float64)).max()
        assert err < 1e-4, (stream, op_select, salting, err)
        text = dp.explain_rounds()
        for w in want:
            assert w in text, (stream, w, text)
        for f in forbid:
            assert f not in text, (stream, f, text)

# large K, reduce-scatter exchange, salted rounds: the key*S+salt
# sub-destinations fold back before the exchange, so the wire format
# (dense [K] partial) is unchanged
run_case(1 << 19, 4096, "force:psum_scatter", "force:4",
         want=["reduce(psum_scatter", "salt=4x[hint]"])
# same shapes through the allreduce exchange, unsalted
run_case(1 << 19, 4096, "force:allreduce", "off",
         want=["reduce(allreduce[forced]"], forbid=["salt="])
# small K demotes the destination to REP (plain psum): salting must
# compose with the replicated round too
run_case(128, 2048, "cost", "force:4",
         want=["placement: C→REP", "salt=4x[hint]"])
print("SKEW_DIST_OK")
"""


@pytest.mark.slow
def test_distributed_degenerate_streams():
    r = subprocess.run([sys.executable, "-c", _DIST_CODE],
                       capture_output=True, text=True, cwd=_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SKEW_DIST_OK" in r.stdout

import os
import sys

import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single-device CPU; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# deterministic serving harness (shared by test_serve_plans / test_serve_*)
# ---------------------------------------------------------------------------

class FakeClock:
    """Injectable monotonic clock: time only moves when the test says so,
    so flush-timeout scheduling decisions replay exactly — no real sleeps,
    no wall-clock flakiness."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        assert dt >= 0, "monotonic clocks do not rewind"
        self.t += dt


def run_schedule(clock: FakeClock, events, pump):
    """Replay a scripted arrival schedule against an injected clock:
    `events` is a sequence of (t_seconds, thunk) in non-decreasing time
    order; between events the clock jumps (never sleeps) and `pump()` runs
    once per distinct timestamp so timeout flushes fire exactly where the
    script puts them.  Returns the total number of completions pump
    reported."""
    done = 0
    for t, thunk in events:
        assert t >= clock.t, "schedule must be time-ordered"
        if t > clock.t:
            clock.advance(t - clock.t)
            done += pump()
        thunk()
        done += pump()
    return done


@pytest.fixture
def fake_clock():
    return FakeClock()

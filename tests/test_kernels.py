"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps plus
hypothesis-generated segment ids.  Kernels execute in interpret mode (CPU
container; TPU is the lowering target)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:        # the property test is hypothesis-driven; everything else runs
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import flash_attention_ref
from repro.kernels.segment_reduce import segment_reduce, segment_sum
from repro.kernels.segment_reduce_ref import (segment_reduce_ref,
                                              segment_sum_ref)
from repro.kernels.tile_matmul import tile_matmul
from repro.kernels.tile_matmul_ref import tile_matmul_ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n,d,k,dtype", [
    (64, 16, 8, np.float32),
    (200, 33, 17, np.float32),
    (128, 8, 128, np.float32),
    (100, 24, 10, np.bfloat16) if hasattr(np, "bfloat16") else
    (100, 24, 10, np.float32),
])
def test_segment_sum_shapes(n, d, k, dtype):
    ids = rng.integers(0, k, n).astype(np.int32)
    vals = rng.standard_normal((n, d)).astype(np.float32)
    a = segment_sum(jnp.asarray(ids), jnp.asarray(vals), k, bn=32, bk=16,
                    bd=16)
    b = segment_sum_ref(jnp.asarray(ids), jnp.asarray(vals), k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_segment_sum_out_of_range_dropped():
    ids = np.array([0, 5, 99, -1, 2], np.int32)  # 99/-1 out of range
    vals = np.ones((5, 4), np.float32)
    a = segment_sum(jnp.asarray(ids), jnp.asarray(vals), 6)
    b = segment_sum_ref(jnp.asarray(ids), jnp.asarray(vals), 6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def _property_cases():
    """Randomized (n, d, k, seed) cases: hypothesis-generated when the
    package is available, a fixed seeded sweep otherwise."""
    if _HAVE_HYPOTHESIS:
        return None
    r = np.random.default_rng(2024)
    return [(int(r.integers(1, 60)), int(r.integers(1, 12)),
             int(r.integers(1, 20)), int(r.integers(0, 2**31 - 1)))
            for _ in range(20)]


def _check_segment_sum_case(n, d, k, seed):
    r = np.random.default_rng(seed)
    ids = r.integers(0, k, n).astype(np.int32)
    vals = r.standard_normal((n, d)).astype(np.float32)
    a = segment_sum(jnp.asarray(ids), jnp.asarray(vals), k, bn=16, bk=8, bd=8)
    b = segment_sum_ref(jnp.asarray(ids), jnp.asarray(vals), k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


if _HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 60), st.integers(1, 12), st.integers(1, 20),
           st.integers(0, 2**31 - 1))
    def test_segment_sum_property(n, d, k, seed):
        _check_segment_sum_case(n, d, k, seed)
else:
    @pytest.mark.parametrize("n,d,k,seed", _property_cases())
    def test_segment_sum_property(n, d, k, seed):
        _check_segment_sum_case(n, d, k, seed)


# ---------------------------------------------------------------------------
# generalized segment_reduce: natural [N]/[N, D] values, min/max via the
# one-hot select path, exact-int accumulation, K/D not multiples of the
# block sizes, and the negative-key sentinel
# ---------------------------------------------------------------------------

def test_segment_reduce_1d_values():
    ids = rng.integers(0, 7, 50).astype(np.int32)
    vals = rng.standard_normal(50).astype(np.float32)
    a = segment_reduce(jnp.asarray(ids), jnp.asarray(vals), 7, bn=16, bk=4)
    b = segment_reduce_ref(jnp.asarray(ids), jnp.asarray(vals), 7)
    assert a.shape == (7,)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("op", ["+", "min", "max"])
@pytest.mark.parametrize("n,d,k", [(100, 33, 17), (65, 1, 5), (31, 9, 13)])
def test_segment_reduce_ops_nonmultiple_blocks(op, n, d, k):
    # K and D deliberately NOT multiples of bk/bd: the pad rows/columns
    # must never leak the ⊕ identity into kept outputs
    ids = rng.integers(0, k, n).astype(np.int32)
    vals = rng.standard_normal((n, d)).astype(np.float32)
    a = segment_reduce(jnp.asarray(ids), jnp.asarray(vals), k, op=op,
                       bn=16, bk=8, bd=8)
    b = segment_reduce_ref(jnp.asarray(ids), jnp.asarray(vals), k, op=op)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("op", ["+", "min", "max"])
def test_segment_reduce_negative_and_oob_sentinel(op):
    ids = np.array([0, 3, -1, 99, 2, -7, 1], np.int32)  # -1/-7/99 drop
    vals = np.arange(1.0, 8.0, dtype=np.float32)
    a = segment_reduce(jnp.asarray(ids), jnp.asarray(vals), 5, op=op,
                       bn=4, bk=4)
    b = segment_reduce_ref(jnp.asarray(ids), jnp.asarray(vals), 5, op=op)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_segment_reduce_exact_int_accumulation():
    # 16777217 = 2**24 + 1 is not representable in fp32: a fp32-rounding
    # path would sum 16777216 + 1; the exact-int path must return 2**24+2
    ids = jnp.asarray(np.zeros(2, np.int32))
    vals = jnp.asarray(np.array([2**24 + 1, 1], np.int32))
    a = segment_reduce(ids, vals, 1)
    assert a.dtype == jnp.int32
    assert int(a[0]) == 2**24 + 2
    # min/max on ints keep the integer dtype too
    m = segment_reduce(ids, vals, 1, op="max")
    assert m.dtype == jnp.int32 and int(m[0]) == 2**24 + 1


@pytest.mark.parametrize("m,k,n,bm", [(64, 32, 48, 32), (100, 70, 90, 32),
                                      (33, 17, 9, 16), (128, 128, 128, 128)])
def test_tile_matmul_shapes(m, k, n, bm):
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    c = tile_matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bm, bk=bm)
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-4, atol=1e-3)


def test_tile_matmul_bf16():
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    c = tile_matmul(jnp.asarray(a, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16),
                    bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=5e-2, atol=5e-1)


def test_tile_matmul_masked():
    a = rng.standard_normal((96, 64)).astype(np.float32)
    b = rng.standard_normal((64, 80)).astype(np.float32)
    mask = rng.integers(0, 2, (3, 2)).astype(np.float32)  # bm=32, bk=32
    c = tile_matmul(jnp.asarray(a), jnp.asarray(b),
                    tile_mask=jnp.asarray(mask), bm=32, bn=32, bk=32)
    r = tile_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(mask),
                        bm=32, bk=32)
    np.testing.assert_allclose(np.asarray(c), np.asarray(r), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("bh,sq,sk,hd,bq", [(2, 64, 64, 16, 32),
                                            (4, 128, 128, 32, 64),
                                            (1, 32, 32, 8, 32)])
def test_flash_attention_causal(bh, sq, sk, hd, bq):
    q = rng.standard_normal((bh, sq, hd)).astype(np.float32)
    k = rng.standard_normal((bh, sk, hd)).astype(np.float32)
    v = rng.standard_normal((bh, sk, hd)).astype(np.float32)
    a = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        bq=bq, bk=32)
    b = flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_non_causal():
    q = rng.standard_normal((2, 64, 16)).astype(np.float32)
    k = rng.standard_normal((2, 64, 16)).astype(np.float32)
    v = rng.standard_normal((2, 64, 16)).astype(np.float32)
    a = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        bq=32, bk=32, causal=False)
    b = flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("b,s,d,n,bd,bk", [(2, 32, 16, 4, 8, 8),
                                           (1, 64, 32, 8, 16, 16)])
def test_selective_scan_kernel(b, s, d, n, bd, bk):
    from repro.kernels.selective_scan import selective_scan
    from repro.kernels.selective_scan_ref import selective_scan_ref
    r = np.random.default_rng(0)
    a = jnp.asarray(np.exp(-np.abs(r.standard_normal((b, s, d, n)))),
                    jnp.float32)
    bx = jnp.asarray(r.standard_normal((b, s, d, n)) * 0.1, jnp.float32)
    c = jnp.asarray(r.standard_normal((b, s, n)), jnp.float32)
    y = selective_scan(a, bx, c, bd=bd, bk=bk)
    yr = selective_scan_ref(a, bx, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)

"""Optimizer / data-pipeline / hlo-analysis unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLMData
from repro.launch import hlo_analysis
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule)


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    pn = {k: np.asarray(v, np.float64) for k, v in p.items()}
    m = {k: np.zeros_like(v) for k, v in pn.items()}
    v2 = {k: np.zeros_like(v) for k, v in pn.items()}
    for t in range(1, 4):
        g = {"w": jnp.asarray(rng.standard_normal((4, 3)) * 0.1, jnp.float32)}
        p, st, _ = adamw_update(p, g, st, lr=lr, b1=b1, b2=b2, eps=eps,
                                weight_decay=wd, max_norm=1e9)
        gn = {k: np.asarray(x, np.float64) for k, x in g.items()}
        for k in pn:
            m[k] = b1 * m[k] + (1 - b1) * gn[k]
            v2[k] = b2 * v2[k] + (1 - b2) * gn[k] ** 2
            mh = m[k] / (1 - b1 ** t)
            vh = v2[k] / (1 - b2 ** t)
            pn[k] -= lr * (mh / (np.sqrt(vh) + eps) + wd * pn[k])
    np.testing.assert_allclose(np.asarray(p["w"], np.float64), pn["w"],
                               rtol=1e-4, atol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 10.0, rtol=1e-5)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-4)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(jnp.asarray(5))) == 0.5
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.asarray(110))) < 1e-6


def test_data_determinism_and_sharding():
    a = SyntheticLMData(50, 8, 16, seed=9)
    b = SyntheticLMData(50, 8, 16, seed=9)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])
    hosts = [SyntheticLMData(50, 8, 16, seed=9, host_index=h, host_count=4)
             for h in range(4)]
    full = SyntheticLMData(50, 8, 16, seed=9)
    np.testing.assert_array_equal(
        np.concatenate([h.next_batch()["tokens"] for h in hosts], 0),
        full.next_batch()["tokens"])


def test_hlo_analysis_trip_expansion():
    def step(c, _):
        return c @ c, None

    def f(x):
        return jax.lax.scan(step, x, None, length=7)[0]

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)) \
        .compile()
    stats = hlo_analysis.analyze(comp.as_text())
    expect = 7 * 2 * 32 ** 3
    assert abs(stats["flops"] - expect) / expect < 0.05, stats["flops"]
    assert 7 in stats["trip_counts"].values()


def test_hlo_analysis_dot_flops_flat():
    def f(a, b):
        return a @ b

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 16), jnp.float32)).compile()
    stats = hlo_analysis.analyze(comp.as_text())
    expect = 2 * 64 * 32 * 16
    assert abs(stats["flops"] - expect) / expect < 0.01

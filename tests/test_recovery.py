"""Surgical recovery (DESIGN.md §13): lineage-based shard recomputation,
speculative straggler re-execution, and peer-replicated carry snapshots.

The recovery tier sits ABOVE the §11 ladder: losing one shard's output
partition recomputes ONLY that partition from lineage (bit-identical,
zero ladder descents), a flagged straggler gets one speculative backup
copy (first finisher wins), and loop carries restore from the in-memory
peer-replica tier before the disk tier is consulted.  Escalation paths
(flapping worker within the TTL, failed checksum verification, lineage
disabled) hand the ORIGINAL fault to the ladder — exactly the pre-§13
behaviour.

Distributed scenarios run in slow subprocesses with forced host devices,
like test_core_distributed.py; everything else is in-process and fast.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import FakeClock
from test_core_programs import data_for

from repro.core import compile_program
from repro.core import faults as F
from repro.core import plan as P
from repro.core.programs import ALL
from repro.runtime import LoopRunner
from repro.runtime.ft import PeerReplica, TrainRunner
from repro.serve import PlanServer

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WRITE_KINDS = {"store", "reduce", "scalar", "rebalance", "carry"}
_READ_KINDS = {"rep", "aligned", "gathered"}


def _fresh(ins):
    out = {}
    for k, v in ins.items():
        if isinstance(v, tuple):
            out[k] = tuple(np.array(c) for c in v)
        elif isinstance(v, np.ndarray):
            out[k] = v.copy()
        else:
            out[k] = v
    return out


def _quiet(cp):
    cp.faults.sleep = lambda s: None
    return cp


def _bitident(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


def _walk(nodes):
    for n in nodes:
        yield n
        if isinstance(n, P.SeqLoop):
            yield from _walk(n.body)
        elif isinstance(n, (P.Fused, P.FusedRound)):
            yield from _walk(n.parts)


# ---------------------------------------------------------------------------
# the lineage pass: every round carries its recovery recipe
# ---------------------------------------------------------------------------

def test_every_round_annotated_with_lineage():
    cp = compile_program(ALL["pagerank"], round_fusion=False)
    nodes = list(_walk(cp.plan))
    assert any(isinstance(n, P.SeqLoop) for n in nodes)
    for n in nodes:
        lin = getattr(n, "lineage", None)
        assert lin is not None, f"unannotated round: {type(n).__name__}"
        assert lin.recoverable
        assert all(k in _WRITE_KINDS for _a, k in lin.writes), lin
        assert all(k in _READ_KINDS for _a, k in lin.reads), lin
        assert lin.depth >= 1


def test_seq_loop_lineage_marks_carries():
    cp = compile_program(ALL["pagerank"], round_fusion=False)
    loops = [n for n in cp.plan if isinstance(n, P.SeqLoop)]
    assert loops
    loop = loops[0]
    assert loop.lineage.writes == tuple((c, "carry") for c in loop.carry)
    body_depth = max(m.lineage.depth for m in loop.body)
    assert loop.lineage.depth == body_depth + 1


def test_fused_region_lineage_is_union_of_members():
    cp = compile_program(ALL["pagerank"])            # fusion on
    fused = [n for n in _walk(cp.plan) if isinstance(n, P.FusedRound)]
    if not fused:
        pytest.skip("no fused region formed for this program")
    for fr in fused:
        lin = fr.lineage
        assert lin is not None and lin.writes
    # pagerank's fused loop body: NP is written by an early member and
    # read by a later one — internal, re-derived during replay, so it
    # must appear only as a write; the carry P is read BEFORE the member
    # that rewrites it, a genuine external read the replay re-fetches
    # from the pre-round snapshot
    loop_lin = fused[-1].lineage
    written = {a for a, _k in loop_lin.writes}
    read = {a for a, _k in loop_lin.reads}
    assert "NP" in written and "NP" not in read
    assert "P" in written and "P" in read


def test_explain_lineage_text():
    cp = compile_program(ALL["pagerank"], round_fusion=False)
    txt = cp.explain_lineage()
    assert txt.startswith("== round lineage: pagerank ==")
    assert "lineage: axis=" in txt
    assert "depth=" in txt and "writes[" in txt and "reads[" in txt


def test_lineage_disabled_leaves_rounds_unannotated():
    cp = compile_program(ALL["pagerank"], round_fusion=False, lineage=False)
    assert all(getattr(n, "lineage", None) is None for n in _walk(cp.plan))


# ---------------------------------------------------------------------------
# straggler watchdog: median exclusion (satellite regression)
# ---------------------------------------------------------------------------

def test_two_consecutive_slow_rounds_both_flag():
    """A flagged sample must NOT fold into the trailing window — one
    genuine straggler dragging the median up would mask the next one."""
    led = F.FaultLedger(name="t")
    for _ in range(5):
        assert not led.note_time("round", 1.0)
    assert led.note_time("round", 10.0)
    assert led.note_time("round", 10.0)     # second slow round ALSO flags
    assert led.counters["straggler"] == 2
    assert 10.0 not in led._times           # excluded from the window


def test_train_runner_shares_fault_ledger(tmp_path):
    """The TrainRunner watchdog IS the shared FaultLedger trailing-median
    idiom — events land in the ledger a caller passed in, next to the
    core executor's and the serving layer's."""
    import time

    class Data:
        def next_batch(self):
            return None

    led = F.FaultLedger(name="shared")
    calls = {"n": 0}

    def step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 7:
            time.sleep(0.25)
        return p, o, {}

    r = TrainRunner(step, {}, None, Data(), ckpt_dir=str(tmp_path),
                    ckpt_every=10 ** 6, ledger=led)
    assert r.faults is led
    r.run(9)
    assert 6 in r.straggler_events
    assert led.counters["straggler"] >= 1
    assert "train.step" in r.explain_faults()


# ---------------------------------------------------------------------------
# peer-replicated carry snapshots (host-mirror tier; ring copy is covered
# by the forced-device subprocess below)
# ---------------------------------------------------------------------------

def test_peer_replica_torn_falls_back_to_previous_good():
    led = F.FaultLedger(name="peer")
    pr = PeerReplica(ledger=led)
    a, b = np.arange(8.0), np.arange(8.0) * 3
    pr.mirror(0, 1, 10, {"P": a})
    pr.mirror(0, 2, 11, {"P": b})
    pr.snaps[-1]["data"]["P"][2] += 1.0     # torn write
    li, it, step, carry = pr.latest_good()
    assert (li, it, step) == (0, 1, 10)
    assert np.array_equal(np.asarray(carry["P"]), a)
    assert pr.torn == [11]
    assert led.counters["escalate"] == 1


def test_peer_replica_depth_bound():
    pr = PeerReplica(depth=2)
    for i in range(5):
        pr.mirror(0, i, i, {"x": np.full(4, float(i))})
    assert len(pr.snaps) == 2
    assert pr.latest_good()[1] == 4


def test_loop_runner_restores_carry_from_peer_replica(tmp_path):
    """The in-memory tier beats the disk tier on recency: a loop killed
    at iteration k restores the carry from the newest GOOD peer snapshot
    (disk saves are sparse here) and finishes bit-identical to an
    uninterrupted stepwise run."""
    ins = data_for("pagerank")
    ins["num_steps"] = 6.0
    cp = _quiet(compile_program(ALL["pagerank"]))
    ref = cp.run_stepwise(_fresh(ins))
    runner = LoopRunner(cp, str(tmp_path), every=10 ** 6, peer_every=1)
    with F.inject(F.FaultSpec("lower.loop_iter", "deterministic", nth=4,
                              message="kill -9")):
        with pytest.raises(F.DeterministicFault):
            runner.run(_fresh(ins), resume=False)
    assert runner.peer is not None and runner.peer.snaps
    out = runner.run(_fresh(ins), resume=True)
    assert runner.peer_restores == 1
    assert cp.faults.counters["recovered"] >= 1
    assert "peer replica" in cp.explain_faults()
    assert _bitident(out, ref)


# ---------------------------------------------------------------------------
# speculative re-execution of a straggling batched flush (serving layer)
# ---------------------------------------------------------------------------

def _gb_inputs(n, seed):
    r = np.random.default_rng(seed)
    return dict(S=(r.integers(0, 10, n).astype(np.float64),
                   r.standard_normal(n)), C=np.zeros(10))


_SLOW_FLUSH = lambda: [  # noqa: E731 — fresh specs per test
    F.FaultSpec("serve.batched_call", "slow", nth=1, times=5, delay_s=0.01),
    F.FaultSpec("serve.batched_call", "slow", nth=6, delay_s=1.0)]


def test_serve_speculative_backup_wins_straggling_flush():
    clk = FakeClock()
    srv = PlanServer({"group_by": compile_program(ALL["group_by"])},
                     max_batch=1, clock=clk)
    with F.inject(*_SLOW_FLUSH(), clock=clk):
        for i in range(6):
            srv.submit("group_by", _gb_inputs(20, i))
            srv.drain()
    s = srv.stats()
    assert s["completed"] == 6 and s["failed"] == 0
    assert srv.speculated == 1 and s["speculated"] == 1
    assert srv.faults.counters["speculative"] == 1
    assert s["spec_saved_ms"] > 500          # the backup won back ~1s
    assert "backup flush won" in srv.explain_faults()
    assert "speculated=1" in srv.explain_serving()


def test_serve_speculation_opt_out():
    clk = FakeClock()
    srv = PlanServer({"group_by": compile_program(ALL["group_by"])},
                     max_batch=1, clock=clk, speculative=False)
    with F.inject(*_SLOW_FLUSH(), clock=clk):
        for i in range(6):
            srv.submit("group_by", _gb_inputs(20, i))
            srv.drain()
    assert srv.faults.counters["straggler"] >= 1   # watchdog still fires
    assert srv.speculated == 0
    assert srv.faults.counters["speculative"] == 0


# ---------------------------------------------------------------------------
# recovery × capacity: a ChunkLoop killed by shard loss resumes at chunk
# granularity through the ordinary LoopRunner machinery
# ---------------------------------------------------------------------------

def test_shard_lost_during_chunk_loop_resumes_chunk_granular(tmp_path):
    def wc_inputs(n):
        r = np.random.default_rng(0)
        return dict(W=r.integers(0, 10, n).astype(np.float64),
                    C=np.zeros(10))

    ref = _quiet(compile_program(ALL["word_count"])).run(wc_inputs(1024))
    cp = _quiet(compile_program(ALL["word_count"], out_of_core="force",
                                chunk_rows=128))          # 8 chunks
    runner = LoopRunner(cp, str(tmp_path), every=1)
    with pytest.raises(F.ShardLostFault):
        with F.inject(F.FaultSpec("lower.chunk_step", "shard_lost",
                                  nth=6, times=10 ** 6, shard=3)):
            runner.run(wc_inputs(1024), resume=False)
    assert runner.saves >= 1

    cp2 = _quiet(compile_program(ALL["word_count"], out_of_core="force",
                                 chunk_rows=128))
    runner2 = LoopRunner(cp2, str(tmp_path), every=1)
    out = runner2.run(wc_inputs(1024), resume=True)
    assert runner2.resumed_from is not None
    assert _bitident(ref, out)
    assert cp2.chunker.chunks_run < 8        # completed chunks NOT re-run


# ---------------------------------------------------------------------------
# distributed shard loss: surgical lineage recovery (slow subprocesses,
# forced host devices — the acceptance scenarios)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import sys; sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import numpy as np
from test_core_programs import data_for
from repro.core import compile_program
from repro.core import faults as F
from repro.core.programs import ALL
from repro.core.distributed import compile_distributed
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((%d,), ("data",))

def mk(**kw):
    cp = compile_program(ALL["pagerank"], **kw)
    cp.policy.backoff_s = 0.0
    cp.policy.max_backoff_s = 0.0
    cp.faults.sleep = lambda s: None
    return compile_distributed(cp, mesh)

def bit(a, b):
    for k in a:
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k

def close(a, b):
    for k in a:
        x = np.asarray(b[k], np.float64); y = np.asarray(a[k], np.float64)
        assert np.max(np.abs(x - y) / (np.abs(y) + 1.0)) < 1e-6, k

ins = data_for("pagerank")
ref = mk(round_fusion=False).run(ins)
"""

# Acceptance: 1-of-8 shard loss mid-pagerank — mid-round AND mid-SeqLoop —
# recovers via lineage recompute BIT-IDENTICAL to the fault-free run with
# zero ladder descents.
_ACCEPT_CODE = _PRELUDE % (8, 8) + """
lost = F.FaultSpec  # shorthand

# 1) pre-loop reduce with a replicated destination: recovery is free
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=1, shard=3)):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["descend"] == 0
assert dp.faults.counters["recovered"] == 1
txt = dp.explain_faults()
assert "nothing to recompute" in txt and "lineage depth" in txt

# 2) mid-SeqLoop aligned store: block-restricted recompute, 1/8 of the round
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=7, shard=5)):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["descend"] == 0
txt = dp.explain_faults()
assert "block-restricted recompute (1/8 of the round)" in txt
assert "checksum ok" in txt

# 3) mid-SeqLoop unaligned reduce: replay the cached round + re-slice
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=6, shard=1)):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["descend"] == 0
assert "replay round + re-slice" in dp.explain_faults()

# 4) MID-round loss (the worker died before its outputs applied): the
# program's inputs survive on the host, ONE same-level re-dispatch
dp = mk(round_fusion=False)
with F.inject(lost("dist.round_exec", kind="shard_lost", nth=5, shard=2)):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["descend"] == 0
assert "same-level re-dispatch" in dp.explain_faults()

# recovery respects the memest budget: the block-restricted recompute
# materializes ONLY shard k's row block (1/P of each destination), never
# a full-size intermediate — so its working set fits any budget that
# admitted the sharded round itself
import repro.core.distributed as D
shapes = []
orig = D.DistributedProgram._recompute_blocks
def spy(self, k, pre, env, rec):
    out = orig(self, k, pre, env, rec)
    if out:
        shapes.extend((int(np.asarray(v).shape[0]),
                       int(np.asarray(pre[d]).shape[0]))
                      for d, v in out.items())
    return out
D.DistributedProgram._recompute_blocks = spy
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=7, shard=6)):
    out = dp.run(ins)
D.DistributedProgram._recompute_blocks = orig
bit(ref, out)
assert shapes and all(blk * 8 == dest for blk, dest in shapes), shapes
print("ACCEPT_OK")
"""

# 1-of-4 matrix leg + fused-region loss + on-mesh peer-replica ring copy
_MATRIX4_CODE = _PRELUDE % (4, 4) + """
lost = F.FaultSpec

# fused loop region (fusion on): replay the fused executable + re-slice
ref_f = mk().run(ins)
dp = mk()
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=2, shard=2)):
    out = dp.run(ins)
bit(ref_f, out)
assert dp.faults.counters["descend"] == 0
assert "replay fused loop + re-slice" in dp.explain_faults()

# per-member mid-loop block recompute at 4 shards
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=4, shard=3)):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["descend"] == 0
assert "1/4 of the round" in dp.explain_faults()

# peer-replica ring copy on a real mesh: blocks live on the neighbour,
# inverse permute + checksum round-trips; a torn replica falls back
from repro.runtime.ft import PeerReplica
pr = PeerReplica(mesh=mesh, dp=("data",))
x = np.arange(16.0); y = np.arange(16.0) * 2
pr.mirror(0, 1, 1, {"P": x})
pr.mirror(0, 2, 2, {"P": y})
li, it, step, carry = pr.latest_good()
assert it == 2 and np.array_equal(np.asarray(carry["P"]), y)
torn = np.asarray(pr.snaps[-1]["data"]["P"]).copy()
torn[3] += 1.0
pr.snaps[-1]["data"]["P"] = torn
li, it, step, carry = pr.latest_good()
assert it == 1 and np.array_equal(np.asarray(carry["P"]), x)
assert pr.torn == [2]
print("MATRIX4_OK")
"""

# escalation paths hand the ORIGINAL fault to the §11 ladder, and a
# straggling round gets ONE speculative backup copy
_ESCALATE_CODE = _PRELUDE % (8, 8) + """
lost = F.FaultSpec

# same shard lost twice within the TTL: flapping worker, ladder takes
# over (REP-everything rerun is ≈-equal, not bit-identical)
dp = mk(round_fusion=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=4, times=2,
                   shard=5)):
    out = dp.run(ins)
close(ref, out)
assert dp.faults.counters["descend"] >= 1
txt = dp.explain_faults()
assert "flapping" in txt and "TTL" in txt

# lineage disabled: the pre-recovery behaviour — every shard loss is a
# ladder event
dp = mk(round_fusion=False, lineage=False)
with F.inject(lost("dist.shard_lost", kind="shard_lost", nth=4, shard=5)):
    out = dp.run(ins)
close(ref, out)
assert dp.faults.counters["descend"] >= 1
assert dp.faults.counters["recovered"] == 0

# speculative re-execution of a straggling round: fake clock, the 6th
# round straggles 100x over the trailing median, the backup copy wins
class FakeClock:
    def __init__(self): self.t = 0.0
    def __call__(self): return self.t
    def advance(self, dt): self.t += dt

dp = mk(round_fusion=False)
clk = FakeClock()
dp.faults.clock = clk
specs = [lost("dist.round_exec", kind="slow", nth=1, times=5, delay_s=0.01),
         lost("dist.round_exec", kind="slow", nth=6, delay_s=1.0)]
with F.inject(*specs, clock=clk):
    out = dp.run(ins)
bit(ref, out)
assert dp.faults.counters["straggler"] >= 1
assert dp.faults.counters["speculative"] == 1
assert dp.faults.spec_saved_s > 0.5
assert "backup won" in dp.explain_faults()
assert dp.faults.counters["descend"] == 0
print("ESCALATE_OK")
"""


def _run_sub(code, marker):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert marker in r.stdout


@pytest.mark.slow
def test_shard_loss_lineage_recovery_acceptance():
    """1-of-8 shard loss mid-pagerank (mid-round AND mid-SeqLoop)
    recovers via lineage recompute bit-identical to the fault-free run
    with ZERO ladder descents."""
    _run_sub(_ACCEPT_CODE, "ACCEPT_OK")


@pytest.mark.slow
def test_shard_loss_matrix_1_of_4_and_fused():
    _run_sub(_MATRIX4_CODE, "MATRIX4_OK")


@pytest.mark.slow
def test_shard_loss_escalation_and_speculation():
    _run_sub(_ESCALATE_CODE, "ESCALATE_OK")

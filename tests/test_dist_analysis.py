"""Distribution-analysis unit tests (DESIGN.md §6): the fixed-point pass
infers ONED_ROW for bag-joined/axis-aligned dense arrays, TWOD_BLOCK for
pure matmul operands, and REP whenever a write shape the distributed
executor cannot produce forces the meet to ⊥.  No mesh needed — the
analysis is static."""
from repro.core import compile_program, dim, loop_program, vector
from repro.core.dist_analysis import Dist
from repro.core.programs import ALL


def dists(name, **kw):
    return compile_program(ALL[name], **kw).dists


def test_pagerank_dense_arrays_shard():
    d = dists("pagerank")
    # ranks, new ranks and out-degree counts all shard by vertex row —
    # the acceptance bar for scaling past one device's memory
    assert d["P"] == Dist.ONED_ROW
    assert d["NP"] == Dist.ONED_ROW
    assert d["C"] == Dist.ONED_ROW


def test_matmul_operands_are_twod_candidates():
    d = dists("matrix_multiplication")
    assert d["M"] == Dist.TWOD_BLOCK      # pure matmul operands
    assert d["N"] == Dist.TWOD_BLOCK
    assert d["R"] == Dist.ONED_ROW        # also written by the zero-init


def test_matrix_factorization_factors_shard():
    d = dists("matrix_factorization_step")
    assert all(v == Dist.ONED_ROW for v in d.values()), d
    # Pp/Qp are matmul operands in pq's contraction but ALSO appear in the
    # gradient updates: the read-side rebalance sweep caps them at ONED_ROW
    assert d["Pp"] == Dist.ONED_ROW
    assert d["Qp"] == Dist.ONED_ROW


def test_kmeans_per_point_arrays_shard():
    d = dists("kmeans_step")
    for name in ("D", "MinD", "Cl"):      # bag-joined dense writes: the
        # live row count per shard is data-dependent (one row per bag
        # element), so they carry variable blocks rather than balanced ones
        assert d[name] == Dist.ONED_VAR, (name, d[name])
    for name in ("SX", "SY", "CN"):       # computed-key reduces stay
        assert d[name] == Dist.ONED_ROW   # balanced over the key space


def test_strided_store_forces_rep():
    @loop_program
    def strided(V: vector, W: vector, n: dim):
        for i in range(0, n):
            W[2 * i] = V[i]

    d = compile_program(strided).dists
    # computed scatter keys cross shard boundaries: the write meets to ⊥
    assert d["W"] == Dist.REP
    assert d["V"] == Dist.ONED_ROW        # read-only operand still shards


def test_nonzero_range_base_forces_rep():
    @loop_program
    def shifted(V: vector, W: vector, n: dim):
        for i in range(1, n):
            W[i] = V[i]

    d = compile_program(shifted).dists
    # rows-from-1 do not tile as contiguous blocks from row 0
    assert d["W"] == Dist.REP


def test_infer_distributions_off_is_rep_everything():
    d = dists("pagerank", infer_distributions=False)
    assert set(d.values()) == {Dist.REP}  # the guaranteed ⊥ fallback


def test_seqloop_carried_arrays_have_one_stable_sharding():
    cp = compile_program(ALL["pagerank"])
    from repro.core import plan as P
    from repro.core.dist_analysis import leaf_nodes
    loop = next(n for n in cp.plan if isinstance(n, P.SeqLoop))
    seen = {}
    for n in leaf_nodes(loop.body):
        for name, sh in (n.shardings or {}).items():
            assert seen.setdefault(name, sh.dist) == sh.dist, \
                f"{name} changes distribution across the loop body"
    assert seen["P"] == Dist.ONED_ROW     # carried AND sharded


def test_annotations_cover_every_dense_operand():
    cp = compile_program(ALL["matrix_factorization_step"])
    from repro.core.dist_analysis import leaf_nodes
    for n in leaf_nodes(cp.plan):
        assert n.shardings, f"missing shardings on {n.describe()}"
        assert n.dest in n.shardings      # destination always listed first
        assert next(iter(n.shardings)) == n.dest


# ---------------------------------------------------------------------------
# ONED_VAR and the _rebalance re-run (skew-aware distribution)
# ---------------------------------------------------------------------------

def _rebalance_dests(nodes):
    from repro.core import plan as P

    def walk(ns):
        for n in ns:
            if isinstance(n, P.SeqLoop):
                yield from walk(n.body)
            elif isinstance(n, (P.Fused, P.FusedRound)):
                yield from walk(n.parts)
            elif isinstance(n, P.Rebalance):
                yield n.dest
    return list(walk(nodes))


def test_bag_derived_store_infers_oned_var():
    from repro.core import bag

    @loop_program
    def bag_store(V: bag[1], A: vector):
        for i, v in items(V):
            A[i] = v * 2.0

    d = compile_program(bag_store).dists
    # one row per bag element: the live block length is data-dependent
    assert d["A"] == Dist.ONED_VAR


def test_rebalance_inserted_for_loop_reader():
    from repro.core import bag, scalar

    @loop_program
    def loop_reader(V: bag[1], A: vector, s: scalar, steps: scalar):
        for i, v in items(V):
            A[i] = v * 2.0
        while steps < 3.0:
            steps += 1.0
            for i, v in items(V):
                s += A[i]

    cp = compile_program(loop_reader)
    # A is bag-derived (ONED_VAR producer) but re-read inside a SeqLoop
    # body: the _rebalance re-run pins it up to ONED_ROW and the planner
    # inserts an explicit Rebalance round after the producer
    assert cp.dists["A"] == Dist.ONED_ROW
    assert _rebalance_dests(cp.plan) == ["A"]


def test_rebalance_elided_for_filtered_store():
    from repro.core import bag

    @loop_program
    def filtered(V: bag[1], W: vector):
        for i, v in items(V):
            if v > 0.0:
                W[i] = v

    cp = compile_program(filtered)
    # nothing downstream needs balanced blocks: W keeps variable-length
    # live blocks (pad+mask covers the filtered rows) and no rebalance
    # round is spent on it
    assert cp.dists["W"] == Dist.ONED_VAR
    assert _rebalance_dests(cp.plan) == []


def test_skew_rebalance_off_keeps_variable_blocks():
    from repro.core import bag, scalar

    @loop_program
    def loop_reader2(V: bag[1], A: vector, s: scalar, steps: scalar):
        for i, v in items(V):
            A[i] = v * 2.0
        while steps < 3.0:
            steps += 1.0
            for i, v in items(V):
                s += A[i]

    cp = compile_program(loop_reader2, skew_rebalance=False)
    # the guard-table fallback: no promotion, no Rebalance nodes — the
    # loop reads fall back to the all_gather path on variable blocks
    assert cp.dists["A"] == Dist.ONED_VAR
    assert _rebalance_dests(cp.plan) == []

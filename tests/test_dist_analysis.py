"""Distribution-analysis unit tests (DESIGN.md §6): the fixed-point pass
infers ONED_ROW for bag-joined/axis-aligned dense arrays, TWOD_BLOCK for
pure matmul operands, and REP whenever a write shape the distributed
executor cannot produce forces the meet to ⊥.  No mesh needed — the
analysis is static."""
from repro.core import compile_program, dim, loop_program, vector
from repro.core.dist_analysis import Dist
from repro.core.programs import ALL


def dists(name, **kw):
    return compile_program(ALL[name], **kw).dists


def test_pagerank_dense_arrays_shard():
    d = dists("pagerank")
    # ranks, new ranks and out-degree counts all shard by vertex row —
    # the acceptance bar for scaling past one device's memory
    assert d["P"] == Dist.ONED_ROW
    assert d["NP"] == Dist.ONED_ROW
    assert d["C"] == Dist.ONED_ROW


def test_matmul_operands_are_twod_candidates():
    d = dists("matrix_multiplication")
    assert d["M"] == Dist.TWOD_BLOCK      # pure matmul operands
    assert d["N"] == Dist.TWOD_BLOCK
    assert d["R"] == Dist.ONED_ROW        # also written by the zero-init


def test_matrix_factorization_factors_shard():
    d = dists("matrix_factorization_step")
    assert all(v == Dist.ONED_ROW for v in d.values()), d
    # Pp/Qp are matmul operands in pq's contraction but ALSO appear in the
    # gradient updates: the read-side rebalance sweep caps them at ONED_ROW
    assert d["Pp"] == Dist.ONED_ROW
    assert d["Qp"] == Dist.ONED_ROW


def test_kmeans_per_point_arrays_shard():
    d = dists("kmeans_step")
    for name in ("D", "MinD", "Cl"):      # bag-joined dense writes
        assert d[name] == Dist.ONED_ROW, (name, d[name])


def test_strided_store_forces_rep():
    @loop_program
    def strided(V: vector, W: vector, n: dim):
        for i in range(0, n):
            W[2 * i] = V[i]

    d = compile_program(strided).dists
    # computed scatter keys cross shard boundaries: the write meets to ⊥
    assert d["W"] == Dist.REP
    assert d["V"] == Dist.ONED_ROW        # read-only operand still shards


def test_nonzero_range_base_forces_rep():
    @loop_program
    def shifted(V: vector, W: vector, n: dim):
        for i in range(1, n):
            W[i] = V[i]

    d = compile_program(shifted).dists
    # rows-from-1 do not tile as contiguous blocks from row 0
    assert d["W"] == Dist.REP


def test_infer_distributions_off_is_rep_everything():
    d = dists("pagerank", infer_distributions=False)
    assert set(d.values()) == {Dist.REP}  # the guaranteed ⊥ fallback


def test_seqloop_carried_arrays_have_one_stable_sharding():
    cp = compile_program(ALL["pagerank"])
    from repro.core import plan as P
    from repro.core.dist_analysis import leaf_nodes
    loop = next(n for n in cp.plan if isinstance(n, P.SeqLoop))
    seen = {}
    for n in leaf_nodes(loop.body):
        for name, sh in (n.shardings or {}).items():
            assert seen.setdefault(name, sh.dist) == sh.dist, \
                f"{name} changes distribution across the loop body"
    assert seen["P"] == Dist.ONED_ROW     # carried AND sharded


def test_annotations_cover_every_dense_operand():
    cp = compile_program(ALL["matrix_factorization_step"])
    from repro.core.dist_analysis import leaf_nodes
    for n in leaf_nodes(cp.plan):
        assert n.shardings, f"missing shardings on {n.describe()}"
        assert n.dest in n.shardings      # destination always listed first
        assert next(iter(n.shardings)) == n.dest

"""Serving-layer robustness under injected faults (DESIGN.md §11):
request deadlines shed BEFORE pad/flush, bounded admission (queue cap),
transient batched-call retries that keep the batch intact, poisoned-bucket
bisection (one bad request fails alone, the rest complete batched — never
the all-sequential stampede), the per-lane nan guard, and the 64-client
chaos gate: ≥80% of fault-free goodput under 10% transient faults with
zero lost or duplicated tickets.  Everything runs on the FakeClock —
deterministic schedules, no real sleeps.
"""
import numpy as np
import pytest

from conftest import FakeClock
from test_core_programs import data_for

from repro.core import compile_program
from repro.core import faults as F
from repro.core.programs import ALL
from repro.serve import DeadlineExceeded, PlanServer, QueueFull

_CP = {}


def cp():
    if not _CP:
        _CP["group_by"] = compile_program(ALL["group_by"])
    return _CP["group_by"]


def gb_inputs(n, seed):
    r = np.random.default_rng(seed)
    return dict(S=(r.integers(0, 10, n).astype(np.float64),
                   r.standard_normal(n)), C=np.zeros(10))


def server(**kw):
    kw.setdefault("clock", FakeClock())
    return PlanServer({"group_by": cp()}, max_batch=8, **kw)


# ---------------------------------------------------------------------------
# transient faults: retried with the batch intact
# ---------------------------------------------------------------------------

def test_transient_batched_call_retried_batch_intact():
    ref = {i: cp().run(gb_inputs(20, i)) for i in range(8)}
    srv = server()
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    with F.inject(F.FaultSpec("serve.batched_call", "transient", nth=1)):
        srv.drain()
    s = srv.stats()
    assert all(t.state == "done" for t in ts)
    assert all(np.array_equal(t.output["C"], ref[i]["C"])
               for i, t in enumerate(ts))
    assert s["retries"] == 1
    assert s["bisections"] == 0 and s["seq_fallbacks"] == 0
    assert s["flushes"] == 1                  # ONE batched flush, retried


def test_transient_device_put_retried():
    srv = server(prefetch=False)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(4)]
    with F.inject(F.FaultSpec("serve.device_put", "transient", nth=1)) \
            as inj:
        srv.drain()
    assert inj.fired
    assert all(t.state == "done" for t in ts)
    # the whole dispatch (stack + put + call) is the retry unit
    assert srv.stats()["failed_flushes"] >= 1


# ---------------------------------------------------------------------------
# poisoned-bucket bisection (satellite: replaces all-or-sequential)
# ---------------------------------------------------------------------------

def test_bisection_isolates_single_bad_request():
    """A rid-matched deterministic fault fails every batch the bad request
    rides in: bisection must strip it down to a singleton in O(log B)
    splits while every OTHER request completes batched (not sequentially),
    and the ledger stays balanced."""
    ref = {i: cp().run(gb_inputs(20, i)) for i in range(8)}
    srv = server()
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    with F.inject(F.FaultSpec("serve.batched_call", "deterministic",
                              rid=3, times=1000)):
        srv.drain()
    s = srv.stats()
    good = [t for i, t in enumerate(ts) if i != 3]
    assert all(t.state == "done" for t in good)
    assert all(np.array_equal(t.output["C"], ref[i]["C"])
               for i, t in enumerate(ts) if i != 3)
    # the bad request was isolated to a singleton and served through the
    # sequential fallback — ALONE, not the whole batch
    assert ts[3].state == "done" and s["seq_fallbacks"] == 1
    assert s["bisections"] >= 1
    # everyone else stayed batched: 7 of 8 requests served in batched
    # flushes (sum of bucket reqs), not one-by-one
    assert sum(r["reqs"] for r in s["buckets"].values()) == 7
    assert s["admitted"] == s["completed"] + s["cancelled"] \
        + s["failed"] + s["queued"]


def test_bisection_disabled_falls_back_sequentially():
    srv = server(bisect=False)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(4)]
    with F.inject(F.FaultSpec("serve.batched_call", "deterministic",
                              rid=1, times=1000)):
        srv.drain()
    s = srv.stats()
    assert all(t.state == "done" for t in ts)
    assert s["seq_fallbacks"] == 4            # the old stampede, opt-in
    assert s["bisections"] == 0


def test_failed_singleton_without_fallback_fails_cleanly():
    srv = server(sequential_fallback=False)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(4)]
    with F.inject(F.FaultSpec("serve.batched_call", "deterministic",
                              rid=2, times=1000)):
        srv.drain()
    s = srv.stats()
    assert ts[2].state == "failed"
    assert isinstance(ts[2].error, F.DeterministicFault)
    assert [t.state for i, t in enumerate(ts) if i != 2] == ["done"] * 3
    assert s["failed"] == 1 and s["completed"] == 3


def test_failed_flush_does_not_inflate_served_counters():
    """The satellite accounting fix: a failed batched call must not count
    its lanes/reqs/latency as served — occupancy and the served-lane
    balance stay truthful under faults."""
    srv = server()
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    with F.inject(F.FaultSpec("serve.batched_call", "deterministic",
                              rid=0, times=1000)):
        srv.drain()
    s = srv.stats()
    assert s["failed_flushes"] >= 1
    assert all(t.state == "done" for t in ts)
    assert sum(r["reqs"] for r in s["buckets"].values()) \
        + s["seq_fallbacks"] == s["completed"]


# ---------------------------------------------------------------------------
# NaN/Inf poisoning: per-lane guard, no bisection needed
# ---------------------------------------------------------------------------

def test_poisoned_lane_fails_alone_same_flush():
    ref = {i: cp().run(gb_inputs(20, i)) for i in range(8)}
    srv = server()
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    with F.inject(F.FaultSpec("serve.stack", "poison", rid=5, times=1000)):
        srv.drain()
    s = srv.stats()
    assert ts[5].state == "failed"
    assert isinstance(ts[5].error, F.PoisonedOutput)
    assert all(t.state == "done" for i, t in enumerate(ts) if i != 5)
    assert all(np.array_equal(t.output["C"], ref[i]["C"])
               for i, t in enumerate(ts) if i != 5)
    # isolation came from the per-lane guard, not from splitting batches
    assert s["poisoned"] == 1 and s["flushes"] == 1 and s["bisections"] == 0


def test_nan_guard_off_returns_poisoned_lane():
    srv = server(nan_guard=False)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(2)]
    with F.inject(F.FaultSpec("serve.stack", "poison", rid=0, times=1000)):
        srv.drain()
    assert ts[0].state == "done"              # caller opted out of the guard
    assert not np.all(np.isfinite(ts[0].output["C"]))


# ---------------------------------------------------------------------------
# deadlines + admission control
# ---------------------------------------------------------------------------

def test_deadline_sheds_before_flush():
    clk = FakeClock()
    srv = server(clock=clk, flush_ms=2.0)
    t1 = srv.submit("group_by", gb_inputs(20, 0), deadline_ms=1.0)
    clk.advance(0.005)                        # past t1's deadline
    t2 = srv.submit("group_by", gb_inputs(20, 1))
    srv.drain()
    s = srv.stats()
    assert t1.state == "failed" and isinstance(t1.error, DeadlineExceeded)
    assert t2.state == "done"
    assert s["deadline_expired"] == 1
    # the shed request never cost a lane
    assert sum(r["reqs"] for r in s["buckets"].values()) == 1


def test_server_default_deadline_applies():
    clk = FakeClock()
    srv = server(clock=clk, deadline_ms=3.0)
    t = srv.submit("group_by", gb_inputs(20, 0))
    clk.advance(0.004)
    srv.pump()
    assert t.state == "failed" and isinstance(t.error, DeadlineExceeded)


def test_queue_cap_sheds_at_admission():
    srv = server(queue_cap=2)
    srv.submit("group_by", gb_inputs(20, 0))
    srv.submit("group_by", gb_inputs(20, 1))
    with pytest.raises(QueueFull):
        srv.submit("group_by", gb_inputs(20, 2))
    s = srv.stats()
    assert s["load_shed"] == 1 and s["admitted"] == 2
    srv.drain()                               # capacity frees up
    srv.submit("group_by", gb_inputs(20, 3))
    assert srv.stats()["admitted"] == 3


# ---------------------------------------------------------------------------
# straggler watchdog on the injected clock
# ---------------------------------------------------------------------------

def test_slow_batch_records_straggler():
    clk = FakeClock()
    srv = PlanServer({"group_by": cp()}, max_batch=1, clock=clk)
    specs = [F.FaultSpec("serve.batched_call", "slow", nth=1, times=5,
                         delay_s=0.01),
             F.FaultSpec("serve.batched_call", "slow", nth=6,
                         delay_s=1.0)]
    with F.inject(*specs, clock=clk):
        for i in range(6):
            srv.submit("group_by", gb_inputs(20, i))
            srv.drain()
    assert srv.faults.counters["straggler"] >= 1
    assert "straggler" in srv.explain_faults()


# ---------------------------------------------------------------------------
# chaos gate (acceptance): 64 clients, 10% transient faults
# ---------------------------------------------------------------------------

def test_chaos_gate_64_clients_10pct_transients():
    """Under a transient fault on every 10th batched call, with one
    rid-poisoned request and one rid-deterministic request mixed in:
    ≥80% of fault-free goodput, zero lost or duplicated tickets, and the
    ledger balanced to the last request."""
    clk = FakeClock()
    srv = PlanServer({"group_by": cp()}, max_batch=8, flush_ms=2.0,
                     clock=clk, queue_cap=256)
    rng = np.random.default_rng(0)
    specs = [F.FaultSpec("serve.batched_call", "transient", nth=n)
             for n in range(1, 120, 10)]
    specs += [F.FaultSpec("serve.stack", "poison", rid=11, times=10 ** 4),
              F.FaultSpec("serve.batched_call", "deterministic", rid=37,
                          times=10 ** 4)]
    tickets = []
    with F.inject(*specs, clock=clk):
        for i in range(64):
            n = int(rng.choice([12, 20, 33]))  # several shape buckets
            tickets.append(srv.submit("group_by", gb_inputs(n, i)))
            if i % 8 == 7:
                clk.advance(0.003)
                srv.pump()
        srv.drain()
    s = srv.stats()
    # zero lost or duplicated: every ticket resolved exactly once
    assert all(t._completions == 1 for t in tickets)
    assert s["queued"] == 0
    assert s["admitted"] == 64 == s["completed"] + s["failed"]
    # goodput: only the poisoned request may fail (the rid-deterministic
    # one is bisected out and served solo) — far above the 80% gate
    assert s["completed"] >= int(0.8 * 64)
    assert s["poisoned"] == 1
    assert tickets[11].state == "failed"
    assert tickets[37].state == "done"
    # transient retries happened and never killed a batch
    assert s["retries"] >= 1
    # ledger balance under chaos
    assert sum(r["reqs"] for r in s["buckets"].values()) \
        + s["seq_fallbacks"] == s["completed"]
    text = srv.explain_serving()
    assert "robustness:" in text and "poisoned=1" in text


# ---------------------------------------------------------------------------
# memory-aware admission (DESIGN.md §12): queue or shed, never OOM a flush
# ---------------------------------------------------------------------------

def _bucket_peak():
    """Estimated device bytes for one lane of the 20-row group_by
    bucket (padded to the bucket edge) — the unit the lane cap divides."""
    srv = server(memory_budget=10 ** 12)
    srv.submit("group_by", gb_inputs(20, 0))
    srv.drain()
    return next(iter(srv.stats()["buckets"].values()))["est_peak"]


def test_memory_budget_caps_flush_lanes():
    """budget = 3 lanes: 8 concurrent requests flush as 3+3+2 — every
    request still completes bit-identically, the overflow WAITS instead
    of riding a batch projected past the budget."""
    peak = _bucket_peak()
    ref = {i: cp().run(gb_inputs(20, i)) for i in range(8)}
    srv = server(memory_budget=3 * peak)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    srv.drain()
    s = srv.stats()
    b = next(iter(s["buckets"].values()))
    assert b["lane_cap"] == 3
    assert s["completed"] == 8 and s["failed"] == 0
    assert s["flushes"] == 3
    assert s["mem_deferred"] > 0 and s["mem_shed"] == 0
    assert all(np.array_equal(t.output["C"], ref[i]["C"])
               for i, t in enumerate(ts))
    assert "memory: budget=" in srv.explain_serving()
    assert srv.faults.counters["defer"] >= 1


def test_oversize_request_sheds_with_capacity_error():
    """A single lane over budget can never be served by batching less:
    it sheds with a RESOURCE_EXHAUSTED error that classify() reads as
    capacity — pointing the caller at the out-of-core run() path."""
    peak = _bucket_peak()
    srv = server(memory_budget=peak // 2)
    t = srv.submit("group_by", gb_inputs(20, 0))
    srv.drain()
    s = srv.stats()
    assert t.state == "failed"
    assert s["mem_shed"] == 1 and s["failed"] == 1
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        t.result(0)
    try:
        t.result(0)
    except RuntimeError as ex:
        assert F.classify(ex) == "capacity"
    assert srv.faults.counters["shed"] == 1
    assert "mem_shed=1" in srv.explain_serving()


def test_lane_rounding_never_exceeds_cap():
    """batch_round pads lanes up to a power of two — but a dummy lane
    costs real device bytes, so rounding must respect the cap too."""
    peak = _bucket_peak()
    srv = server(memory_budget=3 * peak, batch_round=True)
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(3)]
    srv.drain()
    s = srv.stats()
    assert all(t.state == "done" for t in ts)
    lanes = sum(b.lanes for b in srv._buckets.values())
    assert lanes <= 3                  # NOT rounded up to 4


def test_no_budget_means_no_caps():
    srv = server()
    ts = [srv.submit("group_by", gb_inputs(20, i)) for i in range(8)]
    srv.drain()
    s = srv.stats()
    b = next(iter(s["buckets"].values()))
    assert b["lane_cap"] is None and b["est_peak"] is None
    assert s["flushes"] == 1 and s["completed"] == 8
    assert "memory:" not in srv.explain_serving()

"""Distributed (shard_map + gspmd) execution of compiled loop programs
equals single-device execution — run in a subprocess with 8 forced host
devices (the main test process must keep 1 device)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(7)
nv = 16
cases = {
  "word_count": dict(W=rng.integers(0, nv, 64).astype(np.float64), C=np.zeros(nv)),
  "group_by": dict(S=(rng.integers(0, nv, 64).astype(np.float64),
                      rng.standard_normal(64)), C=np.zeros(nv)),
  "histogram": dict(P=tuple(rng.integers(0, nv, 64).astype(np.float64)
                            for _ in range(3)),
                    R=np.zeros(nv), G=np.zeros(nv), B=np.zeros(nv)),
  "conditional_sum": dict(V=rng.standard_normal(64), s=0.0, limit=0.3),
  "pagerank": dict(E=(rng.integers(0, 12, 64).astype(np.float64),
                      rng.integers(0, 12, 64).astype(np.float64)),
                   P=np.full(12, 1/12), NP=np.zeros(12), C=np.zeros(12),
                   N=12, num_steps=2.0, steps=0.0, b=0.85),
  "matrix_multiplication": dict(M=rng.standard_normal((16, 8)),
                                N=rng.standard_normal((8, 12)),
                                R=np.zeros((16, 12)), n=16, m=12, l=8),
  # bag generator x dim-bounded range in one reduction: dims must reach
  # the shard_map body as static python ints, not traced operands
  "kmeans_step": dict(P=(rng.standard_normal(24) * 3,
                         rng.standard_normal(24) * 3),
                      CX=rng.standard_normal(4), CY=rng.standard_normal(4),
                      K=4, D=np.zeros((24, 4)), MinD=np.full(24, 1e30),
                      Cl=np.zeros(24), SX=np.zeros(4), SY=np.zeros(4),
                      CN=np.zeros(4), NX=np.zeros(4), NY=np.zeros(4)),
}
for name, ins in cases.items():
    fn = ALL[name]
    single = compile_program(fn).run(ins)
    for mode in ("shardmap", "gspmd"):
        dist = compile_distributed(fn, mesh, ("data",), mode=mode).run(ins)
        for k in single:
            a = np.asarray(dist[k], np.float64)
            b = np.asarray(single[k], np.float64)
            err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
            assert err < 1e-4, (name, mode, k, err)
print("DIST_OK")
"""


@pytest.mark.slow
def test_distributed_equals_single_device():
    r = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                       text=True, cwd=_ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "DIST_OK" in r.stdout


_ODD_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from jax.sharding import PartitionSpec
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(11)
nv = 16
n = 65                      # NOT divisible by 8: pads to 72, masks 7 rows
cases = {
  "word_count": dict(W=rng.integers(0, nv, n).astype(np.float64),
                     C=np.zeros(nv)),
  "group_by": dict(S=(rng.integers(0, nv, n).astype(np.float64),
                      rng.standard_normal(n)), C=np.zeros(nv)),
  "conditional_sum": dict(V=rng.standard_normal(n), s=0.0, limit=0.3),
}
for name, ins in cases.items():
    fn = ALL[name]
    single = compile_program(fn).run(ins)
    for mode in ("shardmap", "gspmd"):
        dp = compile_distributed(fn, mesh, ("data",), mode=mode)
        # odd-length bags must SHARD (padded), not silently replicate
        placed, limits, dense_limits = dp.place(ins)
        bag = next(k for k, t in fn.program.params.items()
                   if t.kind == "bag")
        assert limits[bag] == n, (name, limits)
        col = placed[bag][0]
        assert col.shape[0] == 72
        assert col.sharding.spec == PartitionSpec(("data",)), \
            (name, col.sharding.spec)
        dist = dp.run(ins)
        for k in single:
            a = np.asarray(dist[k], np.float64)
            b = np.asarray(single[k], np.float64)
            err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
            assert err < 1e-4, (name, mode, k, err)
print("ODD_OK")
"""


@pytest.mark.slow
def test_odd_length_bag_pads_and_shards():
    r = subprocess.run([sys.executable, "-c", _ODD_CODE],
                       capture_output=True, text=True, cwd=_ROOT,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "ODD_OK" in r.stdout


_EINSUM_BAG_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program, loop_program, bag, matrix, vector, dim
from repro.core.distributed import compile_distributed
from repro.core.plan import EinsumContract
from repro.launch.mesh import make_test_mesh


@loop_program
def col_sums(B: bag[1], M: matrix, R: vector, m: dim):
    # +-product of gathers contracting the BAG axis: plans as an
    # EinsumContract whose shardmap execution must fall back to the
    # masked AxisReduce inside each shard (traced bag offsets)
    for i, w in items(B):
        for j in range(0, m):
            R[j] += M[i, j]


cp = compile_program(col_sums)
assert any(isinstance(x, EinsumContract) for x in cp.plan), cp.explain()
rng = np.random.default_rng(13)
nb, m = 24, 5
ins = dict(B=rng.standard_normal(nb), M=rng.standard_normal((nb, m)),
           R=np.zeros(m), m=m)
single = cp.run(ins)
mesh = make_test_mesh((8,), ("data",))
for mode in ("shardmap", "gspmd"):
    dist = compile_distributed(col_sums, mesh, ("data",), mode=mode).run(ins)
    err = np.max(np.abs(np.asarray(dist["R"], np.float64)
                        - np.asarray(single["R"], np.float64)))
    assert err < 1e-4, (mode, err)
print("EINSUM_BAG_OK")
"""


@pytest.mark.slow
def test_bag_driven_einsum_distributes(tmp_path):
    script = tmp_path / "einsum_bag.py"          # @loop_program needs a file
    script.write_text(_EINSUM_BAG_CODE)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, cwd=_ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "EINSUM_BAG_OK" in r.stdout


_DENSE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import numpy as np
from jax.sharding import PartitionSpec
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((4,), ("data",))
rng = np.random.default_rng(17)


def check(name, ins):
    fn = ALL[name]
    single = compile_program(fn).run(ins)
    for mode in ("shardmap", "gspmd"):
        dist = compile_distributed(fn, mesh, ("data",), mode=mode).run(ins)
        for k in single:
            a = np.asarray(dist[k], np.float64)
            b = np.asarray(single[k], np.float64)
            assert a.shape == b.shape, (name, mode, k, a.shape, b.shape)
            err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
            assert err < 1e-4, (name, mode, k, err)
    return single


# ---- PageRank: dense rank vectors must SHARD, with N=13 NOT divisible by
# 4 exercising the dense pad+mask path (pad to 16, mask 3 rows) ----
N = 13
pr_ins = dict(E=(rng.integers(0, N, 40).astype(np.float64),
                 rng.integers(0, N, 40).astype(np.float64)),
              P=np.full(N, 1 / N), NP=np.zeros(N), C=np.zeros(N),
              N=N, num_steps=3.0, steps=0.0, b=0.85)
text = compile_program(ALL["pagerank"]).explain()
assert "P=ONED_ROW(i)" in text, text        # ranks inferred sharded...
assert "P=REP" not in text, text            # ...not replicated
dp = compile_distributed(ALL["pagerank"], mesh, ("data",))
placed, bag_limits, array_limits = dp.place(pr_ins)
assert array_limits["P"] == N               # padded 13 -> 16
assert placed["P"].shape[0] == 16
assert placed["P"].sharding.spec == PartitionSpec(("data",)), \\
    placed["P"].sharding.spec                # row blocks, NOT replicated
single = check("pagerank", pr_ins)

# ---- REP-everything fallback (shard_dense=False): same results, dense
# arrays placed replicated ----
dp_rep = compile_distributed(ALL["pagerank"], mesh, ("data",),
                             shard_dense=False)
placed, _, alims = dp_rep.place(pr_ins)
assert alims == {} and placed["P"].shape[0] == N
assert placed["P"].sharding.spec == PartitionSpec(), \\
    placed["P"].sharding.spec
rep = dp_rep.run(pr_ins)
for k in single:
    err = np.max(np.abs(np.asarray(rep[k], np.float64)
                        - np.asarray(single[k], np.float64)))
    assert err < 1e-6, ("rep-fallback", k, err)

# ---- Matrix factorization: every factor matrix ONED_ROW, l=5 and n=10
# both non-divisible by 4 ----
n, m, l = 10, 6, 5
mf_ins = dict(R=rng.standard_normal((n, m)),
              P=rng.standard_normal((n, l)) * 0.1,
              Q=rng.standard_normal((l, m)) * 0.1,
              Pp=rng.standard_normal((n, l)) * 0.1,
              Qp=rng.standard_normal((l, m)) * 0.1,
              pq=np.zeros((n, m)), err=np.zeros((n, m)),
              n=n, m=m, l=l, a=0.01, lam=0.1)
from repro.core.dist_analysis import Dist
cp = compile_program(ALL["matrix_factorization_step"])
assert all(d == Dist.ONED_ROW for d in cp.dists.values()), cp.dists
check("matrix_factorization_step", mf_ins)
print("DENSE_OK")
"""


@pytest.mark.slow
def test_dense_arrays_shard_not_replicate():
    """Tentpole acceptance: PageRank ranks and MF factors shard on a
    4-device mesh (non-divisible rows → pad+mask), match single-device,
    and the REP-everything fallback still works."""
    r = subprocess.run([sys.executable, "-c", _DENSE_CODE],
                       capture_output=True, text=True, cwd=_ROOT,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "DENSE_OK" in r.stdout

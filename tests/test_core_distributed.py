"""Distributed (shard_map + gspmd) execution of compiled loop programs
equals single-device execution — run in a subprocess with 8 forced host
devices (the main test process must keep 1 device)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(7)
nv = 16
cases = {
  "word_count": dict(W=rng.integers(0, nv, 64).astype(np.float64), C=np.zeros(nv)),
  "group_by": dict(S=(rng.integers(0, nv, 64).astype(np.float64),
                      rng.standard_normal(64)), C=np.zeros(nv)),
  "histogram": dict(P=tuple(rng.integers(0, nv, 64).astype(np.float64)
                            for _ in range(3)),
                    R=np.zeros(nv), G=np.zeros(nv), B=np.zeros(nv)),
  "conditional_sum": dict(V=rng.standard_normal(64), s=0.0, limit=0.3),
  "pagerank": dict(E=(rng.integers(0, 12, 64).astype(np.float64),
                      rng.integers(0, 12, 64).astype(np.float64)),
                   P=np.full(12, 1/12), NP=np.zeros(12), C=np.zeros(12),
                   N=12, num_steps=2.0, steps=0.0, b=0.85),
  "matrix_multiplication": dict(M=rng.standard_normal((16, 8)),
                                N=rng.standard_normal((8, 12)),
                                R=np.zeros((16, 12)), n=16, m=12, l=8),
}
for name, ins in cases.items():
    fn = ALL[name]
    single = compile_program(fn).run(ins)
    for mode in ("shardmap", "gspmd"):
        dist = compile_distributed(fn, mesh, ("data",), mode=mode).run(ins)
        for k in single:
            a = np.asarray(dist[k], np.float64)
            b = np.asarray(single[k], np.float64)
            err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
            assert err < 1e-4, (name, mode, k, err)
print("DIST_OK")
"""


@pytest.mark.slow
def test_distributed_equals_single_device():
    r = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                       text=True, cwd=_ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "DIST_OK" in r.stdout

"""Translation-rule tests: the compiler reproduces the paper's derivations
and rejects the paper's counterexamples with Def-3.1 diagnostics."""
import numpy as np
import pytest

from repro.core import (RejectionError, compile_program, loop_program,
                        matrix, vector, dim, scalar, parse_program)
from repro.core.programs import matrix_multiplication, rejected_programs


def test_matmul_target_matches_paper():
    cp = compile_program(matrix_multiplication)
    text = cp.pretty_target()
    # the §1.1 derivation: zero-init store + join/group-by(+/) comprehension
    assert "group by (i, j)" in text
    assert "(M[i, k] * N[k, j])" in text
    assert text.splitlines()[0].startswith("R := R ◁")


@pytest.mark.parametrize("name,builder", rejected_programs())
def test_paper_counterexamples_rejected(name, builder):
    with pytest.raises(RejectionError):
        compile_program(builder())


def test_fixed_smoothing_accepted():
    # paper §3.2: copying V to V' first satisfies the restrictions
    @loop_program
    def smoothing_fixed(V: vector, Vp: vector, n: dim):
        for i in range(0, n):
            Vp[i] = V[i]
        for i in range(1, n - 1):
            V[i] = (Vp[i - 1] + Vp[i + 1]) / 2.0
    cp = compile_program(smoothing_fixed)
    v = np.arange(8, dtype=np.float64)
    out = cp.run(dict(V=v, Vp=np.zeros(8), n=8))
    expect = v.copy()
    expect[1:7] = (v[0:6] + v[2:8]) / 2
    np.testing.assert_allclose(np.asarray(out["V"]), expect, rtol=1e-5)


def test_fixed_scalar_as_vector_accepted():
    # paper §3.2: n := V[i] fixed by making n a vector
    @loop_program
    def fixed(V: vector, N: vector, W: vector, n: dim):
        for i in range(0, n):
            N[i] = V[i]
            W[i] = N[i] * 2.0
    compile_program(fixed)


def test_exception_b_matmul_like_read_of_aggregate():
    # pq[i,j] += ...; err[i,j] := R[i,j]-pq[i,j]  is the paper's exception (b)
    @loop_program
    def mf_head(R: matrix, P: matrix, Q: matrix, pq: matrix, err: matrix,
                n: dim, m: dim, l: dim):
        for i in range(0, n):
            for j in range(0, m):
                pq[i, j] = 0.0
                for k in range(0, l):
                    pq[i, j] += P[i, k] * Q[k, j]
                err[i, j] = R[i, j] - pq[i, j]
    compile_program(mf_head)


def test_exception_b_violation_rejected():
    # reading the aggregate inside the k-loop violates exception (b)
    with pytest.raises(RejectionError):
        def bad(P: matrix, Q: matrix, pq: matrix, M: matrix,
                n: dim, m: dim, l: dim):
            for i in range(0, n):
                for j in range(0, m):
                    pq[i, j] = 0.0
                    for k in range(0, l):
                        pq[i, j] += P[i, k] * Q[k, j]
                        M[i, k] = pq[i, j]
        compile_program(parse_program(bad))


def test_mixed_monoid_hardening():
    with pytest.raises(RejectionError):
        def bad(V: vector, W: vector, n: dim):
            for i in range(0, n):
                V[0] += W[i]
                V[0] *= W[i]
        compile_program(parse_program(bad))


def test_incremental_with_indirect_key_accepted():
    # the paper's flagship permissiveness: C[K[i]] += V[i]
    @loop_program
    def indirect(K: vector, V: vector, C: vector, n: dim):
        for i in range(0, n):
            C[int(K[i])] += V[i]
    cp = compile_program(indirect)
    k = np.array([1.0, 0.0, 1.0, 2.0])
    v = np.array([10.0, 20.0, 30.0, 40.0])
    out = cp.run(dict(K=k, V=v, C=np.zeros(3), n=4))
    np.testing.assert_allclose(np.asarray(out["C"]), [20.0, 40.0, 40.0],
                               rtol=1e-6)


def test_scalar_write_in_loop_rejected():
    with pytest.raises(RejectionError):
        def bad(V: vector, t: scalar, n: dim):
            for i in range(0, n):
                t = V[i]
        compile_program(parse_program(bad))

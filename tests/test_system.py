"""End-to-end behaviour tests: the train driver runs, checkpoints, resumes,
and the serve driver generates; the dry-run entry point works single-cell
(in a subprocess with forced devices)."""
import os
import subprocess
import sys

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "llama3-8b", "--smoke", "--steps", "6",
                 "--global-batch", "4", "--seq", "16",
                 "--ckpt", str(tmp_path), "--ckpt-every", "3"])
    assert np.isfinite(loss)
    # resume continues from the checkpoint
    loss2 = main(["--arch", "llama3-8b", "--smoke", "--steps", "8",
                  "--global-batch", "4", "--seq", "16",
                  "--ckpt", str(tmp_path), "--resume"])
    assert np.isfinite(loss2)


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    gen = main(["--arch", "llama3-8b", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 4)


def test_loss_decreases_on_learnable_data(tmp_path):
    """Real learning signal: constant-token data should drive CE down."""
    import jax
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.optim.adamw import adamw_init
    from repro.train.step import make_train_step

    cfg = smoke_config("llama3-8b")
    model = get_model(cfg)
    params = model.init(0)
    step = jax.jit(make_train_step(cfg, None, ("data",), lr=1e-2,
                                   compress_grads=False))
    batch = {"tokens": np.full((4, 16), 7, np.int32),
             "labels": np.full((4, 16), 7, np.int32)}
    opt = adamw_init(params)
    first = None
    for _ in range(10):
        params, opt, m = step(params, opt, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first * 0.5, (first, float(m["loss"]))


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=_ROOT, env=env, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "\"status\": \"ok\"" in r.stdout


@pytest.mark.slow
def test_dryrun_respects_long_context_skip(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3-8b",
         "--shape", "long_500k", "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=_ROOT, env=env, timeout=300)
    assert r.returncode == 0
    assert "skipped" in r.stdout

"""Continuous-batching serve engine: mixed-length requests decoded in
shared slots must produce exactly the tokens of independent greedy runs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.serve.engine import ServeEngine


def _greedy_reference(cfg, model, params, prompt, max_new, max_seq):
    logits, cache = model.prefill(params, jnp.asarray(prompt[None]), max_seq)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(toks) < max_new:
        logits, cache = model.decode(params, cache,
                                     jnp.asarray([[toks[-1]]], jnp.int32),
                                     jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b"])
def test_engine_matches_independent_greedy(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, slots=2, max_seq=48)

    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]          # 3 requests > 2 slots
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)

    for p, r in zip(prompts, reqs):
        want = _greedy_reference(cfg, model, params, p, 6, 48)
        assert r.out == want, (r.out, want)


def test_engine_slot_recycling():
    cfg = smoke_config("llama3-8b")
    eng = ServeEngine(cfg, get_model(cfg).init(0), slots=1, max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 3)
            for _ in range(3)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)

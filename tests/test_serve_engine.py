"""Continuous-batching serve engine: mixed-length requests decoded in
shared slots must produce exactly the tokens of independent greedy runs.

Request counts deliberately exceed the slot count everywhere — slot count
and batch size are NOT the same thing, and the scripted-arrival test
drives admissions mid-run through the shared conftest harness (the same
FakeClock/run_schedule the plan-serving suite uses)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FakeClock, run_schedule

from repro.configs import smoke_config
from repro.models import get_model
from repro.serve.engine import ServeEngine


def _greedy_reference(cfg, model, params, prompt, max_new, max_seq):
    logits, cache = model.prefill(params, jnp.asarray(prompt[None]), max_seq)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(toks) < max_new:
        logits, cache = model.decode(params, cache,
                                     jnp.asarray([[toks[-1]]], jnp.int32),
                                     jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b"])
def test_engine_matches_independent_greedy(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, slots=2, max_seq=48)

    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]          # 3 requests > 2 slots
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)

    for p, r in zip(prompts, reqs):
        want = _greedy_reference(cfg, model, params, p, 6, 48)
        assert r.out == want, (r.out, want)


def test_engine_slot_recycling():
    cfg = smoke_config("llama3-8b")
    eng = ServeEngine(cfg, get_model(cfg).init(0), slots=1, max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 3)
            for _ in range(3)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


def test_engine_rids_unique_across_queue_drain():
    """Regression: default rids came from len(queue), so ids recycled
    once the queue drained — two distinct requests could alias.  The
    monotonic counter must hand every request its own id, including
    around explicit-rid submissions."""
    cfg = smoke_config("llama3-8b")
    eng = ServeEngine(cfg, get_model(cfg).init(0), slots=1, max_seq=32)
    rng = np.random.default_rng(2)

    def sub(**kw):
        return eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                          2, **kw)

    a = sub()
    eng.run()                       # queue drains back to empty
    b = sub()                       # would have re-issued rid 0
    c = sub(rid=40)                 # explicit ids advance the counter too
    d = sub()
    eng.run()
    rids = [r.rid for r in (a, b, c, d)]
    assert len(set(rids)) == 4, rids
    assert d.rid > c.rid == 40 > b.rid > a.rid


def test_engine_scripted_midrun_arrivals():
    """Requests arriving WHILE earlier ones decode (more requests than
    slots, staggered on the shared fake-clock schedule) still match the
    independent greedy reference exactly."""
    cfg = smoke_config("llama3-8b")
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, slots=2, max_seq=48)

    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 4, 8, 5)]       # 4 requests > 2 slots
    reqs = []
    clock = FakeClock()
    events = [
        (0.000, lambda: reqs.append(eng.submit(prompts[0], 5))),
        (0.001, lambda: reqs.append(eng.submit(prompts[1], 5))),
        (0.002, lambda: reqs.append(eng.submit(prompts[2], 5))),  # no slot
        (0.003, lambda: reqs.append(eng.submit(prompts[3], 5))),  # queued
    ]
    run_schedule(clock, events, eng.step)   # each tick = one engine step
    eng.run()                               # drain the stragglers
    assert all(r.done for r in reqs)
    for p, r in zip(prompts, reqs):
        want = _greedy_reference(cfg, model, params, p, 5, 48)
        assert r.out == want, (r.out, want)

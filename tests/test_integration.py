"""Cross-layer integration: the paper's compiled group-by, the Pallas
segment kernel, and the MoE combine primitive all compute the same thing —
the technique really is one first-class feature across the stack."""
import jax.numpy as jnp
import numpy as np

from repro.core import compile_program, loop_program, map_, vector, dim
from repro.kernels import segment_sum
from repro.models.moe import segment_add


@loop_program
def combine(T: vector, W: vector, V: vector, Y: map_, n: dim):
    # the MoE combine loop: Y[token(a)] += weight(a) * value(a)
    for a in range(0, n):
        Y[int(T[a])] += W[a] * V[a]


def test_moe_combine_equals_compiled_groupby_equals_kernel():
    rng = np.random.default_rng(0)
    n, toks = 200, 16
    t = rng.integers(0, toks, n)
    w = rng.standard_normal(n).astype(np.float32)
    v = rng.standard_normal(n).astype(np.float32)

    # 1. the paper's compiler
    cp = compile_program(combine)
    y1 = np.asarray(cp.run(dict(T=t.astype(np.float64), W=w, V=v,
                                Y=np.zeros(toks), n=n))["Y"])
    # 2. the same program with the Pallas kernel as group-by backend
    cpk = compile_program(combine, use_kernels=True)
    y2 = np.asarray(cpk.run(dict(T=t.astype(np.float64), W=w, V=v,
                                 Y=np.zeros(toks), n=n))["Y"])
    # 3. the MoE layer's combine primitive
    y3 = np.asarray(segment_add(jnp.asarray(w * v)[:, None],
                                jnp.asarray(t, jnp.int32), toks))[:, 0]
    # 4. the raw Pallas kernel
    y4 = np.asarray(segment_sum(jnp.asarray(t, jnp.int32),
                                jnp.asarray((w * v))[:, None], toks))[:, 0]

    for other in (y2, y3, y4):
        np.testing.assert_allclose(y1, other, rtol=1e-4, atol=1e-4)


def test_wordcount_with_kernel_backend():
    from repro.core.programs import word_count
    rng = np.random.default_rng(1)
    w = rng.integers(0, 10, 300).astype(np.float64)
    a = compile_program(word_count).run(dict(W=(w,), C=np.zeros(10)))["C"]
    b = compile_program(word_count, use_kernels=True).run(
        dict(W=(w,), C=np.zeros(10)))["C"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

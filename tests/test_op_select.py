"""Operator-selection subsystem (core/op_select.py, DESIGN.md §8):
decision-table goldens under the forced cost model, autotune-cache
round-trips, backend equivalence on randomized programs, and the
explain()/explain_rounds() observable contract."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import compile_program
from repro.core.op_select import (EXCHANGE_CANDIDATES, SEGMENT_CANDIDATES,
                                  OpSelector)
from repro.core.programs import ALL

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# decision-table goldens: the cost model is a deterministic function of the
# shape class and platform (autotune may override it; these pin the model)
# ---------------------------------------------------------------------------

def test_cost_model_decision_table_cpu():
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    table = [
        # (n, k, d, op)                      -> expected backend
        ((200_000, 1000, 1, "+"), "scatter"),   # large N: scatter wins
        ((8192, 128, 1, "+"), "scatter"),
        ((4096, 16, 1, "+"), "onehot"),         # tiny K: one-hot dot wins
        ((512, 8, 1, "+"), "onehot"),
        ((200_000, 1000, 1, "min"), "scatter"),  # no onehot for min
        ((65_536, 4096, 1, "*"), "scatter"),     # sort never wins on cpu
    ]
    for (n, k, d, op), want in table:
        dec = sel.choose_segment(n=n, k=k, d=d, op=op, dtype="float32",
                                 dest_dist="ONED_ROW")
        assert dec.backend == want, ((n, k, d, op), dec)
        assert dec.source == "cost"


def test_cost_model_decision_table_tpu():
    # the pallas MXU kernel is only ever cost-picked on a real TPU backend
    sel = OpSelector(mode="cost", cache_path=None, platform="tpu")
    big = sel.choose_segment(n=200_000, k=1000, d=1, op="+",
                             dtype="float32", dest_dist="ONED_ROW")
    assert big.backend == "pallas"
    small = sel.choose_segment(n=512, k=8, d=1, op="+", dtype="float32",
                               dest_dist="ONED_ROW")
    assert small.backend == "onehot"
    cpu = OpSelector(mode="cost", cache_path=None, platform="cpu")
    for n, k in [(512, 8), (8192, 128), (200_000, 1000)]:
        dec = cpu.choose_segment(n=n, k=k, d=1, op="+", dtype="float32",
                                 dest_dist="ONED_ROW")
        assert dec.backend != "pallas", (n, k, dec)


def test_candidate_sets_respect_monoid():
    assert "onehot" not in SEGMENT_CANDIDATES["min"]   # onehot only sums
    assert "onehot" not in SEGMENT_CANDIDATES["*"]
    assert "pallas" not in SEGMENT_CANDIDATES["*"]
    assert set(SEGMENT_CANDIDATES["+"]) == {"scatter", "sort", "onehot",
                                            "pallas"}


def test_exchange_decision_table():
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    # + into a row-block destination: reduce-scatter (K/P rows per shard)
    dec = sel.choose_exchange(k=1024, d=1, op="+", nshards=8, n_local=128,
                              dest_dist="ONED_ROW")
    assert dec.backend == "psum_scatter"
    # non-+ monoids have no reduce-scatter primitive
    dec = sel.choose_exchange(k=1024, d=1, op="min", nshards=8,
                              n_local=128, dest_dist="ONED_ROW")
    assert dec.backend == "allreduce"
    # replicated destination: allreduce is the only candidate
    dec = sel.choose_exchange(k=1024, d=1, op="+", nshards=8, n_local=128,
                              dest_dist="REP")
    assert dec.backend == "allreduce"
    assert set(EXCHANGE_CANDIDATES) == {"psum_scatter", "allreduce"}


def test_reduce_dest_decision_table():
    sel = OpSelector(mode="cost", cache_path=None, platform="cpu")
    # tiny destination: sharding pays placement overhead for nothing
    small = sel.choose_reduce_dest(k=128, d=1, op="+", nshards=8)
    assert small.backend == "replicate"
    # large destination: dense partial + reduce-scatter wins (K/P rows
    # per shard instead of K everywhere)
    big = sel.choose_reduce_dest(k=1 << 20, d=1, op="+", nshards=8)
    assert big.backend == "shard"


def test_demotable_dests_static_analysis():
    from repro.core.dist_analysis import demotable_dests
    # word_count's C is only ever an unaligned reduce destination
    cp = compile_program(ALL["word_count"])
    assert "C" in demotable_dests(cp.plan, cp.program)
    # pagerank: C is an unaligned dest + cross-shard read (demotable),
    # but P and NP have aligned store rounds — never demoted
    cp = compile_program(ALL["pagerank"])
    dem = demotable_dests(cp.plan, cp.program)
    assert "C" in dem and "P" not in dem and "NP" not in dem


def test_contract_decision_per_platform():
    cpu = OpSelector(mode="cost", cache_path=None, platform="cpu")
    tpu = OpSelector(mode="cost", cache_path=None, platform="tpu")
    # off-TPU the Pallas tiled kernel runs in python-level interpret mode
    assert cpu.choose_contract(m=512, k=512, n=512).backend == \
        "unpack-einsum"
    assert tpu.choose_contract(m=512, k=512, n=512).backend == \
        "pallas-tiled"


# ---------------------------------------------------------------------------
# autotune: measure once per shape class, persist, reload identically
# ---------------------------------------------------------------------------

def test_autotune_cache_roundtrip(tmp_path):
    cache = str(tmp_path / "autotune.json")
    sel = OpSelector(mode="autotune", cache_path=cache)
    d1 = sel.choose_segment(n=256, k=16, d=1, op="+", dtype="float32",
                            dest_dist="REP")
    assert d1.source == "autotune"
    assert os.path.exists(cache)
    blob = json.load(open(cache))
    assert blob["version"] == 1 and len(blob["decisions"]) == 1
    entry = next(iter(blob["decisions"].values()))
    assert entry["backend"] == d1.backend
    assert set(entry["us"]) == set(SEGMENT_CANDIDATES["+"])
    # a fresh selector reloads the decision without re-measuring
    sel2 = OpSelector(mode="autotune", cache_path=cache)
    d2 = sel2.choose_segment(n=256, k=16, d=1, op="+", dtype="float32",
                             dest_dist="REP")
    assert d2.source == "cache" and d2.backend == d1.backend
    # same shape CLASS (power-of-two bucket) → same cached decision
    d3 = sel2.choose_segment(n=250, k=15, d=1, op="+", dtype="float32",
                             dest_dist="REP")
    assert d3.source == "cache" and d3.backend == d1.backend


def test_autotune_compile_produces_identical_plan(tmp_path):
    cache = str(tmp_path / "autotune.json")
    rng = np.random.default_rng(3)
    ins = dict(S=(rng.integers(0, 50, 2000).astype(float),
                  rng.standard_normal(2000)), C=np.zeros(50))

    def run_once():
        cp = compile_program(ALL["group_by"], op_select="autotune",
                             autotune_cache=cache)
        out = cp.run(dict(S=(ins["S"][0].copy(), ins["S"][1].copy()),
                          C=np.zeros(50)))
        sel_lines = [ln for ln in cp.explain().splitlines()
                     if "selected:" in ln]
        return np.asarray(out["C"]), sel_lines

    c1, lines1 = run_once()          # measures + persists
    c2, lines2 = run_once()          # reloads from disk
    np.testing.assert_allclose(c1, c2)
    assert len(lines1) == 1 and "segment:" in lines1[0]
    assert lines2[0].replace("[cache]", "[autotune]") == lines1[0] \
        or lines1 == lines2          # same backend, cache provenance


# ---------------------------------------------------------------------------
# backend equivalence: every candidate computes the same ⊕-merge with the
# paper's drop semantics (negative and out-of-range keys)
# ---------------------------------------------------------------------------

_FORCE_MODES = ("force:scatter", "force:sort", "force:onehot",
                "force:pallas")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_backend_equivalence_randomized(seed):
    rng = np.random.default_rng(seed)
    nv, ne = int(rng.integers(8, 60)), int(rng.integers(16, 400))
    # keys deliberately include negatives and ≥ nv (must drop everywhere)
    keys = rng.integers(-4, nv + 5, ne).astype(np.float64)
    vals = rng.standard_normal(ne)
    cases = {
        "word_count": dict(W=keys.copy(), C=np.zeros(nv)),
        "group_by": dict(S=(keys.copy(), vals.copy()), C=np.zeros(nv)),
        "histogram": dict(P=tuple(rng.integers(-2, nv + 2, ne)
                                  .astype(np.float64) for _ in range(3)),
                          R=np.zeros(nv), G=np.zeros(nv), B=np.zeros(nv)),
    }
    for name, ins in cases.items():
        ref = None
        for mode in _FORCE_MODES:
            cp = compile_program(ALL[name], op_select=mode)
            out = cp.run({k: (tuple(c.copy() for c in v)
                              if isinstance(v, tuple) else
                              (v.copy() if isinstance(v, np.ndarray) else v))
                          for k, v in ins.items()})
            got = {k: np.asarray(v, np.float64) for k, v in out.items()}
            if ref is None:
                ref = got
                continue
            for k in ref:
                np.testing.assert_allclose(got[k], ref[k], rtol=1e-4,
                                           atol=1e-4,
                                           err_msg=f"{name}/{mode}/{k}")


def test_onehot_and_pallas_drop_nonfinite_values():
    # dropped rows may carry non-finite values (a condition guarding a
    # division); the one-hot DOT paths must zero them — 0 × inf = NaN
    # would otherwise contaminate every segment the matmul touches
    import jax.numpy as jnp
    from repro.core.frontend import bag, loop_program, map_

    @loop_program
    def safe_inv(S: bag[2], C: map_):
        for k, v in S:
            if v != 0.0:
                C[k] += 1.0 / v

    keys = np.array([0.0, 1.0, 2.0, 1.0])
    vals = np.array([2.0, 0.0, 4.0, 8.0])     # row 1 dropped, 1/0 = inf
    want = np.array([0.5, 0.125, 0.25])
    for mode in _FORCE_MODES:
        out = compile_program(safe_inv, op_select=mode).run(
            dict(S=(keys.copy(), vals.copy()), C=np.zeros(3)))
        got = np.asarray(out["C"], np.float64)
        assert np.isfinite(got).all(), (mode, got)
        np.testing.assert_allclose(got, want, rtol=1e-5, err_msg=mode)
    # and the kernel directly, with an OOB-row inf
    from repro.kernels.segment_reduce import segment_reduce
    r = segment_reduce(jnp.asarray(np.array([0, 99, -1], np.int32)),
                       jnp.asarray(np.array([1.0, np.inf, np.nan],
                                            np.float32)), 2)
    np.testing.assert_allclose(np.asarray(r), [1.0, 0.0])


def test_force_unpack_einsum_respected_on_packed_lhs():
    # a pinned single-candidate TiledMatmul must honor the pin, not fall
    # through to the Pallas kernel
    import jax.numpy as jnp
    from repro.core.tiles import pack
    rng = np.random.default_rng(5)
    n, m, l = 32, 24, 16
    M = rng.standard_normal((n, l))
    N = rng.standard_normal((l, m))
    tm = pack(jnp.asarray(M, jnp.float32), bm=16, bn=16)
    for mode, tag in [("force:unpack-einsum", "tiled:unpack-einsum[pinned]"),
                      ("force:pallas-tiled", "tiled:pallas-tiled[pinned]"),
                      ("cost", "tiled:unpack-einsum[cost]")]:  # cpu model
        cp = compile_program(ALL["matrix_multiplication"], op_select=mode)
        out = cp.run(dict(M=tm, N=N, R=np.zeros((n, m)), n=n, m=m, l=l))
        np.testing.assert_allclose(np.asarray(out["R"]), M @ N, rtol=1e-3,
                                   atol=1e-4, err_msg=mode)
        assert tag in cp.explain(tiled={"M"}), (mode, cp.explain(tiled={"M"}))


def test_force_dense_grid_skips_einsum():
    cp = compile_program(ALL["matrix_multiplication"],
                         op_select="force:dense-grid")
    rng = np.random.default_rng(6)
    A, B = rng.standard_normal((12, 8)), rng.standard_normal((8, 10))
    out = cp.run(dict(M=A, N=B, R=np.zeros((12, 10)), n=12, m=10, l=8))
    np.testing.assert_allclose(np.asarray(out["R"]), A @ B, rtol=1e-4,
                               atol=1e-4)
    assert "selected: fallback:dense-grid" in cp.explain()


def test_forced_backend_shows_in_explain():
    cp = compile_program(ALL["word_count"], op_select="force:sort")
    assert "backend=sort" in cp.explain()
    cp.run(dict(W=(np.array([1.0, 2.0, 1.0]),), C=np.zeros(4)))
    assert "selected: segment:sort[pinned]" in cp.explain()


def test_backend_selection_reaches_fused_parts():
    # operator-selection runs AFTER update-fusion: the three fused
    # histogram updates must each get candidates / honor forcing
    cp = compile_program(ALL["histogram"])
    assert cp.explain().count("backend=auto{") == 3
    cp = compile_program(ALL["histogram"], op_select="force:sort")
    assert cp.explain().count("backend=sort") == 3


def test_auto_backend_selected_line_golden(tmp_path):
    # empty cache path isolates the golden from any developer-local
    # .repro_autotune.json (the cache overrides cost mode by design)
    cp = compile_program(ALL["group_by"],
                         autotune_cache=str(tmp_path / "cache.json"))
    assert "backend=auto{scatter|sort|onehot|pallas}" in cp.explain()
    assert "selected:" not in cp.explain()      # no run yet: no decision
    rng = np.random.default_rng(0)
    cp.run(dict(S=(rng.integers(0, 1000, 200_000).astype(float),
                   rng.standard_normal(200_000)), C=np.zeros(1000)))
    # the committed CPU cost table picks scatter for this class
    assert "selected: segment:scatter[cost]" in cp.explain()


def test_cost_mode_honors_cache_override(tmp_path):
    # the cache file is the override channel in EVERY mode: a supplied
    # entry beats the analytical model (e.g. pinning the exchange on a
    # platform whose reduce-scatter lowering underperforms)
    sel0 = OpSelector(mode="cost", cache_path=None, platform="cpu")
    key = sel0.exchange_class(4096, 1, "+", 8, 512)
    cache = tmp_path / "cache.json"
    cache.write_text(json.dumps({
        "version": 1, "platform": "cpu",
        "decisions": {key: {"backend": "allreduce"}}}))
    sel = OpSelector(mode="cost", cache_path=str(cache), platform="cpu")
    dec = sel.choose_exchange(k=4096, d=1, op="+", nshards=8, n_local=512,
                              dest_dist="ONED_ROW")
    assert dec.backend == "allreduce" and dec.source == "cache"


def test_force_inapplicable_falls_through_to_model():
    # force:onehot cannot apply to a min-group-by (onehot only sums):
    # the selector must fall through to the cost model, not silently pin
    # the first candidate
    sel = OpSelector(mode="force:onehot", cache_path=None, platform="cpu")
    dec = sel.choose_segment(n=8192, k=128, d=1, op="min", dtype="float32",
                             dest_dist="REP")
    assert dec.backend == "scatter" and dec.source == "cost"


def test_use_kernels_legacy_flag_pins_pallas():
    cp = compile_program(ALL["word_count"], use_kernels=True)
    assert "backend=pallas" in cp.explain()
    out = cp.run(dict(W=(np.array([0.0, 1.0, 1.0, 3.0]),), C=np.zeros(4)))
    np.testing.assert_allclose(np.asarray(out["C"]), [1, 2, 0, 1])
    assert "selected: segment:pallas[pinned]" in cp.explain()


# ---------------------------------------------------------------------------
# distributed: the exchange is an op_select decision, printed per round
# ---------------------------------------------------------------------------

_DIST_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(11)

def run_case(nv, ne):
    ins = dict(S=(rng.integers(0, nv, ne).astype(np.float64),
                  rng.standard_normal(ne)), C=np.zeros(nv))
    cp = compile_program(ALL["group_by"])
    dp = compile_distributed(cp, mesh, ("data",))
    out = dp.run(ins)
    single = compile_program(ALL["group_by"]).run(
        dict(S=(ins["S"][0].copy(), ins["S"][1].copy()), C=np.zeros(nv)))
    err = np.abs(np.asarray(out["C"], np.float64)
                 - np.asarray(single["C"], np.float64)).max()
    assert err < 1e-4, (nv, err)
    return dp.explain_rounds()

# small K: sharding the 128-row destination doesn't pay — op_select
# demotes it to REP and the exchange is a plain psum
text = run_case(128, 1024)
assert "placement: C→REP (dest-replicate[cost])" in text, text
assert "reduce(psum)" in text, text
assert "per-shard[C]: segment:" in text, text

# large K: the dense partial + reduce-scatter exchange pays; the
# destination stays ONED_ROW and the round uses psum_scatter
text = run_case(1 << 19, 4096)
assert "placement:" not in text, text
assert "reduce(psum_scatter[cost])" in text, text
print("OPSEL_DIST_OK")
"""


@pytest.mark.slow
def test_exchange_decision_in_rounds():
    r = subprocess.run([sys.executable, "-c", _DIST_CODE],
                       capture_output=True, text=True, cwd=_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OPSEL_DIST_OK" in r.stdout

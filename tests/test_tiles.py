"""§5 packed (tiled) arrays: pack/unpack roundtrip, zero-tile pruning, and
the fused block-sparse matmul through the loop compiler."""
import jax.numpy as jnp
import numpy as np

from repro.core import compile_program
from repro.core.programs import matrix_multiplication
from repro.core.tiles import TiledMatrix, pack, unpack


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    m = rng.standard_normal((50, 37)).astype(np.float32)
    t = pack(jnp.asarray(m), bm=16, bn=16)
    np.testing.assert_allclose(np.asarray(unpack(t)), m, rtol=1e-6)


def test_zero_tiles_pruned():
    m = np.zeros((64, 64), np.float32)
    m[40, 40] = 1.0
    t = pack(jnp.asarray(m), bm=32, bn=32)
    assert float(t.mask.sum()) == 1.0
    np.testing.assert_allclose(np.asarray(unpack(t)), m)


def test_compiler_fuses_packed_matmul():
    rng = np.random.default_rng(3)
    n, m, l = 40, 30, 20
    M = rng.standard_normal((n, l))
    M[:16] = 0.0
    N = rng.standard_normal((l, m))
    tm = pack(jnp.asarray(M, jnp.float32), bm=16, bn=16)
    cp = compile_program(matrix_multiplication)
    dense = cp.run(dict(M=M, N=N, R=np.zeros((n, m)), n=n, m=m, l=l))
    tiled = cp.run(dict(M=tm, N=N, R=np.zeros((n, m)), n=n, m=m, l=l))
    np.testing.assert_allclose(np.asarray(tiled["R"]),
                               np.asarray(dense["R"]), rtol=1e-3, atol=1e-4)

"""Fault-injection harness + classified degradation ladder (DESIGN.md
§11): scripted faults at every named site recover to bit-identical output
(same-level retries), recover after exactly one descent (deterministic
errors with a level left to descend to), or surface (deterministic errors
that reproduce at every level) — with every move visible in
explain_faults().  Mid-loop checkpoint/resume rides the same harness: a
SeqLoop killed at iteration k resumes bit-identically.

The distributed ladder (dist.* sites, fused → per-member → REP-everything
→ single-device) runs in a slow subprocess with 8 forced host devices,
like test_core_distributed.py.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from test_core_programs import data_for

from repro.core import compile_program, interpret
from repro.core import faults as F
from repro.core.programs import ALL
from repro.runtime import LoopRunner

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fresh(ins):
    out = {}
    for k, v in ins.items():
        if isinstance(v, tuple):
            out[k] = tuple(np.array(c) for c in v)
        elif isinstance(v, np.ndarray):
            out[k] = v.copy()
        else:
            out[k] = v
    return out


def _quiet(cp):
    cp.faults.sleep = lambda s: None        # no real backoff sleeps
    return cp


def _bitident(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


# ---------------------------------------------------------------------------
# harness unit behaviour
# ---------------------------------------------------------------------------

def test_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown injection site"):
        F.FaultSpec("no.such.site")


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultSpec("lower.node", "flaky")


def test_site_is_noop_without_injector():
    F.site("lower.node", node="MapExpr")     # must not raise or record
    assert F.active() is None


def test_nth_hit_counting():
    with F.inject(F.FaultSpec("lower.node", "transient", nth=3)) as inj:
        for _ in range(2):
            F.site("lower.node")
        with pytest.raises(F.TransientFault):
            F.site("lower.node")
        F.site("lower.node")                 # hit 4: spec exhausted
    assert inj.hits["lower.node"] == 4
    assert [f["hit"] for f in inj.fired] == [3]


def test_classify():
    assert F.classify(F.TransientFault("x")) == "transient"
    assert F.classify(F.CapacityFault("x")) == "capacity"
    assert F.classify(F.DeterministicFault("x")) == "deterministic"
    assert F.classify(MemoryError()) == "capacity"
    assert F.classify(RuntimeError("RESOURCE_EXHAUSTED: oom")) == "capacity"
    assert F.classify(RuntimeError("UNAVAILABLE: peer reset")) == "transient"
    assert F.classify(RuntimeError("DEADLINE_EXCEEDED")) == "transient"
    # the safe default: unknown errors must never be retried forever
    assert F.classify(ValueError("bad user input")) == "deterministic"


def test_classify_real_oom_messages():
    """Verbatim allocator messages captured from jaxlib / XLA / CUDA /
    torch runs: every one must read as capacity, or real OOMs would take
    the retry (or surface) path instead of the chunked rung."""
    real = [
        "RESOURCE_EXHAUSTED: Out of memory while trying to allocate "
        "75497472 bytes.",
        "RESOURCE_EXHAUSTED: Ran out of memory in memory space hbm. "
        "Used 33.61G of 15.48G hbm. Exceeded hbm capacity by 18.13G.",
        "Resource exhausted: Out of memory while trying to allocate "
        "4294967296 bytes.",
        "CUDA_ERROR_OUT_OF_MEMORY: out of memory",
        "CUDA out of memory. Tried to allocate 20.00 MiB",
        "INTERNAL: Failed to allocate 1073741824 bytes",
    ]
    for msg in real:
        assert F.classify(RuntimeError(msg)) == "capacity", msg

    # the runtime's exception TYPES classify by name even with an
    # unhelpful message (jaxlib.xla_extension.XlaRuntimeError subclasses
    # RuntimeError; torch raises OutOfMemoryError)
    class XlaRuntimeError(RuntimeError):
        pass

    class OutOfMemoryError(RuntimeError):
        pass

    assert F.classify(OutOfMemoryError("")) == "capacity"
    assert F.classify(XlaRuntimeError("RESOURCE_EXHAUSTED: oom")) \
        == "capacity"
    assert F.classify(XlaRuntimeError("INTERNAL: unknown")) \
        == "deterministic"

    # word-boundary matching: "bloom"/"BOOM" must NOT read as OOM
    assert F.classify(RuntimeError("bloom filter rebuild failed")) \
        == "deterministic"
    assert F.classify(RuntimeError("BOOM")) == "deterministic"
    assert F.classify(RuntimeError("device OOM during fusion")) \
        == "capacity"


def test_run_with_retries_bounded_backoff():
    ledger = F.FaultLedger("t")
    sleeps = []
    attempts = []

    def fn():
        attempts.append(1)
        raise F.TransientFault("UNAVAILABLE")

    with pytest.raises(F.TransientFault):
        F.run_with_retries(fn, policy=F.RetryPolicy(max_retries=3,
                                                    backoff_s=0.01),
                           ledger=ledger, label="x", sleep=sleeps.append)
    assert len(attempts) == 4                # 1 initial + 3 retries
    assert sleeps == [0.01, 0.02, 0.04]      # exponential, recorded
    assert ledger.counters["retry"] == 3


def test_run_with_retries_never_retries_deterministic():
    ledger = F.FaultLedger("t")
    attempts = []

    def fn():
        attempts.append(1)
        raise F.DeterministicFault("user error")

    with pytest.raises(F.DeterministicFault):
        F.run_with_retries(fn, policy=F.RetryPolicy(), ledger=ledger,
                           label="x", sleep=lambda s: None)
    assert len(attempts) == 1 and ledger.counters["retry"] == 0


def test_straggler_watchdog_trailing_median():
    ledger = F.FaultLedger("t")
    for _ in range(5):
        ledger.note_time("round", 0.01)
    ledger.note_time("round", 0.2)           # 20x the trailing median
    assert ledger.counters["straggler"] == 1
    assert "straggler" in ledger.explain()


# ---------------------------------------------------------------------------
# single-device ladder matrix: site x kind x mode on three programs
# ---------------------------------------------------------------------------

PROGRAMS = ("pagerank", "group_by", "kmeans_step")


@pytest.mark.parametrize("name", PROGRAMS)
@pytest.mark.parametrize("site", ("lower.whole_trace", "lower.node"))
@pytest.mark.parametrize("mode", ("whole", "eager"))
def test_transient_recovers_bitidentical(name, site, mode):
    """A transient fault at any site is retried at the SAME ladder level:
    the re-attempt runs the identical computation, so recovery is
    bit-identical to the fault-free run of the same mode."""
    if mode == "eager" and site == "lower.whole_trace":
        pytest.skip("site not on the eager path")
    ins = data_for(name)
    ref = _quiet(compile_program(ALL[name], compile_mode=mode)) \
        .run(_fresh(ins))
    cp = _quiet(compile_program(ALL[name], compile_mode=mode))
    with F.inject(F.FaultSpec(site, "transient", nth=1)) as inj:
        out = cp.run(_fresh(ins))
    assert inj.fired, "spec never fired"
    assert _bitident(out, ref)
    assert cp.faults.counters["retry"] >= 1
    assert cp.faults.counters["recover"] >= 1
    assert cp.faults.counters["descend"] == 0


@pytest.mark.parametrize("name", PROGRAMS)
@pytest.mark.parametrize("site", ("lower.whole_trace", "lower.node"))
def test_deterministic_descends_whole_to_eager(name, site):
    """A deterministic fault inside the whole-program attempt gets its ONE
    ladder descent: the eager level absorbs it (the spec's single firing
    was consumed), and the result is bit-identical to a fault-free EAGER
    run — the recovered path IS the eager path."""
    ins = data_for(name)
    ref = _quiet(compile_program(ALL[name], compile_mode="eager")) \
        .run(_fresh(ins))
    cp = _quiet(compile_program(ALL[name]))
    with F.inject(F.FaultSpec(site, "deterministic", nth=1)) as inj:
        out = cp.run(_fresh(ins))
    assert inj.fired
    assert _bitident(out, ref)
    assert cp.faults.counters["descend"] == 1
    assert cp.faults.level_reached == "eager"
    assert cp.trace_failures == 1 and cp._whole_disabled


@pytest.mark.parametrize("name", PROGRAMS)
@pytest.mark.parametrize("mode", ("whole", "eager"))
def test_deterministic_forever_surfaces(name, mode):
    """A deterministic error that reproduces at every level SURFACES after
    at most one ladder descent — never an infinite retry, and never the
    interpreter oracle (which would silently mask a user error)."""
    cp = _quiet(compile_program(ALL[name], compile_mode=mode))
    with F.inject(F.FaultSpec("lower.node", "deterministic", nth=1,
                              times=10 ** 6)):
        with pytest.raises(F.DeterministicFault):
            cp.run(_fresh(data_for(name)))
    assert cp.faults.counters["descend"] <= 1
    assert cp.faults.level_reached != "interp"


def test_persistent_transient_reaches_interp_oracle():
    """Transients that persist past the bounded retries descend all the
    way to the interpreter oracle — correct float64 results (allclose,
    not bit-identical; the ledger says the level was reached)."""
    name = "group_by"
    ins = data_for(name)
    ref = interpret(ALL[name].program,
                    {k: (np.array(v, np.float64)
                         if isinstance(v, np.ndarray) else v)
                     for k, v in _fresh(ins).items()})
    cp = _quiet(compile_program(ALL[name], compile_mode="eager"))
    with F.inject(F.FaultSpec("lower.node", "transient", nth=1,
                              times=10 ** 6)):
        out = cp.run(_fresh(ins))
    np.testing.assert_allclose(np.asarray(out["C"], np.float64),
                               np.asarray(ref["C"], np.float64),
                               rtol=1e-5, atol=1e-6)
    assert cp.faults.level_reached == "interp"
    assert cp.faults.counters["retry"] >= cp.policy.max_retries


# ---------------------------------------------------------------------------
# per-signature whole-program disable (satellite: sticky _whole_disabled)
# ---------------------------------------------------------------------------

def test_whole_disable_is_per_signature():
    """A trace failure for one input signature must not disable
    whole-program mode for other signatures (the old global boolean did)."""
    cp = _quiet(compile_program(ALL["group_by"]))
    small = data_for("group_by")
    big = dict(small)
    big["S"] = (np.concatenate([small["S"][0]] * 2),
                np.concatenate([small["S"][1]] * 2))
    with F.inject(F.FaultSpec("lower.whole_trace", "deterministic", nth=1)):
        cp.run(_fresh(small))                # signature A: trace fails
    assert cp.trace_failures == 1 and len(cp._whole_bad) == 1
    cp.run(_fresh(big))                      # signature B: traces fine
    assert cp.trace_count == 1
    assert len(cp._whole_bad) == 1           # A still sitting out its ttl


def test_whole_disable_expires_and_retraces():
    """The per-signature disable is a bounded sit-out, not a life
    sentence: after `disable_ttl` eager runs the trace is re-attempted
    (and succeeds once the fault is gone), with the probes counting it."""
    cp = _quiet(compile_program(ALL["group_by"]))
    cp.policy.disable_ttl = 2
    ins = data_for("group_by")
    with F.inject(F.FaultSpec("lower.whole_trace", "deterministic", nth=1)):
        cp.run(_fresh(ins))
    assert cp._whole_disabled and cp.trace_count == 0
    ref = cp.run(_fresh(ins))                # ttl 2 -> 1 (eager)
    cp.run(_fresh(ins))                      # ttl expires -> re-trace
    assert cp.trace_count == 1 and cp.whole_retries == 1
    assert not cp._whole_disabled
    out = cp.run(_fresh(ins))                # whole-program again, cached
    assert cp.cache_hits >= 1
    assert _bitident(out, ref)


def test_explain_faults_renders_ledger():
    cp = _quiet(compile_program(ALL["pagerank"]))
    ins = data_for("pagerank")
    with F.inject(F.FaultSpec("lower.whole_trace", "transient", nth=1)):
        cp.run(_fresh(ins))
    text = cp.explain_faults()
    assert "== fault ledger: pagerank ==" in text
    assert "retries=1 descents=0 recoveries=1" in text
    assert "retry" in text and "[whole]" in text
    assert "whole-program: 0 trace failures" in text


# ---------------------------------------------------------------------------
# mid-loop checkpoint/resume (tentpole part 4)
# ---------------------------------------------------------------------------

def test_seq_loops_numbering():
    from repro.core import plan as P
    cp = compile_program(ALL["pagerank"])
    loops = P.seq_loops(cp.plan)
    assert loops and all(isinstance(n, P.SeqLoop) for _, n in loops)


@pytest.mark.parametrize("every", (1, 2))
def test_midloop_kill_resumes_bitidentical(every, tmp_path):
    """An iterative plan killed at iteration k resumes from the latest
    carry snapshot with BIT-IDENTICAL final outputs vs an uninterrupted
    stepwise run (both execute the same per-iteration computations on the
    same carry values; npz round-trips are exact) — whether every
    iteration was snapshotted or only every other one."""
    from repro.core.plan import seq_loops
    ins = data_for("pagerank")
    ins["num_steps"] = 6.0
    cp = _quiet(compile_program(ALL["pagerank"]))
    assert seq_loops(cp.plan), "pagerank must have a top-level SeqLoop"
    ref = cp.run_stepwise(_fresh(ins))
    runner = LoopRunner(cp, str(tmp_path / "ck"), every=every)
    with F.inject(F.FaultSpec("lower.loop_iter", "deterministic", nth=4,
                              message="kill -9")):
        with pytest.raises(F.DeterministicFault):
            runner.run(_fresh(ins), resume=False)
    at_kill = runner.mgr.latest()
    assert at_kill is not None and runner.saves >= 1
    resumed = LoopRunner(cp, str(tmp_path / "ck"), every=every)
    out = resumed.run(_fresh(ins), resume=True)
    assert resumed.resumed_from == at_kill
    assert _bitident(out, ref)


def test_stepwise_matches_run_allclose():
    """run_stepwise (host-driven loops) is a different XLA compilation
    than run() (on-device lax.while_loop): equal to float tolerance, and
    exactly repeatable against itself — the bit-identity contract of
    resume is stepwise-vs-stepwise."""
    cp = compile_program(ALL["pagerank"])
    ins = data_for("pagerank")
    a = cp.run_stepwise(_fresh(ins))
    b = cp.run(_fresh(ins))
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                   np.asarray(b[k], np.float64),
                                   rtol=1e-5, atol=1e-6)
    assert _bitident(a, cp.run_stepwise(_fresh(ins)))


# ---------------------------------------------------------------------------
# distributed ladder: fused -> per-member -> REP-everything -> single-device
# (subprocess with 8 forced host devices, like test_core_distributed.py)
# ---------------------------------------------------------------------------

_DIST_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import faults as F
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(7)
ins = dict(E=(rng.integers(0, 12, 64).astype(np.float64),
              rng.integers(0, 12, 64).astype(np.float64)),
           P=np.full(12, 1/12), NP=np.zeros(12), C=np.zeros(12),
           N=12, num_steps=2.0, steps=0.0, b=0.85)
fn = ALL["pagerank"]
ref = compile_distributed(fn, mesh, ("data",), mode="shardmap").run(ins)

def fresh():
    dp = compile_distributed(fn, mesh, ("data",), mode="shardmap")
    dp.faults.sleep = lambda s: None
    return dp

def maxerr(out):
    return max(float(np.max(np.abs(np.asarray(out[k], np.float64)
                                   - np.asarray(ref[k], np.float64))))
               for k in ref)

# transient at each dist site: same-level retry, bit-identical
for site in ("dist.fused_compile", "dist.round_exec", "dist.exchange"):
    dp = fresh()
    with F.inject(F.FaultSpec(site, "transient", nth=1)) as inj:
        out = dp.run(ins)
    assert inj.fired, site
    assert maxerr(out) == 0.0, (site, maxerr(out))
    assert dp.faults.counters["retry"] >= 1, site
    assert dp.faults.counters["recover"] >= 1, site

# deterministic once at fused compile: ONE descent to per-member rounds,
# bit-identical (fusion never changes results)
dp = fresh()
with F.inject(F.FaultSpec("dist.fused_compile", "deterministic", nth=1)):
    out = dp.run(ins)
assert maxerr(out) == 0.0
assert dp.faults.level_reached == "per-member rounds"

# deterministic once at round exec: descend to REP-everything placements
# (allclose: different placement compiles differently)
dp = fresh()
with F.inject(F.FaultSpec("dist.round_exec", "deterministic", nth=1)):
    out = dp.run(ins)
assert maxerr(out) < 1e-6
assert dp.faults.level_reached == "rep"
assert dp.faults.counters["descend"] == 1

# deterministic FOREVER: surfaces after exactly one ladder descent
dp = fresh()
raised = False
try:
    with F.inject(F.FaultSpec("dist.round_exec", "deterministic", nth=1,
                              times=10**6)):
        dp.run(ins)
except F.DeterministicFault:
    raised = True
assert raised
assert dp.faults.counters["descend"] == 1

# capacity FOREVER: rounds -> chunked (out-of-core streaming), NEVER the
# rep rung — replicating everything ASCENDS the per-device memory curve,
# exactly the wrong move for an OOM
dp = fresh()
with F.inject(F.FaultSpec("dist.round_exec", "capacity", nth=1,
                          times=10**6)):
    out = dp.run(ins)
assert maxerr(out) < 1e-6
assert dp.faults.level_reached == "chunked"
text = dp.explain_faults()
assert "== fault ledger: pagerank ==" in text
assert "ladder-level-reached=chunked" in text
assert "rounds->chunked" in text
assert "rounds->rep" not in text

# with out-of-core disabled, capacity falls back to the single-device
# rung directly (still never rep)
dp = compile_distributed(fn, mesh, ("data",), mode="shardmap",
                         out_of_core="off")
dp.faults.sleep = lambda s: None
with F.inject(F.FaultSpec("dist.round_exec", "capacity", nth=1,
                          times=10**6)):
    out = dp.run(ins)
assert maxerr(out) < 1e-6
assert dp.faults.level_reached == "single-device"
assert "rounds->single-device" in dp.explain_faults()
print("DIST_FAULTS_OK")
"""


@pytest.mark.slow
def test_distributed_fault_ladder():
    r = subprocess.run([sys.executable, "-c", _DIST_CODE],
                       capture_output=True, text=True, cwd=_ROOT,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "DIST_FAULTS_OK" in r.stdout

"""Deterministic concurrency tests for the plan-serving layer (§10).

No real sleeps anywhere: every test drives PlanServer with the shared
FakeClock + scripted arrival schedules from conftest, so flush-timeout
decisions replay bit-for-bit.  The core contract under test: a request
served through a padded, vmapped batch returns results BIT-IDENTICAL to a
solo sequential run() — for all three mixed-workload programs, including
ragged shapes that share a bucket (padded) and ones that split buckets.
"""
import numpy as np
import pytest

from conftest import FakeClock, run_schedule
from test_core_programs import data_for

from repro.core import programs as progs
from repro.core.lower import compile_program
from repro.serve import PlanServer

WORKLOADS = ("pagerank", "group_by", "kmeans_step")

_CPS = {}


def cps():
    """Module-shared compiled programs (compilation and batch traces are
    the expensive part; the server under test is cheap)."""
    if not _CPS:
        for name in WORKLOADS:
            _CPS[name] = compile_program(getattr(progs, name))
    return _CPS


def ragged(name, scale, seed):
    """data_for() variant with a rescaled bag — ragged client traffic.
    Dtypes mirror data_for exactly so solo and served requests
    canonicalize identically."""
    rng = np.random.default_rng(seed)
    d = data_for(name)
    if name == "pagerank":
        N, m = int(d["N"]), max(4, int(len(d["E"][0]) * scale))
        d["E"] = (rng.integers(0, N, m).astype(np.float64),
                  rng.integers(0, N, m).astype(np.float64))
    elif name == "group_by":
        m = max(4, int(len(d["S"][0]) * scale))
        d["S"] = (rng.integers(0, 10, m).astype(np.float64),
                  rng.standard_normal(m))
    elif name == "kmeans_step":
        m = max(8, int(len(d["P"][0]) * scale))
        d["P"] = (rng.standard_normal(m) * 3, rng.standard_normal(m) * 3)
        d["D"] = np.zeros((m, d["K"]))
        d["MinD"] = np.full(m, 1e30)
        d["Cl"] = np.zeros(m)
    return d


# scales whose bag lengths round up to ONE shared power-of-two bucket
# (base lengths: pagerank E=30 → 32, group_by S=40 → 64, kmeans P=20 → 32)
SHARED_BUCKET_SCALES = {
    "pagerank": (1.0, 0.9, 0.8, 0.6),        # 30, 27, 24, 18 rows
    "group_by": (1.0, 0.95, 0.9, 0.85),      # 40, 38, 36, 34 rows
    "kmeans_step": (1.0, 0.95, 0.9, 0.85),   # 20, 19, 18, 17 rows
}


def deep_copy(ins):
    return {k: (tuple(np.copy(c) for c in v) if isinstance(v, tuple)
                else np.copy(v) if isinstance(v, np.ndarray) else v)
            for k, v in ins.items()}


def assert_bit_identical(name, ins, out):
    """Serving-path output must equal a solo run() bitwise."""
    ref = cps()[name].run(deep_copy(ins))
    for k, rv in ref.items():
        np.testing.assert_array_equal(out[k], np.asarray(rv),
                                      err_msg=f"{name}:{k}")


def make_server(clock, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("flush_ms", 2.0)
    kw.setdefault("bucket_floor", 8)
    return PlanServer(cps(), clock=clock, **kw)


# ---------------------------------------------------------------------------
# batched == sequential, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", WORKLOADS)
def test_batched_matches_sequential(name, fake_clock):
    srv = make_server(fake_clock)
    reqs = [(ragged(name, 1.0, seed), None) for seed in (0, 1, 2, 3)]
    reqs = [(ins, srv.submit(name, ins)) for ins, _ in reqs]
    assert srv.pump() == 4          # full bucket flushes with no timeout
    for ins, t in reqs:
        assert t.state == "done"
        assert_bit_identical(name, ins, t.output)
    s = srv.stats()
    assert s["flushes"] == 1 and s["batch_traced"] == 1
    assert s["seq_fallbacks"] == 0


@pytest.mark.parametrize("name", WORKLOADS)
def test_ragged_requests_pad_into_shared_bucket(name, fake_clock):
    """Different bag lengths under one bucket edge: padded lanes must not
    perturb results (the §3.4 limit masks), outputs slice back to each
    request's own shapes."""
    srv = make_server(fake_clock)
    reqs = [(ins := ragged(name, sc, seed), srv.submit(name, ins))
            for seed, sc in enumerate(SHARED_BUCKET_SCALES[name])]
    assert len(srv.stats()["buckets"]) == 1     # one shared shape bucket
    assert srv.pump() == 4
    for ins, t in reqs:
        assert_bit_identical(name, ins, t.output)
    (row,) = srv.stats()["buckets"].values()
    assert row["pad"] > 0           # padding actually happened


def test_ragged_shapes_land_in_different_buckets(fake_clock):
    """Lengths on opposite sides of a power-of-two edge split buckets —
    and both still serve bit-identically."""
    srv = make_server(fake_clock, max_batch=2)
    small = ragged("group_by", 0.2, 0)      # 8 rows  → bucket 8 (floor)
    large = ragged("group_by", 2.0, 1)      # 80 rows → bucket 128
    ts = srv.submit("group_by", small)
    tl = srv.submit("group_by", large)
    assert len(srv.stats()["buckets"]) == 2
    assert srv.drain() == 2
    assert_bit_identical("group_by", small, ts.output)
    assert_bit_identical("group_by", large, tl.output)


# ---------------------------------------------------------------------------
# scheduling: full-bucket flush, straggler timeout, scripted arrivals
# ---------------------------------------------------------------------------

def test_straggler_timeout_flush(fake_clock):
    """A single request never fills its bucket; the flush_ms timeout must
    flush it — at exactly the scripted tick, not before."""
    srv = make_server(fake_clock, flush_ms=2.0)
    ins = ragged("group_by", 1.0, 0)
    t = srv.submit("group_by", ins)
    assert srv.pump() == 0                  # t=0: not full, not timed out
    fake_clock.advance(0.0015)
    assert srv.pump() == 0                  # 1.5ms < 2ms: still waiting
    fake_clock.advance(0.0006)
    assert srv.pump() == 1                  # 2.1ms: timeout flush fires
    assert t.state == "done"
    assert_bit_identical("group_by", ins, t.output)
    (row,) = srv.stats()["buckets"].values()
    assert row["reqs"] == 1 and row["flushes"] == 1


def test_scripted_arrivals_mixed_programs(fake_clock):
    """Interleaved arrivals across all three programs on one scripted
    timeline: full buckets flush at arrival, stragglers at timeout."""
    srv = make_server(fake_clock, max_batch=2, flush_ms=2.0)
    tickets = []

    def sub(name, seed):
        ins = ragged(name, 1.0, seed)
        tickets.append((name, ins, srv.submit(name, ins)))

    events = [
        (0.0000, lambda: sub("pagerank", 0)),
        (0.0002, lambda: sub("group_by", 1)),
        (0.0004, lambda: sub("pagerank", 2)),   # fills pagerank bucket
        (0.0006, lambda: sub("kmeans_step", 3)),
        (0.0031, lambda: None),                 # group_by+kmeans time out
    ]
    done = run_schedule(fake_clock, events, srv.pump)
    assert done == 4
    for name, ins, t in tickets:
        assert t.state == "done"
        assert_bit_identical(name, ins, t.output)
    s = srv.stats()
    assert s["admitted"] == s["completed"] == 4 and s["queued"] == 0


def test_second_flush_hits_batch_cache(fake_clock):
    """Same bucket, same lane count → the second flush reuses the traced
    batch computation (no retrace)."""
    srv = make_server(fake_clock, max_batch=2)
    for seed in (0, 1):
        srv.submit("group_by", ragged("group_by", 1.0, seed))
    assert srv.pump() == 2
    for seed in (2, 3):
        srv.submit("group_by", ragged("group_by", 1.0, seed))
    assert srv.pump() == 2
    s = srv.stats()
    assert s["batch_traced"] == 1 and s["batch_hits"] == 1


def test_cancel_before_flush(fake_clock):
    srv = make_server(fake_clock)
    keep = srv.submit("group_by", ragged("group_by", 1.0, 0))
    gone = srv.submit("group_by", ragged("group_by", 1.0, 1))
    assert srv.cancel(gone)
    assert gone.state == "cancelled"
    with pytest.raises(RuntimeError, match="cancelled"):
        gone.result(0)
    assert srv.drain() == 1
    assert keep.state == "done"
    assert not srv.cancel(keep)             # too late: already served
    s = srv.stats()
    assert s["admitted"] == s["completed"] + s["cancelled"] + s["queued"]


# ---------------------------------------------------------------------------
# golden: the observability surface is pinned (cf. test_plan_explain.py)
# ---------------------------------------------------------------------------

def test_explain_serving_golden(fake_clock):
    """Under a fake clock every number in explain_serving() is exact:
    bucket rows, occupancy, pad fraction, latency percentiles,
    throughput, and the batch-signature cache line.  Freshly compiled
    programs (not the module-shared ones) pin the traced/hit counts
    regardless of test order."""
    fresh = {n: compile_program(getattr(progs, n)) for n in WORKLOADS}
    srv = PlanServer(fresh, clock=fake_clock, max_batch=2, flush_ms=2.0,
                     bucket_floor=8)
    for seed, sc in ((0, 1.0), (1, 0.9)):
        srv.submit("group_by", ragged("group_by", sc, seed))
    assert srv.pump() == 2                  # full bucket at t=0
    srv.submit("kmeans_step", ragged("kmeans_step", 1.0, 2))
    fake_clock.advance(0.004)
    assert srv.pump() == 1                  # straggler timeout at t=4ms
    text = srv.explain_serving()
    assert text.splitlines()[0] == (
        "== serving plans: 3 programs, max_batch=2, flush=2.0ms, "
        "bucket_floor=8 ==")
    assert "bucket group_by{S:64}#" in text
    assert "depth=0 reqs=2 flushes=1 occ=100% pad=" in text
    assert "bucket kmeans_step{P:32 Cl:32 D:32 MinD:32 K=4}#" in text
    assert ("totals: admitted=3 completed=3 cancelled=0 failed=0 queued=0"
            in text)
    assert "latency: p50=0.0ms p99=4.0ms  throughput=750.0 req/s" in text
    assert ("whole-program cache: 2 batch signatures traced, 0 hits, "
            "0 sequential fallbacks") in text


# ---------------------------------------------------------------------------
# batchable-entry hooks (core/lower.py, core/plan.py)
# ---------------------------------------------------------------------------

def test_entry_signature_matches_device_signature():
    """Host-side bucketing key == the device-side compile-cache key."""
    for name in WORKLOADS:
        cp = cps()[name]
        ins = ragged(name, 1.0, 0)
        host = cp.entry_signature(cp.canonical_inputs(ins))
        dev = cp._signature(cp.prepare_env(deep_copy(ins)))
        assert host == dev, name


def test_bag_row_aligned_analysis():
    """kmeans' per-point scratch arrays ride the bag's row count; the
    dim-N state of pagerank and group_by's keyed map do not."""
    assert cps()["kmeans_step"].bag_row_aligned == {
        "D": "P", "MinD": "P", "Cl": "P"}
    assert cps()["pagerank"].bag_row_aligned == {}
    assert cps()["group_by"].bag_row_aligned == {}

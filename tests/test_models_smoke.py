"""Per-architecture smoke tests: REDUCED same-family configs, one train
step + prefill + decode on CPU, asserting shapes and finiteness; plus
prefill→decode vs full-forward logits parity (cache correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import get_model
from repro.optim.adamw import adamw_init
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

ARCHS = list_archs()


def _batch(cfg, B, S, rng):
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["pos_ids"] = np.broadcast_to(
            np.arange(S, dtype=np.int32)[None, :, None], (B, S, 3)).copy()
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, 2, 16, rng)
    step = jax.jit(make_train_step(cfg, None, ("data",),
                                   compress_grads=False))
    p2, o2, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(1)
    B, S, MAX = 2, 16, 32
    batch = _batch(cfg, B, S, rng)
    batch.pop("labels")
    logits, cache = jax.jit(make_prefill_step(cfg, MAX))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    dec = jax.jit(make_decode_step(cfg))
    tok = np.array([[1], [2]], np.int32)
    lg, cache = dec(params, cache, tok, jnp.asarray(S, jnp.int32))
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "qwen3-moe-30b-a3b",
                                  "whisper-tiny"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode of token S must equal the forward pass logits at
    position S (cache correctness across every cache type)."""
    cfg = smoke_config(arch)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=8.0)  # no drops -> exact parity
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(2)
    B, S = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)

    if cfg.family == "audio":
        frames = rng.standard_normal((B, cfg.enc_seq, cfg.d_model)) \
            .astype(np.float32)
        lg_pref, cache = model.prefill(params, jnp.asarray(frames),
                                       jnp.asarray(toks[:, :S]), S + 4)
        lg_dec, _ = model.decode(params, cache, jnp.asarray(toks[:, S:S + 1]),
                                 jnp.asarray(S, jnp.int32))
        # full forward over S+1 tokens
        loss_in = {"frames": jnp.asarray(frames),
                   "tokens": jnp.asarray(toks),
                   "labels": jnp.asarray(toks)}
        # reuse decoder stack via prefill on S+1 and its last logits
        lg_full, _ = model.prefill(params, jnp.asarray(frames),
                                   jnp.asarray(toks), S + 4)
    else:
        kw = {}
        lg_pref, cache = model.prefill(params, jnp.asarray(toks[:, :S]),
                                       S + 4, **kw)
        lg_dec, _ = model.decode(params, cache, jnp.asarray(toks[:, S:S + 1]),
                                 jnp.asarray(S, jnp.int32))
        lg_full, _ = model.prefill(params, jnp.asarray(toks), S + 4)

    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-2, atol=2e-2)


def test_long_context_archs_have_constant_decode_state():
    """long_500k rationale: SSM / hybrid decode state must not scale with
    the context length."""
    for arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = smoke_config(arch)
        model = get_model(cfg)
        small = model.cache_defs(1, 1024)
        big = model.cache_defs(1, 1024 * 64)
        sb = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(small))
        bb = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(big))
        assert bb == sb, arch  # window/state caches: size independent of S


def test_moe_local_vs_ep_consistency():
    import os
    import subprocess
    import sys
    # shard_map EP needs >1 device -> subprocess with forced host devices
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.models.moe import moe_defs, moe_local, moe_forward
from repro.models.common import tree_init
from repro.launch.mesh import make_test_mesh
cfg = smoke_config("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
p = tree_init(moe_defs(cfg), 1)
mesh = make_test_mesh((2, 2), ("data", "model"))
x = np.random.default_rng(1).standard_normal((4, 16, cfg.d_model)).astype(np.float32)
y1 = np.asarray(jax.jit(lambda p, x: moe_local(cfg, p, x))(p, x))
y2 = np.asarray(jax.jit(lambda p, x: moe_forward(cfg, p, x, mesh, ("data",)))(p, x))
err = np.abs(y1 - y2).max() / (np.abs(y1).max() + 1e-9)
assert err < 2e-3, err
print("OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout

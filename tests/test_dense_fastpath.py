"""Dense fast-path operator specialization (pass: dense-fastpath).

Golden explain() output: identity-space stores show as DenseMap, columnar
reductions carry the [dense] certificate, and the paper-faithful matmul's
AxisReduce carries the [mxu] product certificate — plus guard tests that
non-identity indexing (transposed / shifted subscripts) takes the general
path with identical results, and that the runtime extent guard falls back
without changing results.
"""
import numpy as np

from repro.core import compile_program, interpret, loop_program
from repro.core import dim, matrix, scalar, vector
from repro.core.plan import AxisReduce, DenseMap, MapExpr, flatten
from repro.core.programs import ALL


# ---------------------------------------------------------------------------
# golden explains
# ---------------------------------------------------------------------------

def test_matrix_addition_explains_dense_map():
    cp = compile_program(ALL["matrix_addition"])
    text = cp.explain()
    assert "DenseMap[i×j] → R[i,j]" in text
    assert "(vectorized, gathers elided)" in text
    assert isinstance(cp.plan[0], DenseMap)
    rng = np.random.default_rng(0)
    M, N = rng.standard_normal((5, 4)), rng.standard_normal((5, 4))
    out = cp.run(dict(M=M, N=N, R=np.zeros((5, 4)), n=5, m=4))
    np.testing.assert_allclose(np.asarray(out["R"]), M + N, rtol=1e-5)


def test_conditional_sum_explains_dense_columnar():
    text = compile_program(ALL["conditional_sum"]).explain()
    assert "[dense: columnar, no gathers]" in text


def test_gathering_reduce_is_not_dense():
    @loop_program
    def gsum(V: vector, A: vector, s: scalar, n: dim):
        for i in range(0, n):
            s += A[int(V[i])]

    text = compile_program(gsum).explain()
    assert "[dense" not in text         # value gathers: no columnar cert


def test_paper_faithful_matmul_explains_mxu():
    cp = compile_program(ALL["matrix_multiplication"],
                         optimize_contractions=False)
    text = cp.explain()
    assert "EinsumContract" not in text   # operator choice stays faithful
    assert "AxisReduce(+ over k)" in text
    assert "[mxu: 'ik,kj->ij']" in text   # ...but materializes on the MXU
    node = flatten(cp.plan)[1]          # inside the pass-11 round region
    assert isinstance(node, AxisReduce) and node.product is not None
    rng = np.random.default_rng(1)
    A, B = rng.standard_normal((7, 5)), rng.standard_normal((5, 6))
    out = cp.run(dict(M=A, N=B, R=np.zeros((7, 6)), n=7, m=6, l=5))
    np.testing.assert_allclose(np.asarray(out["R"]), A @ B, rtol=1e-5)


def test_promoted_einsum_fallback_keeps_grid():
    # once promoted to EinsumContract, the fallback AxisReduce must NOT
    # retry the same product guards (it exists to handle their failure)
    cp = compile_program(ALL["matrix_multiplication"])
    node = flatten(cp.plan)[1].contract  # TiledMatmul → EinsumContract
    assert node.fallback.product is None


def test_fastpath_disabled_matches_and_explains_plain():
    cp_off = compile_program(ALL["matrix_multiplication"],
                             optimize_contractions=False,
                             dense_fastpath=False)
    text = cp_off.explain()
    assert "[mxu" not in text and "DenseMap" not in text
    cp_on = compile_program(ALL["matrix_multiplication"],
                            optimize_contractions=False)
    rng = np.random.default_rng(2)
    A, B = rng.standard_normal((6, 4)), rng.standard_normal((4, 9))
    ins = dict(M=A, N=B, R=np.zeros((6, 9)), n=6, m=9, l=4)
    np.testing.assert_allclose(np.asarray(cp_off.run(ins)["R"]),
                               np.asarray(cp_on.run(ins)["R"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# guard tests: non-identity indexing takes the general path
# ---------------------------------------------------------------------------

def test_transposed_subscripts_take_general_path():
    @loop_program
    def tadd(M: matrix, N: matrix, R: matrix, n: dim):
        for i in range(0, n):
            for j in range(0, n):
                R[i, j] = M[j, i] + N[i, j]

    cp = compile_program(tadd)
    store = cp.plan[0]
    assert isinstance(store, MapExpr) and not isinstance(store, DenseMap)
    assert "DenseMap" not in cp.explain()
    rng = np.random.default_rng(3)
    M, N = rng.standard_normal((4, 4)), rng.standard_normal((4, 4))
    out = cp.run(dict(M=M, N=N, R=np.zeros((4, 4)), n=4))
    np.testing.assert_allclose(np.asarray(out["R"]), M.T + N, rtol=1e-5)


def test_shifted_subscripts_take_general_path():
    @loop_program
    def shift(V: vector, W: vector, n: dim):
        for i in range(0, n):
            W[i] = V[i + 1] * 2.0

    cp = compile_program(shift)
    store = cp.plan[0]
    assert isinstance(store, MapExpr) and not isinstance(store, DenseMap)
    v = np.arange(5, dtype=np.float64)
    ins = dict(V=v, W=np.full(5, 7.0), n=5)
    out = cp.run(ins)
    ref = interpret(shift.program, dict(V=v.copy(), W=np.full(5, 7.0), n=5))
    # row n-1 reads out of range → empty bag → keeps the old value
    np.testing.assert_allclose(np.asarray(out["W"]), ref["W"], rtol=1e-6)
    assert ref["W"][4] == 7.0


def test_negative_segment_keys_drop_not_wrap():
    # the direct-scatter segment path relies on mode="drop" for UPPER
    # bounds, but jax normalizes negative indices to end-relative ones
    # BEFORE the drop check — they need the explicit sentinel (§3.4:
    # out-of-range writes denote the empty bag, they never wrap)
    cp = compile_program(ALL["group_by"])
    ins = dict(S=(np.array([0.0, -1.0, 2.0]), np.array([1.0, 10.0, 3.0])),
               C=np.zeros(3))
    ref = interpret(ALL["group_by"].program,
                    dict(S=(np.array([0.0, -1.0, 2.0]),
                            np.array([1.0, 10.0, 3.0])), C=np.zeros(3)))
    np.testing.assert_allclose(np.asarray(cp.run(ins)["C"]), ref["C"],
                               rtol=1e-6)
    assert ref["C"][2] == 3.0           # key -1 dropped, not wrapped


def test_dense_map_runtime_guard_falls_back():
    # the node IS a DenseMap, but the destination has more rows than the
    # iteration space at runtime: the extent guard must route through the
    # general MapExpr path (write only the covered block)
    cp = compile_program(ALL["matrix_addition"])
    assert isinstance(cp.plan[0], DenseMap)
    rng = np.random.default_rng(4)
    M, N = rng.standard_normal((3, 4)), rng.standard_normal((3, 4))
    R0 = np.full((5, 4), 9.0)
    out = cp.run(dict(M=M, N=N, R=R0.copy(), n=3, m=4))
    got = np.asarray(out["R"])
    np.testing.assert_allclose(got[:3], M + N, rtol=1e-5)
    np.testing.assert_allclose(got[3:], 9.0)

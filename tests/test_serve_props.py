"""Property tests for the plan-serving layer: random interleavings of
submit / cancel / pump / clock-advance / drain never lose or duplicate a
response, and the admission ledger stays consistent
(admitted == completed + cancelled + failed + queued).

Hypothesis generates the interleavings when available (optional import,
as in test_kernels.py); without it the same property runs over a fixed
sweep of seeded random schedules, so the invariant is exercised either
way.  Everything runs on the shared FakeClock — no real sleeps.
"""
import numpy as np
import pytest

try:        # interleavings are hypothesis-driven; the seeded sweep isn't
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

from conftest import FakeClock
from test_core_programs import data_for

from repro.core import programs as progs
from repro.core.lower import compile_program
from repro.serve import PlanServer

_CPS = {}


def cps():
    if not _CPS:
        for name in ("group_by", "pagerank"):
            _CPS[name] = compile_program(getattr(progs, name))
    return _CPS


def run_interleaving(ops, allow_failed=False):
    """Execute one schedule.  `ops` is a list of (kind, x) with kind in
    submit (x = bag-length scale index), cancel (x = request index),
    advance (x = ms), pump, drain — checking the ledger invariant after
    every step and the exactly-once completion property at the end.
    `allow_failed` relaxes only the failed==0 check (fault-injection
    schedules may legitimately fail requests — never lose them)."""
    clock = FakeClock()
    srv = PlanServer(cps(), clock=clock, max_batch=3, flush_ms=2.0,
                     bucket_floor=8)
    rng = np.random.default_rng(7)
    tickets = []

    def check_ledger():
        s = srv.stats()
        assert s["admitted"] == (s["completed"] + s["cancelled"]
                                 + s["failed"] + s["queued"])
        assert s["admitted"] == len(tickets)
        # served-lane balance (satellite of the _flush accounting fix):
        # bucket req counters record only successfully batch-served lanes,
        # so they + sequential fallbacks must reconcile with completions —
        # a failed flush can no longer inflate the served numbers
        assert sum(r["reqs"] for r in s["buckets"].values()) \
            + s["seq_fallbacks"] == s["completed"]

    for kind, x in ops:
        if kind == "submit":
            name = ("group_by", "pagerank")[x % 2]
            d = data_for(name)
            m = 10 + 7 * (x % 4)            # ragged: crosses bucket edges
            if name == "group_by":
                d["S"] = (rng.integers(0, 10, m).astype(np.float64),
                          rng.standard_normal(m))
            else:
                N = int(d["N"])
                d["E"] = (rng.integers(0, N, m).astype(np.float64),
                          rng.integers(0, N, m).astype(np.float64))
            tickets.append(srv.submit(name, d))
        elif kind == "cancel" and tickets:
            srv.cancel(tickets[x % len(tickets)])
        elif kind == "advance":
            clock.advance(x / 1e3)
        elif kind == "pump":
            srv.pump()
        elif kind == "drain":
            srv.drain()
        check_ledger()

    srv.drain()
    check_ledger()
    s = srv.stats()
    assert s["queued"] == 0
    # exactly-once: every ticket resolved exactly one way, none lost
    assert all(t._completions == 1 for t in tickets)
    done = [t for t in tickets if t.state == "done"]
    assert len({t.rid for t in tickets}) == len(tickets)    # unique rids
    assert s["completed"] == len(done)
    if not allow_failed:
        assert s["failed"] == 0
    for t in done:                          # every response has a payload
        assert t.output is not None and set(t.output)


_OP = [("submit", 0), ("submit", 1), ("submit", 2), ("submit", 3),
       ("cancel", 0), ("cancel", 1), ("advance", 1), ("advance", 3),
       ("pump", 0), ("drain", 0)]


if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.sampled_from(_OP), min_size=1, max_size=24))
    def test_interleavings_never_lose_or_duplicate(ops):
        run_interleaving(ops)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_interleavings_never_lose_or_duplicate(seed):
        rng = np.random.default_rng(seed)
        ops = [_OP[i] for i in rng.integers(0, len(_OP), 24)]
        run_interleaving(ops)


@pytest.mark.parametrize("seed", range(4))
def test_faulted_interleavings_keep_ledger_balanced(seed):
    """The same interleaving property under injected faults: transient
    batched-call errors (retried) and a rid-matched deterministic error
    (bisected out) must never unbalance the ledger or lose/duplicate a
    ticket — only `failed` may now be nonzero."""
    from repro.core import faults as F
    rng = np.random.default_rng(100 + seed)
    ops = [_OP[i] for i in rng.integers(0, len(_OP), 24)]
    specs = [F.FaultSpec("serve.batched_call", "transient", nth=n)
             for n in (1, 4, 7)]
    specs.append(F.FaultSpec("serve.batched_call", "deterministic",
                             rid=seed, times=1000))
    with F.inject(*specs):
        run_interleaving(ops, allow_failed=True)


def test_cancel_all_then_drain():
    """Degenerate interleaving: everything cancelled before any flush —
    drain must be a no-op and the ledger must balance."""
    srv = PlanServer(cps(), clock=FakeClock(), max_batch=4)
    ts = [srv.submit("group_by", data_for("group_by")) for _ in range(3)]
    for t in ts:
        assert srv.cancel(t)
    assert srv.drain() == 0
    s = srv.stats()
    assert s["cancelled"] == s["admitted"] == 3
    assert s["completed"] == s["queued"] == 0

"""Golden physical-plan tests: the pass pipeline picks the expected operator
per statement (visible via CompiledProgram.explain), and plan-level cleanups
(dead-store elimination, update fusion) preserve interpreter semantics."""
import numpy as np

from repro.core import compile_program, interpret, loop_program
from repro.core import matrix, vector, dim
from repro.core.plan import (AxisReduce, EinsumContract, Fused, MapExpr,
                             SegmentReduce, TiledMatmul, flatten)
from repro.core.programs import ALL


def test_matmul_explains_einsum():
    cp = compile_program(ALL["matrix_multiplication"])
    text = cp.explain()
    assert "EinsumContract('ik,kj->ij'; M,N)" in text
    assert "[fallback: AxisReduce(+ over k)" in text
    # matmul-shaped contractions carry the §5 wrapper; dense lhs at runtime
    # resolves to the EinsumContract underneath
    node = flatten(cp.plan)[1]          # [zero-init; contract] region
    assert isinstance(node, TiledMatmul)
    assert isinstance(node.contract, EinsumContract)


def test_matmul_paper_faithful_explains_axis_reduce():
    cp = compile_program(ALL["matrix_multiplication"],
                         optimize_contractions=False)
    text = cp.explain()
    assert "EinsumContract" not in text
    assert "AxisReduce(+ over k)" in text


def test_histogram_explains_segment_reduce():
    cp = compile_program(ALL["histogram"])
    text = cp.explain()
    assert text.count("SegmentReduce(+") == 3
    for dest in ("R", "G", "B"):
        assert f"→ {dest}" in text
    # the three updates share one iteration space → fused into one round
    assert isinstance(cp.plan[0], Fused)
    assert len(cp.plan[0].parts) == 3


def test_rule17_axis_reduction_explains():
    @loop_program
    def row_min(M: matrix, S: vector, n: dim, m: dim):
        for i in range(0, n):
            for j in range(0, m):
                S[i] = min(S[i], M[i, j])

    cp = compile_program(row_min)
    text = cp.explain()
    assert "AxisReduce(min over j)" in text
    assert "SegmentReduce" not in text     # pure axis keys: no shuffle
    rng = np.random.default_rng(0)
    M = rng.standard_normal((6, 5))
    out = cp.run(dict(M=M, S=np.full(6, 1e30), n=6, m=5))
    np.testing.assert_allclose(np.asarray(out["S"]), M.min(axis=1), rtol=1e-6)


def test_tiled_matmul_explains_fused_kernel():
    cp = compile_program(ALL["matrix_multiplication"])
    text = cp.explain(tiled={"M"})
    assert "TiledMatmul" in text           # §5 fusion: packed lhs, no unpack
    assert "unpack" not in text.lower()
    node = flatten(cp.plan)[1]
    assert isinstance(node, TiledMatmul) and node.lhs == "M"
    # without the packed-input hint the same plan resolves to the einsum
    assert "TiledMatmul" not in compile_program(
        ALL["matrix_multiplication"]).explain()


def test_dead_store_eliminated():
    @loop_program
    def reinit(V: vector, W: vector, n: dim):
        for i in range(0, n):
            W[i] = 0.0
            W[i] = float(i) * 2.0

    cp = compile_program(reinit)
    stores = [x for x in flatten(cp.plan) if isinstance(x, MapExpr)]
    assert len(stores) == 1                # the zero-store is dead
    v = np.arange(5, dtype=np.float64)
    ins = dict(V=v, W=np.full(5, 7.0), n=5)
    out = cp.run(ins)
    ref = interpret(reinit.program, dict(V=v.copy(), W=np.full(5, 7.0), n=5))
    np.testing.assert_allclose(np.asarray(out["W"]), ref["W"], rtol=1e-6)


def test_gather_killer_does_not_eliminate():
    # a killer whose value gathers at computed indices can DROP rows at
    # runtime (empty-bag semantics), so it must not kill the zero-init
    @loop_program
    def indirect(V: vector, A: vector, W: vector, n: dim):
        for i in range(0, n):
            W[i] = 0.0
            W[i] = A[int(V[i])] + 10.0

    cp = compile_program(indirect)
    stores = [x for x in flatten(cp.plan) if isinstance(x, MapExpr)]
    assert len(stores) == 2                # both survive
    v = np.array([0.0, 1.0, 9.0, 2.0])     # row 2 gathers out of range
    a = np.array([0.0, 1.0, 2.0, 3.0])
    ins = dict(V=v, A=a, W=np.full(4, 7.0), n=4)
    out = cp.run(ins)
    ref = interpret(indirect.program,
                    dict(V=v.copy(), A=a.copy(), W=np.full(4, 7.0), n=4))
    np.testing.assert_allclose(np.asarray(out["W"]), ref["W"], rtol=1e-6)
    assert ref["W"][2] == 0.0              # dropped row sees the zero-init


def test_zero_init_before_update_not_eliminated():
    # matmul's R := 0 feeds the ⊕-update that follows: must survive DSE
    cp = compile_program(ALL["matrix_multiplication"])
    assert isinstance(flatten(cp.plan)[0], MapExpr)


def test_update_fusion_shares_iteration_space():
    cp = compile_program(ALL["linear_regression"])
    fused = [x for x in flatten(cp.plan) if isinstance(x, Fused)]
    assert len(fused) == 2                 # (sum_x,sum_y) and (xx_bar,xy_bar)
    assert all(len(f.parts) == 2 for f in fused)


def test_fusion_respects_dependences():
    # kmeans: Cl reads MinD, so their AxisReduces must NOT fuse
    cp = compile_program(ALL["kmeans_step"])
    ar = [x for x in flatten(cp.plan) if isinstance(x, AxisReduce)]
    assert len(ar) == 2                    # MinD and Cl, separate nodes
    fused = [x for x in flatten(cp.plan) if isinstance(x, Fused)]
    assert len(fused) == 1                 # only SX/SY/CN fuse
    assert {p.dest for p in fused[0].parts} == {"SX", "SY", "CN"}
    assert all(isinstance(p, SegmentReduce) for p in fused[0].parts)


def test_distributed_consumes_public_plan_interface():
    import repro.core.distributed as dist
    import inspect
    src = inspect.getsource(dist)
    assert "_StmtLowerer" not in src
    assert "bag_offset" not in src.replace("bag_offsets", "")


# ---------------------------------------------------------------------------
# sharding annotations (distribution analysis, DESIGN.md §6): the inferred
# placement per operand is part of explain()'s documented output
# ---------------------------------------------------------------------------

def test_pagerank_explains_oned_row_shardings():
    text = compile_program(ALL["pagerank"]).explain()
    # the rank update P[i] = (1-b)/N + b*NP[i]: destination and read both
    # shard by vertex row, aligned with axis var i (no collective needed)
    assert "shardings: P=ONED_ROW(i), NP=ONED_ROW(i)" in text
    # the shuffle NP[d] += P[s]/C[s]: destination sharded but written at
    # computed keys (unaligned → psum_scatter), reads cross shards
    assert "shardings: NP=ONED_ROW, C=ONED_ROW, P=ONED_ROW" in text
    assert "=REP" not in text              # nothing replicates in pagerank


def test_matmul_explains_twod_block_operands():
    text = compile_program(ALL["matrix_multiplication"]).explain()
    assert "M=TWOD_BLOCK" in text          # pure matmul operands
    assert "N=TWOD_BLOCK" in text
    assert "R=ONED_ROW(i)" in text         # dest also has a non-matmul use


def test_rep_fallback_explains_rep():
    text = compile_program(ALL["pagerank"],
                           infer_distributions=False).explain()
    assert "ONED_ROW" not in text          # ⊥ everywhere when disabled
    assert "P=REP" in text


def test_scattered_write_explains_rep():
    @loop_program
    def strided(V: vector, W: vector, n: dim):
        for i in range(0, n):
            W[2 * i] = V[i]

    text = compile_program(strided).explain()
    assert "W=REP" in text                 # computed keys cross shards
    assert "V=ONED_ROW" in text            # read-only operand still shards

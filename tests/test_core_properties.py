"""Property-based validation of Theorem A.1 (meaning preservation): random
loop programs drawn from a restriction-respecting grammar must compile to
bulk JAX programs that agree with the sequential interpreter."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import RejectionError, compile_program, interpret
from repro.core.loop_ast import (Assign, BinOp, Call, Const, DIndex, ForRange,
                                 If, IncUpdate, Index, Program, TypeInfo,
                                 UnOp, Var)

N = 5  # vector length for all generated programs


def vec(name):
    return name, TypeInfo("vector", ("n",))


# --- expression strategies (over loop var i, arrays A/B/W, consts) ---

def exprs(depth=2):
    leaf = st.one_of(
        st.sampled_from([Var("i")]),
        st.floats(-2, 2, allow_nan=False).map(lambda c: Const(round(c, 3))),
        st.tuples(st.sampled_from(["A", "B"]), st.integers(-1, 1)).map(
            lambda t: Index(t[0], (BinOp("+", Var("i"), Const(t[1])),))),
    )
    if depth == 0:
        return leaf
    return st.one_of(
        leaf,
        st.tuples(st.sampled_from(["+", "-", "*"]), exprs(depth - 1),
                  exprs(depth - 1)).map(lambda t: BinOp(*t)),
        exprs(depth - 1).map(lambda e: Call("abs", (e,))),
    )


def key_expr():
    # affine keys i+c, or indirect int(W[i]) keys (the paper's flagship case)
    return st.one_of(
        st.integers(-1, 1).map(lambda c: BinOp("+", Var("i"), Const(c))),
        st.just(Call("int", (Index("W", (Var("i"),)),))),
    )


def inc_stmt():
    return st.tuples(st.sampled_from(["+", "max", "min"]), key_expr(),
                     exprs()).map(
        lambda t: IncUpdate(DIndex("C", (t[1],)), t[0], t[2]))


def store_stmt():
    # affine destination covering the loop index
    return st.tuples(st.integers(0, 1), exprs()).map(
        lambda t: Assign(DIndex("D", (BinOp("+", Var("i"), Const(t[0])),)),
                         t[1]))


def cond_stmt(inner):
    return st.tuples(exprs(1), inner).map(
        lambda t: If(BinOp("<", t[0], Const(0.5)), [t[1]], []))


def loop_programs():
    base = st.one_of(inc_stmt(), store_stmt())
    stmt = st.one_of(base, cond_stmt(base))
    return st.lists(stmt, min_size=1, max_size=3).map(
        lambda body: Program(
            "prop",
            dict([vec("A"), vec("B"), vec("W"), vec("C"), vec("D"),
                  ("n", TypeInfo("dim"))]),
            [ForRange("i", Const(0), Var("n"), body)],
            ("C", "D")))


@settings(max_examples=60, deadline=None)
@given(loop_programs(), st.integers(0, 2**31 - 1))
def test_random_programs_meaning_preserving(prog, seed):
    rng = np.random.default_rng(seed)
    ins = dict(A=rng.standard_normal(N).round(3),
               B=rng.standard_normal(N).round(3),
               W=rng.integers(0, N, N).astype(np.float64),
               C=rng.standard_normal(N).round(3),
               D=rng.standard_normal(N).round(3), n=N)
    try:
        cp = compile_program(prog)
    except RejectionError:
        return  # a generated program may legitimately violate Def 3.1
    out = cp.run(ins)
    ref = interpret(prog, {k: (np.array(v, np.float64)
                               if isinstance(v, np.ndarray) else v)
                           for k, v in ins.items()})
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k], np.float64),
                                   np.asarray(ref[k], np.float64),
                                   rtol=1e-3, atol=1e-4, err_msg=str(prog))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.floats(-2, 2,
                                                       allow_nan=False)),
                min_size=1, max_size=40))
def test_groupby_invariant_sum_preserved(pairs):
    """Group-by conservation law: total mass is invariant under grouping."""
    from repro.core.programs import group_by
    k = np.array([p[0] for p in pairs], np.float64)
    v = np.array([round(p[1], 3) for p in pairs], np.float64)
    out = compile_program(group_by).run(dict(S=(k, v), C=np.zeros(10)))
    np.testing.assert_allclose(float(np.asarray(out["C"]).sum()),
                               float(v.sum()), rtol=1e-4, atol=1e-4)

"""Property tests for the attention building blocks."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.launch import hlo_analysis
from repro.models.attention import _scores_mask, attention_core
from repro.models.common import apply_rope


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 8))
def test_window_mask_matches_definition(sq, sk, window):
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    m = np.asarray(_scores_mask(qp, kp, causal=True, window=window))
    for i in range(sq):
        for j in range(sk):
            want = j <= i and (window == 0 or j > i - window)
            assert m[i, j] == want, (i, j, window)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_rope_preserves_norm_and_relative_phase(seed, pos0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 16)), jnp.float32)
    pos = pos0 + jnp.arange(4)
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # relative property: <q_i, k_j> depends only on i-j
    q = jnp.asarray(rng.standard_normal((1, 8, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 1, 16)), jnp.float32)
    q1, k1 = apply_rope(q, jnp.arange(8), 1e4), apply_rope(k, jnp.arange(8), 1e4)
    q2, k2 = apply_rope(q, 5 + jnp.arange(8), 1e4), apply_rope(k, 5 + jnp.arange(8), 1e4)
    s1 = np.einsum("bqhd,bkhd->bqk", np.asarray(q1), np.asarray(k1))
    s2 = np.einsum("bqhd,bkhd->bqk", np.asarray(q2), np.asarray(k2))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)


def test_chunked_attention_equals_direct():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    direct = attention_core(q, k, v, causal=True, chunk_q=64)
    chunked = attention_core(q, k, v, causal=True, chunk_q=16)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               rtol=2e-3, atol=2e-3)


def test_local_window_chunked_subquadratic_path():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 8)), jnp.float32)
    full = attention_core(q, k, v, causal=True, window=16, chunk_q=128)
    # window+chunk < sk triggers the kv-sliced (subquadratic) branch
    sliced = attention_core(q, k, v, causal=True, window=16, chunk_q=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sliced),
                               rtol=2e-3, atol=2e-3)


def test_upcast_artifact_detector():
    hlo = """
HloModule m

ENTRY %main (p: bf16[4,8]) -> f32[] {
  %w = (s32[], bf16[4,8], f32[4,8], f32[2,2]) while(%t), condition=%c, body=%b
  ROOT %r = f32[] constant(0)
}
"""
    stats = hlo_analysis.parse_computations(hlo)
    art = hlo_analysis._upcast_artifact(stats)
    assert art == 4 * 8 * 4  # the f32[4,8] twin of bf16[4,8]; f32[2,2] not

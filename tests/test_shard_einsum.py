"""Per-shard MXU einsum rounds: inside a shard_map round the executor
keeps the jnp.einsum path (aligned operands as local blocks, replicated
ones via bounds-certified dynamic slices) instead of degrading to the
dense-grid AxisReduce — shardmap == single-device on 4- and 8-device host
meshes including non-divisible row counts, with golden explain_rounds()
output showing the einsum (not the AxisReduce fallback) inside the round.
Run in subprocesses: forcing host devices must happen before jax loads."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

ndev = {ndev}
mesh = make_test_mesh((ndev,), ("data",))
rng = np.random.default_rng(23)


def check(cp, ins):
    single = cp.run(ins)
    dp = compile_distributed(cp, mesh, ("data",))
    dist = dp.run(ins)
    for k in single:
        a = np.asarray(dist[k], np.float64)
        b = np.asarray(single[k], np.float64)
        assert a.shape == b.shape, (k, a.shape, b.shape)
        err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
        assert err < 1e-4, (k, err)
    return dp.explain_rounds()


# ---- matmul, paper-faithful plan (AxisReduce + mxu certificate), rows
# divisible and NOT divisible by the shard count ----
for n in (2 * ndev, 2 * ndev + 1, 13):
    m, l = 6, 5
    ins = dict(M=rng.standard_normal((n, l)), N=rng.standard_normal((l, m)),
               R=np.zeros((n, m)), n=n, m=m, l=l)
    cp = compile_program(ALL["matrix_multiplication"],
                         optimize_contractions=False)
    text = check(cp, ins)
    # golden: the sharded round runs the MXU einsum, not the dense grid
    assert "AxisReduce(+ over k) → R[i,j]  [mxu: 'ik,kj->ij']" in text, text
    assert "round: aligned→R over i" in text, text
    assert "per-shard[R]: mxu-einsum" in text, text
    assert "slice-certs[R]: M=local, N=static" in text, text
    assert "dense-grid" not in text, text

    # optimized plan: EinsumContract (under the TiledMatmul wrapper)
    cp2 = compile_program(ALL["matrix_multiplication"])
    text2 = check(cp2, ins)
    assert "per-shard[R]: einsum" in text2, text2
    assert "dense-grid" not in text2, text2

# ---- matrix factorization: every round's contraction stays einsum per
# shard (terms mode incl. contraction-free products), n and l both
# non-divisible ----
n, m, l = 10, 6, 5
mf_ins = dict(R=rng.standard_normal((n, m)),
              P=rng.standard_normal((n, l)) * 0.1,
              Q=rng.standard_normal((l, m)) * 0.1,
              Pp=rng.standard_normal((n, l)) * 0.1,
              Qp=rng.standard_normal((l, m)) * 0.1,
              pq=np.zeros((n, m)), err=np.zeros((n, m)),
              n=n, m=m, l=l, a=0.01, lam=0.1)
text = check(compile_program(ALL["matrix_factorization_step"]), mf_ins)
assert "per-shard[pq]: einsum" in text, text     # Pp·Qp product
assert "per-shard[P]: einsum" in text, text      # term-split gradient
assert "per-shard[Q]: einsum" in text, text      # window-sliced factors
assert "per-shard[err]: dense-store" in text, text
assert "dense-grid" not in text, text

# ---- pagerank: the rank-update rounds are DenseMap stores per shard ----
N = 13
pr_ins = dict(E=(rng.integers(0, N, 40).astype(np.float64),
                 rng.integers(0, N, 40).astype(np.float64)),
              P=np.full(N, 1 / N), NP=np.zeros(N), C=np.zeros(N),
              N=N, num_steps=3.0, steps=0.0, b=0.85)
text = check(compile_program(ALL["pagerank"]), pr_ins)
assert "per-shard[P]: dense-store" in text, text
assert "per-shard[NP]: dense-store" in text, text
print("SHARD_EINSUM_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_shard_einsum_equals_single_device(ndev):
    """ISSUE 3 acceptance: sharded einsum rounds execute jnp.einsum per
    shard (golden explain_rounds) and match single-device execution on 4-
    and 8-device meshes including non-divisible row counts."""
    r = subprocess.run([sys.executable, "-c", _CODE.format(ndev=ndev)],
                       capture_output=True, text=True, cwd=_ROOT,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SHARD_EINSUM_OK" in r.stdout

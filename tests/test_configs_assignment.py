"""Pin every architecture config to the assignment table (guards typos:
these numbers are the graded spec, not tunables)."""
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.cells import all_cells

SPEC = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
    "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
}


def test_all_ten_archs_registered():
    assert sorted(SPEC) == list_archs()


@pytest.mark.parametrize("name", sorted(SPEC))
def test_config_matches_assignment(name):
    cfg = get_config(name)
    layers, d, h, kv, ff, v = SPEC[name]
    assert cfg.num_layers == layers
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_specs():
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.num_experts, q.top_k, q.moe_d_ff) == (128, 8, 768)
    a = get_config("arctic-480b")
    assert (a.num_experts, a.top_k, a.dense_residual) == (128, 2, True)


def test_ssm_spec():
    m = get_config("falcon-mamba-7b")
    assert m.ssm_state == 16 and m.family == "ssm"


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_cell_enumeration_40_with_8_skips():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [c for c in cells if c[2] is not None]
    assert len(skipped) == 8  # long_500k for the 8 quadratic-attn archs
    assert all(s == "long_500k" for _, s, r in skipped)
    runnable_long = [a for a, s, r in cells if s == "long_500k" and r is None]
    assert sorted(runnable_long) == ["falcon-mamba-7b", "recurrentgemma-2b"]

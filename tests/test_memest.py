"""Peak-device-bytes estimator (DESIGN.md §12, `core/memest.py`): the
pass walks the physical plan with a shape environment built from concrete
inputs (or a serving-bucket signature), charges resident operands +
per-node temporaries + destination copies + collective buffers, and its
verdict — all-resident vs chunked — is the admission check run() consults
before touching the device.
"""
import numpy as np
import pytest

from repro.core import compile_program
from repro.core import memest
from repro.core.programs import ALL


def _wc_inputs(n=256, k=16):
    r = np.random.default_rng(0)
    return dict(W=(r.integers(0, k, n).astype(np.int32),),
                C=np.zeros(k, np.float32))


def _pr_inputs(n=64, ne=512):
    r = np.random.default_rng(1)
    return dict(E=(r.integers(0, n, ne).astype(np.int32),
                   r.integers(0, n, ne).astype(np.int32)),
                P=np.full(n, 1.0 / n, np.float32),
                NP=np.zeros(n, np.float32), C=np.zeros(n, np.float32),
                N=n, num_steps=3.0, steps=0.0, b=0.85)


def test_fmt_bytes():
    assert memest.fmt_bytes(512) == "512B"
    assert memest.fmt_bytes(2048) == "2.0KiB"
    assert memest.fmt_bytes(3 * 1024 ** 2) == "3.0MiB"
    assert "GiB" in memest.fmt_bytes(5 * 1024 ** 3)


def test_shape_env_kinds():
    cp = compile_program(ALL["pagerank"])
    env = memest.shape_env(cp.program, cp.canonical_inputs(_pr_inputs()))
    assert env["N"] == ("dim", 64)
    kind, rows, cols = env["E"]
    assert kind == "bag" and rows == 512 and len(cols) == 2
    assert env["P"][0] == "array" and env["P"][1] == (64,)


def test_estimate_charges_more_than_resident():
    """The peak must exceed the raw resident footprint: temporaries for
    the widest node (gathered operands, masks, keys) are real bytes."""
    cp = compile_program(ALL["word_count"])
    ins = cp.canonical_inputs(_wc_inputs())
    est = memest.estimate(cp.plan, cp.program, memest.shape_env(
        cp.program, ins))
    assert est.peak_bytes > est.resident > 0
    assert est.bag_bytes["W"] >= 256  # one int32 column of 256 rows
    assert est.per_row("W") > 0
    assert est.fixed_bytes < est.peak_bytes


def test_estimate_scales_with_rows():
    cp = compile_program(ALL["word_count"])
    small = cp.estimate_memory(_wc_inputs(n=256))
    big = cp.estimate_memory(_wc_inputs(n=4096))
    assert big.peak_bytes > 4 * small.peak_bytes
    # fixed bytes (dests + non-bag residents) do NOT scale with the bag
    assert big.fixed_bytes == small.fixed_bytes


def test_summary_verdict_flips_on_budget():
    cp = compile_program(ALL["word_count"])
    est = cp.estimate_memory(_wc_inputs())
    roomy = est.summary(10 * est.peak_bytes)
    tight = est.summary(est.peak_bytes // 4)
    assert "all-resident" in roomy and "chunked" not in roomy
    assert "chunked" in tight
    assert "peak≈" in est.summary(None)


def test_explain_includes_memory_line_after_estimate():
    cp = compile_program(ALL["word_count"], memory_budget=10 ** 9)
    cp.estimate_memory(_wc_inputs())
    assert "memory: peak≈" in cp.explain()
    long = cp.explain_memory(_wc_inputs())
    assert "== memory estimate" in long and "streaming" in long


def test_estimate_memory_is_cached():
    cp = compile_program(ALL["word_count"])
    a = cp.estimate_memory(_wc_inputs())
    b = cp.estimate_memory(_wc_inputs())
    assert a is b
    c = cp.estimate_memory(_wc_inputs(n=512))
    assert c is not a


def test_signature_env_matches_concrete_env():
    """The serving layer only has the bucket signature — the estimate it
    derives must equal the one concrete inputs would give at the padded
    shapes (that equality is what makes lane caps trustworthy)."""
    cp = compile_program(ALL["word_count"])
    ins = cp.canonical_inputs(_wc_inputs(n=256))
    sig = []
    for name, t in cp.program.params.items():
        v = ins[name]
        if t.kind == "bag":
            sig.append((name, "bag", tuple(
                (tuple(c.shape), str(c.dtype)) for c in v)))
        elif t.kind == "dim":
            sig.append((name, "dim", int(v)))
        else:
            sig.append((name, t.kind, tuple(np.shape(v)),
                        str(np.asarray(v).dtype)))
    env_a = memest.shape_env(cp.program, ins)
    env_b = memest.shape_env_from_signature(cp.program, sig)
    pa = memest.estimate(cp.plan, cp.program, env_a).peak_bytes
    pb = memest.estimate(cp.plan, cp.program, env_b).peak_bytes
    assert pa == pb


def test_loop_program_peaks_at_widest_node():
    """pagerank's SeqLoop charges the MAX over its body nodes, not the
    sum — iterations reuse the same buffers."""
    cp = compile_program(ALL["pagerank"])
    est = cp.estimate_memory(_pr_inputs())
    node_peaks = [c.temp + c.dest + c.collective for c in est.nodes]
    assert est.peak_bytes == est.resident + max(node_peaks)


def test_explain_text_lists_nodes():
    cp = compile_program(ALL["pagerank"])
    text = cp.explain_memory(_pr_inputs())
    assert "SegmentReduce" in text or "segment" in text.lower()
    assert "resident" in text and "budget" not in text.splitlines()[0]

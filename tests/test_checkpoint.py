"""Fault tolerance: checkpoint/restart bit-exactness, crash-safety of the
atomic commit, elastic (different host count) resume of the data stream,
and straggler detection."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.data import SyntheticLMData
from repro.models import get_model
from repro.optim.adamw import adamw_init
from repro.runtime import TrainRunner
from repro.runtime.ft import SimulatedFailure
from repro.train.step import make_train_step


def _mk(tmp, arch="llama3-8b", ckpt_every=2):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(0)
    data = SyntheticLMData(cfg.vocab_size, 4, 16, seed=3)
    step = jax.jit(make_train_step(cfg, None, ("data",),
                                   compress_grads=False))
    return TrainRunner(step, params, adamw_init(params), data,
                       ckpt_dir=str(tmp), ckpt_every=ckpt_every)


def _leaves(tree):
    return [np.asarray(x, np.float32) for x in jax.tree.leaves(tree)]


def test_restart_resumes_bit_exact(tmp_path):
    # uninterrupted run to step 6
    r_full = _mk(tmp_path / "a")
    r_full.run(6)

    # interrupted at step 5 -> restart from the step-4 checkpoint
    r1 = _mk(tmp_path / "b")
    with pytest.raises(SimulatedFailure):
        r1.run(6, fail_at_step=5)
    r1.mgr.wait()

    r2 = _mk(tmp_path / "b")
    assert r2.maybe_resume()
    assert r2.step == 4
    assert r2.data.step == 4            # token stream resumes exactly
    r2.run(6)

    for a, b in zip(_leaves(r_full.params), _leaves(r2.params)):
        np.testing.assert_array_equal(a, b)


def test_atomic_commit_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(2, {"w": np.ones(3)})
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash mid-write
    assert mgr.latest() == 2


def test_keep_last_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": np.full(2, s)})
    assert mgr.steps() == [3, 4]


def test_elastic_resume_different_host_count():
    """The same global token stream must be produced when a restarted job
    has a different host count (elastic scaling)."""
    d1 = SyntheticLMData(100, global_batch=8, seq_len=8, seed=1,
                         host_index=0, host_count=1)
    b0 = d1.next_batch()
    state = d1.state()

    # resume with 2 hosts; concatenating both host slices == global batch
    parts = []
    for h in (0, 1):
        d = SyntheticLMData(100, global_batch=8, seq_len=8, seed=1,
                            host_index=h, host_count=2)
        d.restore(state, host_index=h, host_count=2)
        parts.append(d.next_batch()["tokens"])
    d1.restore(state)
    b1 = d1.next_batch()
    np.testing.assert_array_equal(np.concatenate(parts, 0), b1["tokens"])


def test_straggler_watchdog(tmp_path):
    import time
    r = _mk(tmp_path, ckpt_every=100)
    orig = r.step_fn

    def slow_step(p, o, b):
        if r.step == 6:
            time.sleep(1.0)
        return orig(p, o, b)

    r.step_fn = slow_step
    r.run(8)
    assert 6 in r.straggler_events


def test_elastic_mesh_reshard(tmp_path):
    """Restore onto a different (virtual) mesh: full-array checkpoints are
    shard-agnostic, so a job can come back on fewer/more chips."""
    import subprocess
    import sys
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.models import get_model
from repro.launch.mesh import make_test_mesh, axis_sizes

cfg = smoke_config("llama3-8b")
model = get_model(cfg)
params = model.init(0)
mgr = CheckpointManager(r"{tmp_path}", async_write=False)
mgr.save(1, params)

mesh = make_test_mesh((2, 2), ("data", "model"))
ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  model.pspecs(axis_sizes(mesh)),
                  is_leaf=lambda x: isinstance(x, P))
step, restored, _, _ = mgr.restore(1, model.abstract_params(),
                                   shardings=ns)
a = jax.tree.leaves(params)[2]
b = jax.tree.leaves(restored)[2]
assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
print("OK resharded onto", b.sharding)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK resharded" in r.stdout


def test_torn_snapshot_skipped_to_previous_good(tmp_path):
    """A torn write / bit flip in the NEWEST snapshot fails its crc32
    verification and latest() falls back to the previous good snapshot
    instead of restoring garbage."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(2, {"w": np.arange(8.0)})
    mgr.save(4, {"w": np.arange(8.0) * 2})
    payload = tmp_path / "step_00000004" / "params.npz"
    raw = bytearray(payload.read_bytes())
    raw[len(raw) // 2] ^= 0xFF                 # one flipped byte mid-file
    payload.write_bytes(bytes(raw))

    assert mgr.verify(2) and not mgr.verify(4)
    assert mgr.latest() == 2
    assert mgr.skipped == [4]

    step, flat, _ = mgr.restore_flat(2)
    np.testing.assert_array_equal(flat["w"], np.arange(8.0))


def test_snapshot_checksums_written_and_verify(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"w": np.arange(4.0)}, opt_state={"m": np.zeros(4)})
    import json
    with open(tmp_path / "step_00000001" / "checksums.json") as f:
        sums = json.load(f)
    assert set(sums) == {"params.npz", "opt.npz"}
    assert "w" in sums["params.npz"] and "m" in sums["opt.npz"]
    assert mgr.verify(1)


def test_legacy_snapshot_without_checksums_accepted(tmp_path):
    """Pre-checksum snapshots (no checksums.json) restore as-is: absence
    of stamps is not evidence of corruption."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(3, {"w": np.ones(3)})
    os.remove(tmp_path / "step_00000003" / "checksums.json")
    assert mgr.verify(3)
    assert mgr.latest() == 3

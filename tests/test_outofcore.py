"""Out-of-core execution (DESIGN.md §12, `core/chunked.py`): plans whose
inputs dwarf the device budget stream bag tiles through the existing
segment-reduce/scatter rounds while destination accumulators stay
resident.  The contract under test:

* bit-identity — a chunked run equals the all-resident `run_stepwise()`
  (host-driven node-at-a-time execution, the same reference PR 8's resume
  path uses) for EVERY tile size, and equals jitted `run()` for loop-free
  programs;
* admission — a memory estimate over budget routes run() through the
  chunked path up front, recorded in the ledger;
* the ladder — capacity errors descend whole → chunked (and eager →
  chunked), repeated capacity INSIDE the stream halves the tile,
  transients retry in place at the chunk sites, deterministic faults
  surface;
* resume — a killed chunked run restarts from the last chunk checkpoint
  via the ordinary `runtime/ft.LoopRunner` machinery (a ChunkLoop is just
  a top-level SeqLoop to the checkpointer).
"""
import numpy as np
import pytest

from repro.core import compile_program
from repro.core import faults as F
from repro.core import plan as P
from repro.core.chunked import ChunkLoop, choose_chunk_rows
from repro.core.programs import ALL
from repro.runtime import LoopRunner

N, NE = 64, 512


def pr_inputs(seed=7, ne=NE, steps=3.0):
    r = np.random.default_rng(seed)
    return dict(E=(r.integers(0, N, ne).astype(np.int32),
                   r.integers(0, N, ne).astype(np.int32)),
                P=np.full(N, 1.0 / N, np.float32),
                NP=np.zeros(N, np.float32), C=np.zeros(N, np.float32),
                N=N, num_steps=steps, steps=0.0, b=0.85)


def wc_inputs(seed=3, n=1024, k=32):
    r = np.random.default_rng(seed)
    return dict(W=(r.integers(0, k, n).astype(np.int32),),
                C=np.zeros(k, np.float32))


def _pr(**kw):
    cp = compile_program(ALL["pagerank"], op_select="force:scatter", **kw)
    cp.faults.sleep = lambda s: None
    return cp


def _wc(**kw):
    cp = compile_program(ALL["word_count"], op_select="force:scatter", **kw)
    cp.faults.sleep = lambda s: None
    return cp


def _bitident(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


# ---------------------------------------------------------------------------
# the chunking pass
# ---------------------------------------------------------------------------

def test_chunk_plan_wraps_bag_nodes():
    ck = _wc(out_of_core="force").chunker
    loops = [n for n in ck.plan if isinstance(n, ChunkLoop)]
    assert len(loops) == 1
    assert loops[0].chunk_bag == "W"
    assert "C" in loops[0].carry


def test_chunk_plan_recurses_into_seq_loops():
    ck = _pr(out_of_core="force").chunker
    assert ck.n_chunk_loops >= 2    # C outside the while, NP inside it
    outer = [n for n in ck.plan if isinstance(n, ChunkLoop)]
    assert outer, "degree count must stream at top level"


def test_chunk_bodies_pin_bit_identical_backend():
    """Streaming folds partial results chunk-by-chunk: only the direct
    scatter left-fold commutes with that split bit-exactly, so chunk
    bodies pin backend=scatter and salt=1 regardless of op_select."""
    cp = compile_program(ALL["word_count"])  # selector free to pick sort
    for node in P.flatten(cp.chunker.plan):
        if isinstance(node, ChunkLoop):
            for inner in P.flatten(node.body):
                if isinstance(inner, P.SegmentReduce):
                    assert inner.backend == "scatter"
                    assert inner.salt == 1


def test_choose_chunk_rows_fits_budget():
    cp = _wc()
    est = cp.estimate_memory(wc_inputs())
    rows = choose_chunk_rows(est, est.fixed_bytes + 64 * est.per_row("W"),
                             n_rows=1024)
    assert 1 <= rows <= 64
    assert est.fixed_bytes + rows * est.per_row("W") <= \
        est.fixed_bytes + 64 * est.per_row("W")
    # a roomy budget clamps to the full bag, a hopeless one to 1 row
    assert choose_chunk_rows(est, 10 ** 12, n_rows=1024) == 1024
    assert choose_chunk_rows(est, 0, n_rows=1024) == 1


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

def test_word_count_chunked_bitwise_vs_run():
    ref = _wc().run(wc_inputs())
    for tile in (1024, 100, 17):
        out = _wc(out_of_core="force", chunk_rows=tile).run(wc_inputs())
        assert _bitident(ref, out), tile


def test_pagerank_chunked_bitwise_vs_stepwise():
    """All tile sizes — including a non-divisor (7) exercising the padded
    last tile — reproduce the all-resident host-driven run bit-exactly."""
    ref = _pr().run_stepwise(pr_inputs(steps=5.0))
    for tile in (512, 100, 64, 7):
        out = _pr(out_of_core="force", chunk_rows=tile).run(
            pr_inputs(steps=5.0))
        assert _bitident(ref, out), tile


def test_ten_x_over_budget_completes():
    """The acceptance scenario: an edge bag ~10× the simulated budget
    streams to the bit-identical answer, with the chosen tile keeping
    fixed + tile·per_row within budget (peak O(tile + dests))."""
    ins = pr_inputs(steps=3.0)
    probe = _pr()
    est = probe.estimate_memory(ins)
    budget = est.fixed_bytes + est.bag_bytes["E"] // 10
    cp = _pr(memory_budget=budget)
    assert cp._ooc_admits(ins)
    rows = cp._initial_chunk_rows(ins)
    assert est.fixed_bytes + rows * est.per_row("E") <= budget
    out = cp.run(ins)
    ref = _pr().run_stepwise(pr_inputs(steps=3.0))
    assert _bitident(ref, out)
    assert cp.faults.counters["admission"] >= 1
    wc = _wc(memory_budget=400)      # W is 4KiB — 10× over
    out2 = wc.run(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), out2)


def test_admission_is_visible():
    cp = _wc(memory_budget=400)
    cp.run(wc_inputs())
    text = cp.explain_faults()
    assert "admission" in text and "chunked" in text
    assert "budget" in cp.explain_memory(wc_inputs())
    assert "[chunked]" in cp.explain_chunked()


def test_off_disables_admission():
    cp = _wc(memory_budget=400, out_of_core="off")
    assert not cp._ooc_admits(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), cp.run(wc_inputs()))


# ---------------------------------------------------------------------------
# the ladder: capacity → chunked, halving, retries
# ---------------------------------------------------------------------------

def test_capacity_at_whole_descends_to_chunked():
    cp = _wc()
    with F.inject(F.FaultSpec("lower.whole_trace", "capacity", nth=1,
                              times=10 ** 6)):
        out = cp.run(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), out)
    assert cp.faults.level_reached == "chunked"
    text = cp.explain_faults()
    assert "whole->chunked" in text and "recover" in text
    assert "whole->eager" not in text


def test_capacity_at_eager_descends_to_chunked():
    cp = _wc()
    with F.inject(F.FaultSpec("lower.whole_trace", "deterministic", nth=1),
                  F.FaultSpec("lower.node", "capacity", nth=1)):
        out = cp.run(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), out)
    assert "eager->chunked" in cp.explain_faults()


def test_capacity_mid_stream_halves_the_tile():
    cp = _wc(out_of_core="force", chunk_rows=256)
    with F.inject(F.FaultSpec("lower.chunk_step", "capacity", nth=2)):
        out = cp.run(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), out)
    text = cp.explain_faults()
    assert "chunked[256]->chunked[128]" in text
    assert cp.faults.level_reached == "chunked[128]"


def test_repeated_capacity_keeps_halving():
    cp = _wc(out_of_core="force", chunk_rows=64)
    with F.inject(F.FaultSpec("lower.chunk_step", "capacity", nth=1,
                              times=3)):
        out = cp.run(wc_inputs())
    assert _bitident(_wc().run(wc_inputs()), out)
    text = cp.explain_faults()
    assert "chunked[64]->chunked[32]" in text
    assert "chunked[32]->chunked[16]" in text


def test_transient_at_chunk_boundary_retries_in_place():
    cp = _wc(out_of_core="force", chunk_rows=128)
    with F.inject(F.FaultSpec("lower.chunk_step", "transient", nth=3)) \
            as inj:
        out = cp.run(wc_inputs())
    assert inj.fired
    assert _bitident(_wc().run(wc_inputs()), out)
    assert cp.faults.counters["retry"] >= 1
    assert cp.faults.counters["descend"] == 0


def test_transient_mid_prefetch_retries_in_place():
    cp = _wc(out_of_core="force", chunk_rows=128)
    with F.inject(F.FaultSpec("lower.chunk_prefetch", "transient",
                              nth=2)) as inj:
        out = cp.run(wc_inputs())
    assert inj.fired
    assert _bitident(_wc().run(wc_inputs()), out)
    assert cp.faults.counters["retry"] >= 1


def test_deterministic_in_stream_surfaces():
    cp = _wc(out_of_core="force", chunk_rows=128)
    with pytest.raises(F.DeterministicFault):
        with F.inject(F.FaultSpec("lower.chunk_step", "deterministic",
                                  nth=2, times=10 ** 6)):
            cp.run(wc_inputs())


def test_pagerank_capacity_descent_is_bitwise_stepwise():
    """whole → chunked must hold the STEPWISE identity even for a looped
    program (the chunked executor is host-driven like run_stepwise)."""
    cp = _pr()
    with F.inject(F.FaultSpec("lower.whole_trace", "capacity", nth=1,
                              times=10 ** 6)):
        out = cp.run(pr_inputs())
    ref = _pr().run_stepwise(pr_inputs())
    assert _bitident(ref, out)
    assert cp.faults.level_reached == "chunked"


# ---------------------------------------------------------------------------
# chunk-granular checkpoint/resume
# ---------------------------------------------------------------------------

def test_killed_chunked_run_resumes_from_chunk_checkpoint(tmp_path):
    ref = _pr(out_of_core="force", chunk_rows=64).run(pr_inputs(steps=5.0))

    cp = _pr(out_of_core="force", chunk_rows=64)
    runner = LoopRunner(cp, str(tmp_path), every=1)
    with pytest.raises(F.DeterministicFault):
        with F.inject(F.FaultSpec("lower.chunk_step", "deterministic",
                                  nth=5, times=10 ** 6)):
            runner.run(pr_inputs(steps=5.0), resume=False)
    assert runner.saves >= 1

    cp2 = _pr(out_of_core="force", chunk_rows=64)
    runner2 = LoopRunner(cp2, str(tmp_path), every=1)
    out = runner2.run(pr_inputs(steps=5.0), resume=True)
    assert runner2.resumed_from is not None
    assert _bitident(ref, out)


def test_resume_skips_completed_chunks(tmp_path):
    """The fast-forward is real: the resumed run must execute fewer
    chunks of the killed loop than a cold run would."""
    ins = wc_inputs(n=1024)
    cp = _wc(out_of_core="force", chunk_rows=128)   # 8 chunks
    runner = LoopRunner(cp, str(tmp_path), every=1)
    with pytest.raises(F.DeterministicFault):
        with F.inject(F.FaultSpec("lower.chunk_step", "deterministic",
                                  nth=6, times=10 ** 6)):
            runner.run(ins, resume=False)

    cp2 = _wc(out_of_core="force", chunk_rows=128)
    runner2 = LoopRunner(cp2, str(tmp_path), every=1)
    out = runner2.run(ins, resume=True)
    assert _bitident(_wc().run(wc_inputs(n=1024)), out)
    assert cp2.chunker.chunks_run < 8

"""Whole-program compilation (DESIGN.md §9): CompiledProgram.run() traces
the ENTIRE physical plan into one cached XLA computation per (static dims,
shapes, dtypes) signature — one dispatch per call — with the per-node eager
path as the guaranteed fallback.  Covers the compile-cache keying contract
(identical shapes hit the cache, different N/dtype/dims retrace — no shape
cross-contamination), buffer donation of mutated destinations, and
whole==eager equivalence on every benchmark program.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compile_program
from repro.core.programs import ALL
from test_core_programs import data_for


def _fresh(ins):
    """Deep-copy an input dict (runs must not share buffers)."""
    out = {}
    for k, v in ins.items():
        if isinstance(v, tuple):
            out[k] = tuple(np.array(c) for c in v)
        elif isinstance(v, np.ndarray):
            out[k] = v.copy()
        else:
            out[k] = v
    return out


def _check_equal(a, b, names):
    for k in names:
        np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                   np.asarray(b[k], np.float64),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# eager-fallback equivalence on every benchmark program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ALL))
def test_whole_equals_eager(name):
    ins = data_for(name)
    whole = compile_program(ALL[name])
    eager = compile_program(ALL[name], compile_mode="eager")
    out_w = whole.run(_fresh(ins))
    out_e = eager.run(_fresh(ins))
    _check_equal(out_w, out_e, out_w)
    # the whole-program path actually ran (no silent eager fallback) …
    assert whole.trace_count == 1 and not whole._whole_disabled
    # … and the eager configuration never traced a whole program
    assert eager.trace_count == 0


# ---------------------------------------------------------------------------
# compile-cache keying
# ---------------------------------------------------------------------------

def test_identical_shapes_hit_the_cache():
    ins = data_for("word_count")
    cp = compile_program(ALL["word_count"])
    a = cp.run(_fresh(ins))
    b = cp.run(_fresh(ins))
    assert cp.trace_count == 1 and cp.cache_hits == 1
    _check_equal(a, b, a)


def test_different_bag_length_retraces():
    rng = np.random.default_rng(0)
    cp = compile_program(ALL["word_count"])
    ref = compile_program(ALL["word_count"], compile_mode="eager")
    for n in (50, 80):                   # different N ⇒ new signature
        ins = dict(W=rng.integers(0, 10, n).astype(np.float64),
                   C=np.zeros(10))
        _check_equal(cp.run(_fresh(ins)), ref.run(_fresh(ins)), ["C"])
    assert cp.trace_count == 2 and cp.cache_hits == 0


def test_different_dtype_retraces():
    # bag columns keep their dtype (no f32 coercion): an int32 key column
    # and a float32 one are DIFFERENT signatures and must not share a
    # traced computation (f64 inputs coerce to f32 under jax defaults and
    # legitimately share one)
    rng = np.random.default_rng(1)
    cp = compile_program(ALL["word_count"])
    keys = rng.integers(0, 10, 32)
    rf = cp.run(dict(W=keys.astype(np.float32), C=np.zeros(10)))
    ri = cp.run(dict(W=keys.astype(np.int32), C=np.zeros(10)))
    assert cp.trace_count == 2            # bag dtype is part of the key
    np.testing.assert_allclose(np.asarray(rf["C"]), np.asarray(ri["C"]),
                               rtol=1e-5)


def test_different_dims_retrace():
    rng = np.random.default_rng(2)
    cp = compile_program(ALL["matrix_addition"])
    for n in (4, 7):                     # dims are static: shapes differ
        M = rng.standard_normal((n, 3))
        out = cp.run(dict(M=M, N=M, R=np.zeros((n, 3)), n=n, m=3))
        np.testing.assert_allclose(np.asarray(out["R"]), 2 * M, rtol=1e-5)
    assert cp.trace_count == 2


def test_explain_reports_compile_cache():
    ins = data_for("group_by")
    cp = compile_program(ALL["group_by"])
    cp.run(_fresh(ins))
    cp.run(_fresh(ins))
    text = cp.explain()
    assert "whole-program: mode=whole, 1 traced, 1 cache hits" in text
    text_e = compile_program(ALL["group_by"], compile_mode="eager").explain()
    assert "whole-program: mode=eager" in text_e


# ---------------------------------------------------------------------------
# buffer donation (mutated destinations + SeqLoop carries)
# ---------------------------------------------------------------------------

def test_donation_results_unchanged_and_buffer_freed():
    ins = data_for("word_count")
    ref = compile_program(ALL["word_count"], compile_mode="eager") \
        .run(_fresh(ins))
    cp = compile_program(ALL["word_count"], donate=True)
    c_in = jnp.zeros(10, jnp.float32)     # dest buffer, jax-owned
    out = cp.run(dict(W=ins["W"].copy(), C=c_in))
    _check_equal(out, ref, ["C"])
    # the destination buffer was donated to the computation and freed
    assert c_in.is_deleted()


def test_donation_seq_loop_carries():
    ins = data_for("pagerank")
    ref = compile_program(ALL["pagerank"], compile_mode="eager") \
        .run(_fresh(ins))
    cp = compile_program(ALL["pagerank"], donate=True)
    p_in = jnp.asarray(np.full(10, 0.1), jnp.float32)   # loop carry
    fresh = _fresh(ins)
    fresh["P"] = p_in
    out = cp.run(fresh)
    _check_equal(out, ref, out)
    assert p_in.is_deleted()
    # numpy inputs are copied to device per call: donation stays safe on
    # repeat runs with fresh buffers
    out2 = cp.run(_fresh(ins))
    _check_equal(out2, ref, out2)
    assert cp.trace_count == 1 and cp.cache_hits == 1
